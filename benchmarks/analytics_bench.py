"""Fig. 16-17: collaborative analytics — dataset modification latency and
storage, version diff vs difference size, aggregation queries (row vs
column layout vs OrpheusDB-style baseline).

Scaled down from the paper's 5M x 180 B records to 50k records (single
CPU); record layout matches (12 B pk, two ints, variable text)."""
from __future__ import annotations

import time

import numpy as np

from repro.apps import ColumnTable, OrpheusLite, RowTable
from repro.core import ForkBase

from .common import emit


def make_records(rng, n):
    recs = []
    for i in range(n):
        recs.append([f"pk{i:010d}".encode(),
                     str(int(rng.integers(0, 1000))).encode(),
                     str(int(rng.integers(0, 1000))).encode(),
                     rng.bytes(int(rng.integers(100, 200)))])
    return recs


def run():
    rng = np.random.default_rng(0)
    n = 50_000
    recs = make_records(rng, n)
    db = ForkBase()
    rt = RowTable(db, "ds")
    t0 = time.perf_counter()
    u0 = rt.load({r[0]: r for r in recs})
    emit("ds_import_forkbase_s", (time.perf_counter() - t0) * 1e6,
         f"physical={db.store.stats.physical_bytes / 1e6:.1f}MB")
    ol = OrpheusLite()
    t0 = time.perf_counter()
    v0 = ol.load(recs)
    emit("ds_import_orpheus_s", (time.perf_counter() - t0) * 1e6,
         f"storage={ol.storage_bytes / 1e6:.1f}MB")

    # Fig. 16: modification (100 rows) — ForkBase updates via the lazy
    # handle + incremental commit; Orpheus checkout -> modify -> commit
    idxs = rng.choice(n, 100, replace=False)
    ups = {recs[i][0]: [recs[i][0], b"7", b"7", b"upd"] for i in idxs}
    t0 = time.perf_counter()
    u1 = rt.update(ups)
    t_fb = (time.perf_counter() - t0) * 1e6
    phys0 = db.store.stats.physical_bytes
    t0 = time.perf_counter()
    work = ol.checkout(v0)
    for i in idxs:
        work[i] = [recs[i][0], b"7", b"7", b"upd"]
    v1 = ol.commit(v0, {int(i): work[i] for i in idxs})
    t_or = (time.perf_counter() - t0) * 1e6
    emit("ds_modify100_forkbase", t_fb, f"speedup={t_or / t_fb:.1f}x")
    emit("ds_modify100_orpheus", t_or)

    # Fig. 17a: version diff vs difference size
    for k in [10, 100, 1000]:
        idxs = rng.choice(n, k, replace=False)
        uk = rt.update({recs[i][0]: [recs[i][0], b"9", b"9", b"d"]
                        for i in idxs})
        vk = ol.commit(v0, {int(i): [recs[i][0], b"9", b"9", b"d"]
                            for i in idxs})
        t0 = time.perf_counter()
        a, r, c = rt.diff(uk, u0)
        t_fb = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        d = ol.diff(vk, v0)
        t_or = (time.perf_counter() - t0) * 1e6
        emit(f"ds_diff{k}_forkbase", t_fb, f"found={len(c) + len(a)}")
        emit(f"ds_diff{k}_orpheus", t_or, f"found={len(d)}")

    # Fig. 17b: aggregation — row vs column vs orpheus
    ct = ColumnTable(db, "dsc", ["pk", "a", "b", "payload"])
    ct.load(recs)
    t0 = time.perf_counter()
    s_row = rt.aggregate(1)
    emit("ds_agg_row_forkbase", (time.perf_counter() - t0) * 1e6)
    t0 = time.perf_counter()
    s_col = ct.aggregate("a")
    t_col = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    s_or = ol.aggregate(v0, 1)
    t_or = (time.perf_counter() - t0) * 1e6
    assert s_row == s_col == s_or
    emit("ds_agg_col_forkbase", t_col, f"vs orpheus {t_or / t_col:.1f}x")
    emit("ds_agg_orpheus", t_or)
