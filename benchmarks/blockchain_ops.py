"""Fig. 9 + 10: blockchain operation latencies (read / write / commit) and
client-perceived throughput for ForkBase-backed Hyperledger vs the
RocksDB-style baseline (KV + bucket Merkle tree + state delta) vs
ForkBase-KV (ForkBase used as a dumb KV under the same app-layer Merkle
structures — the paper's third system)."""
from __future__ import annotations

import numpy as np

from repro.apps import ForkBaseLedger, KVLedger
from repro.core import ForkBase, FString

from .common import bench, emit


class ForkBaseKV(KVLedger):
    """ForkBase as a pure KV store: app-layer Merkle tree retained, so
    hashing happens both in the app and in the storage (the paper's
    explanation for its slower commits)."""

    def __init__(self, n_buckets: int = 1024):
        super().__init__("bucket", n_buckets)
        self.fb = ForkBase()

    def commit(self) -> bytes:
        for k, v in self._writes.items():
            self.fb.put(k, FString(v))
        return super().commit()


def run():
    rng = np.random.default_rng(0)
    b = 50
    systems = {"forkbase": ForkBaseLedger(),
               "rocksdb": KVLedger("bucket", 1024),
               "forkbase_kv": ForkBaseKV(1024)}
    # seed state
    for _name, sys_ in systems.items():
        for i in range(512):
            sys_.write("kv", f"key{i}", rng.bytes(64))
        sys_.commit()
    for name, sys_ in systems.items():
        i = [0]

        def read():
            sys_.read("kv", f"key{i[0] % 512}"); i[0] += 1
        emit(f"bc_read_{name}", bench(read, 500))

        def write():
            sys_.write("kv", f"key{i[0] % 512}", rng.bytes(64)); i[0] += 1
        emit(f"bc_write_{name}", bench(write, 500))
        sys_.commit()

        def commit():
            for j in range(b):
                sys_.write("kv", f"key{(i[0] * b + j) % 512}",
                           rng.bytes(64))
            i[0] += 1
            sys_.commit()
        us = bench(commit, 20)
        emit(f"bc_commit_b{b}_{name}", us,
             f"throughput~{b * 1e6 / us:.0f}tx/s")


def run_live() -> dict:
    """``--live`` mode: ForkBaseLedger on the flat-state fast path vs
    the archival per-key path — same op mix, same seed.  Returns the
    metrics merged into BENCH_live.json by live_bench."""
    rng = np.random.default_rng(0)
    n_seed, b = 2048, 200
    out: dict = {}
    ledgers = {"arch": ForkBaseLedger(),
               "live": ForkBaseLedger(live=True)}
    for _name, led in ledgers.items():
        for i in range(n_seed):
            led.write("kv", f"key{i}", rng.bytes(64))
        led.commit()
    for name, led in ledgers.items():
        i = [0]

        def read():
            led.read("kv", f"key{i[0] % n_seed}"); i[0] += 1
        out[f"bc_{name}_read_us"] = bench(read, 2000)

        def write():
            led.write("kv", f"key{i[0] % n_seed}", rng.bytes(64))
            i[0] += 1
        out[f"bc_{name}_write_us"] = bench(write, 2000)
        led.commit()

        def commit():
            for j in range(b):
                led.write("kv", f"key{(i[0] * b + j) % n_seed}",
                          rng.bytes(64))
            i[0] += 1
            led.commit()
        us = bench(commit, 10)
        out[f"bc_{name}_commit_b{b}_us"] = us
        out[f"bc_{name}_commit_tx_s"] = b * 1e6 / us
        emit(f"bc_live_commit_b{b}_{name}", us,
             f"throughput~{b * 1e6 / us:.0f}tx/s")
    live = ledgers["live"]
    out["bc_read_speedup"] = (out["bc_arch_read_us"]
                              / out["bc_live_read_us"])
    out["bc_commit_speedup"] = (out[f"bc_arch_commit_b{b}_us"]
                                / out[f"bc_live_commit_b{b}_us"])
    st = live.db.live("__state__").stats
    out["bc_live_folds"] = st.folds
    out["bc_live_fold_ms_avg"] = st.fold_seconds / max(1, st.folds) * 1e3
    emit("bc_live_read", out["bc_live_read_us"],
         f"x{out['bc_read_speedup']:.1f} vs archival")
    return out


if __name__ == "__main__":
    import sys
    run_live() if "--live" in sys.argv else run()
