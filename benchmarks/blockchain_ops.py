"""Fig. 9 + 10: blockchain operation latencies (read / write / commit) and
client-perceived throughput for ForkBase-backed Hyperledger vs the
RocksDB-style baseline (KV + bucket Merkle tree + state delta) vs
ForkBase-KV (ForkBase used as a dumb KV under the same app-layer Merkle
structures — the paper's third system)."""
from __future__ import annotations

import numpy as np

from repro.apps import ForkBaseLedger, KVLedger
from repro.core import ForkBase, FString

from .common import bench, emit


class ForkBaseKV(KVLedger):
    """ForkBase as a pure KV store: app-layer Merkle tree retained, so
    hashing happens both in the app and in the storage (the paper's
    explanation for its slower commits)."""

    def __init__(self, n_buckets: int = 1024):
        super().__init__("bucket", n_buckets)
        self.fb = ForkBase()

    def commit(self) -> bytes:
        for k, v in self._writes.items():
            self.fb.put(k, FString(v))
        return super().commit()


def run():
    rng = np.random.default_rng(0)
    b = 50
    systems = {"forkbase": ForkBaseLedger(),
               "rocksdb": KVLedger("bucket", 1024),
               "forkbase_kv": ForkBaseKV(1024)}
    # seed state
    for name, sys_ in systems.items():
        for i in range(512):
            sys_.write("kv", f"key{i}", rng.bytes(64))
        sys_.commit()
    for name, sys_ in systems.items():
        i = [0]

        def read():
            sys_.read("kv", f"key{i[0] % 512}"); i[0] += 1
        emit(f"bc_read_{name}", bench(read, 500))

        def write():
            sys_.write("kv", f"key{i[0] % 512}", rng.bytes(64)); i[0] += 1
        emit(f"bc_write_{name}", bench(write, 500))
        sys_.commit()

        def commit():
            for j in range(b):
                sys_.write("kv", f"key{(i[0] * b + j) % 512}",
                           rng.bytes(64))
            i[0] += 1
            sys_.commit()
        us = bench(commit, 20)
        emit(f"bc_commit_b{b}_{name}", us,
             f"throughput~{b * 1e6 / us:.0f}tx/s")
