"""Beyond-paper: ForkBase as the training checkpoint substrate —
storage vs a naive full-copy checkpoint store, across (a) consecutive
steps with partially-frozen weights (common in fine-tuning), (b) an
experiment fork sharing history, (c) a crash-replay re-commit."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.ckpt import CheckpointStore
from repro.configs import ARCHS, smoke
from repro.shardings import Sharding
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.data import SyntheticLM

from .common import emit


def run():
    import jax.numpy as jnp
    sc = smoke(ARCHS["tinyllama-1.1b"])
    shd = Sharding(None, sc)
    state = init_train_state(sc, jax.random.PRNGKey(0), shards=4)
    ds = SyntheticLM(sc.vocab, 64, 4)
    step = jax.jit(make_train_step(sc, shd, AdamWConfig(warmup_steps=2)))
    ck = CheckpointStore()
    naive_bytes = 0
    t_save = 0.0
    # partially-frozen regime: only save params (servers checkpoint
    # weights far more often than optimizer state)
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, _ = step(state, b)
        t0 = time.perf_counter()
        ck.save({"params": state["params"]}, "run", step=i)
        t_save += time.perf_counter() - t0
        naive_bytes += sum(np.asarray(x).nbytes
                           for x in jax.tree.leaves(state["params"]))
    # crash replay: re-commit the same state (restart path)
    ck.save({"params": state["params"]}, "run", step=5)
    naive_bytes += sum(np.asarray(x).nbytes
                       for x in jax.tree.leaves(state["params"]))
    # fork: new branch, one diverging step
    ck.fork("run", "sweep")
    b = {k: jnp.asarray(v) for k, v in ds.batch_at(99).items()}
    s2, _ = step(state, b)
    ck.save({"params": s2["params"]}, "sweep", step=6)
    naive_bytes += sum(np.asarray(x).nbytes
                       for x in jax.tree.leaves(s2["params"]))
    st = ck.dedup_stats
    emit("ckpt_save_us", t_save / 6 * 1e6)
    emit("ckpt_forkbase_bytes", st.physical_bytes,
         f"naive={naive_bytes} -> {naive_bytes / st.physical_bytes:.2f}x "
         f"smaller; dedup_hits={st.dedup_hits}")
