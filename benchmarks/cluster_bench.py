"""Cluster runtime benchmark -> BENCH_cluster.json.

Two claims from the event-driven runtime (core.runtime), measured:

1. **Coalesced dispatch beats per-request fan-out.**  The same put
   workload runs once as N individual ``Cluster.put`` calls (each its
   own WriteBuffer flush and per-node ``put_many`` fan-out) and once
   queued through ``ClusterRuntime`` and drained in coalesced
   ``put_batch`` groups (one flush covers a whole batch).  Reported:
   µs/op for both modes, the speedup, and the routing-store
   ``put_batches`` counts that explain it.

2. **The MaintenanceDaemon stays out of the foreground's way.**  Put
   latency is sampled with no daemon and with the daemon ticking in a
   background thread (re-replication + incremental-GC cycles + audits +
   staggered folds/compactions drawing one budget, backing off under
   load).  Reported: p50/p99 for both runs and the p99 ratio — the CI
   expectation is ratio <= 1.25.

Alternating rounds (mode order flipped each round, fresh clusters per
round) keep clock drift and allocator growth symmetric, as in
obs_bench.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import Cluster, FBlob, MaintenanceDaemon, RuntimeConfig

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_cluster.json")

N_NODES = 4
VALUE_BYTES = 1 << 10
COALESCE_ROUNDS = 4        # alternating (per-request, coalesced) rounds
COALESCE_OPS = 96          # puts per round per mode
LATENCY_OPS = 4000         # put samples across both daemon modes
LATENCY_SEGMENTS = 40      # alternating (off, on) sampling segments


def _routing_put_batches(cl) -> int:
    return sum(n.servlet.store.stats.put_batches for n in cl.nodes)


def _per_request(rng) -> tuple[float, int]:
    cl = Cluster(N_NODES)
    vals = [rng.bytes(VALUE_BYTES) for _ in range(COALESCE_OPS)]
    t0 = time.perf_counter()
    for i, v in enumerate(vals):
        cl.put(f"k{i}", FBlob(v))
    dt = time.perf_counter() - t0
    return dt / COALESCE_OPS * 1e6, _routing_put_batches(cl)


def _coalesced(rng) -> tuple[float, int]:
    cl = Cluster(N_NODES)
    rt = cl.runtime(RuntimeConfig(queue_depth=4 * COALESCE_OPS))
    vals = [rng.bytes(VALUE_BYTES) for _ in range(COALESCE_OPS)]
    t0 = time.perf_counter()
    futs = [rt.submit_put(f"k{i}", FBlob(v)) for i, v in enumerate(vals)]
    rt.drain()
    for f in futs:
        f.result()
    dt = time.perf_counter() - t0
    return dt / COALESCE_OPS * 1e6, _routing_put_batches(cl)


def _put_latencies(rng) -> tuple[list[float], list[float]]:
    """Put-latency samples (µs) without and with the daemon, taken as
    strictly alternating segments on ONE cluster so scheduler and
    allocator jitter land on both modes symmetrically."""
    cl = Cluster(N_NODES)
    # give the daemon real work: garbage to collect every GC cycle
    for i in range(24):
        cl.put(f"g{i}", FBlob(rng.bytes(VALUE_BYTES)))
        cl.fork(f"g{i}", "master", "tmp")
        cl.put(f"g{i}", FBlob(rng.bytes(VALUE_BYTES)), "tmp")
        cl.remove(f"g{i}", "tmp")
    # production-shaped cadence: GC epochs advance continuously in
    # SHORT slices (tick_budget bounds each foreground pause), with
    # audit rounds / folds / compactions staggered well apart — the
    # p99 claim is about pause size, which the budget controls, not
    # about the daemon being idle
    d = MaintenanceDaemon(cl, config=RuntimeConfig(
        tick_interval_s=0.01, tick_budget=4, gc_cycle_ticks=16,
        fold_every=16, audit_every=64, compact_every=32))
    base: list[list[float]] = []
    with_d: list[list[float]] = []
    seg = LATENCY_OPS // LATENCY_SEGMENTS
    i = [0]

    def sample(sink: list[list[float]]) -> None:
        cur: list[float] = []
        for _ in range(seg):
            v = rng.bytes(VALUE_BYTES)
            t0 = time.perf_counter()
            cl.put(f"k{i[0] % 64}", FBlob(v))
            cur.append((time.perf_counter() - t0) * 1e6)
            i[0] += 1
        sink.append(cur)

    # a CPU-bound sampling loop against a 5ms GIL switch interval would
    # charge the daemon up to 5ms of scheduler stall per collision —
    # measure lock/slice pauses, not GIL quantum artifacts
    switch0 = sys.getswitchinterval()
    sys.setswitchinterval(0.0005)
    try:
        for j in range(LATENCY_SEGMENTS):
            if j % 2 == 0:
                sample(base)
            else:
                d.start()
                sample(with_d)
                d.stop()
    finally:
        sys.setswitchinterval(switch0)
        d.stop()
    return _trim_pool(base), _trim_pool(with_d)


def _trim_pool(segments: list[list[float]]) -> list[float]:
    """Pool per-segment samples, dropping the slowest 10% of segments
    (by mean) per mode: a scheduler preemption burst lands on one whole
    segment and would otherwise own the pooled p99 for that mode alone
    — the same trimmed estimator obs_bench uses, at segment grain."""
    keep = sorted(segments, key=lambda s: sum(s) / len(s))
    keep = keep[:max(1, int(len(keep) * 0.9))]
    return [x for s in keep for x in s]


def _pct(xs: list[float], q: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def run():
    rng = np.random.default_rng(29)

    per_us, co_us = [], []
    per_batches = co_batches = 0
    for r in range(COALESCE_ROUNDS):
        modes = ((_per_request, per_us), (_coalesced, co_us))
        for fn, sink in (modes if r % 2 == 0 else modes[::-1]):
            us, batches = fn(rng)
            sink.append(us)
            if fn is _per_request:
                per_batches = batches
            else:
                co_batches = batches
    per_op = sum(sorted(per_us)[:-1]) / (len(per_us) - 1)
    co_op = sum(sorted(co_us)[:-1]) / (len(co_us) - 1)

    base, with_d = _put_latencies(rng)
    ratio = _pct(with_d, 0.99) / _pct(base, 0.99)

    out = {
        "n_nodes": N_NODES,
        "coalesce_ops": COALESCE_OPS,
        "per_request_put_us": per_op,
        "coalesced_put_us": co_op,
        "coalesce_speedup": per_op / co_op,
        "per_request_put_batches": per_batches,
        "coalesced_put_batches": co_batches,
        "daemon_off_put_p50_us": _pct(base, 0.50),
        "daemon_off_put_p99_us": _pct(base, 0.99),
        "daemon_on_put_p50_us": _pct(with_d, 0.50),
        "daemon_on_put_p99_us": _pct(with_d, 0.99),
        "daemon_p99_ratio": ratio,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2)

    emit("cluster_put_per_request", per_op,
         f"{per_batches} routing put batches")
    emit("cluster_put_coalesced", co_op,
         f"x{out['coalesce_speedup']:.2f} in {co_batches} batches")
    emit("cluster_put_p99_no_daemon", out["daemon_off_put_p99_us"])
    emit("cluster_put_p99_with_daemon", out["daemon_on_put_p99_us"],
         f"ratio {ratio:.2f}")
    print(f"# wrote {BENCH_JSON}")
