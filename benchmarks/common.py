"""Shared benchmark helpers: timing + CSV emission.

Scales are reduced vs the paper's 64-node/5M-record cluster runs (this is
a single CPU container); every benchmark reports ForkBase and its
competitor on the SAME harness so the paper's *relative* claims are what
is reproduced (DESIGN.md §3).

Every ``emit()`` also lands in the shared observability registry as a
``bench_us{name=...}`` gauge, so ``obs.snapshot()`` taken after a bench
run carries the headline numbers alongside the store/GC telemetry.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro import obs

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    obs.set_gauge("bench_us", us_per_call, {"name": name})
    print(f"{name},{us_per_call:.2f},{derived}")


def stats_dict(*stats_objs, prefix: str = "") -> dict:
    """Full StoreStats field dump (merged across the given stats objects)
    with an optional key prefix — replaces the hand-picked field lists
    the benches used to maintain by hand."""
    from repro.storage import StoreStats

    merged = StoreStats()
    for st in stats_objs:
        merged.merge(st)
    return {f"{prefix}{k}": v for k, v in merged.as_dict().items()}


@contextmanager
def timer():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0


def bench(fn, n: int, *, warmup: int = 1) -> float:
    """Mean microseconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6
