"""Shared benchmark helpers: timing + CSV emission.

Scales are reduced vs the paper's 64-node/5M-record cluster runs (this is
a single CPU container); every benchmark reports ForkBase and its
competitor on the SAME harness so the paper's *relative* claims are what
is reproduced (DESIGN.md §3).
"""
from __future__ import annotations

import time
from contextlib import contextmanager

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


@contextmanager
def timer():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0


def bench(fn, n: int, *, warmup: int = 1) -> float:
    """Mean microseconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6
