"""Durable tiered storage benchmark -> merged into BENCH_storage.json.

Measures the disk path the in-memory benchmarks deliberately exclude:

  * segment-append put throughput (log-structured writes + fsync'd flush)
  * cold-read latency (pread from segment files on a fresh process,
    empty hot tier) vs hot-tier reads of the same working set
  * tier hit ratio under a skewed read workload whose hot set fits the
    memory tier while the full inventory does not
  * compaction reclaim throughput: dead bytes dropped per second when
    the GC sweep's flush feeds the segment compactor

BENCH_storage.json is written wholesale by put_breakdown, so this module
MERGES its ``durable_*`` keys into the existing file instead of
replacing it."""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core.chunk import encode_chunk
from repro.storage import SegmentBackend, open_durable

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_storage.json")

N_CHUNKS = 4096
CHUNK_SIZE = 4096
SEGMENT_BYTES = 1 << 20


def _chunks(rng, n=N_CHUNKS, size=CHUNK_SIZE):
    return [encode_chunk(3, rng.bytes(size)) for _ in range(n)]


def durable_put(root: str, raws) -> dict:
    be = SegmentBackend(os.path.join(root, "put"),
                        segment_bytes=SEGMENT_BYTES)
    mb = sum(len(r) for r in raws) / 1e6
    t0 = time.perf_counter()
    be.put_many(raws)
    be.flush()                       # fsync: the durability point
    s = time.perf_counter() - t0
    out = {"durable_put_mb_s": mb / s,
           "durable_segments": be.segment_count()}
    be.close()
    emit("durable_put_batched", s / len(raws) * 1e6,
         f"{out['durable_put_mb_s']:.0f}MB/s "
         f"{out['durable_segments']}segs")
    return out


def cold_vs_hot_read(root: str, raws) -> dict:
    path = os.path.join(root, "tier")
    t = open_durable(path, hot_bytes=256 << 20,
                     segment_bytes=SEGMENT_BYTES)
    cids = t.put_many(raws)
    t.flush()
    t.close()
    # fresh process stand-in: empty hot tier, index rebuilt from footers
    t = open_durable(path, hot_bytes=256 << 20,
                     segment_bytes=SEGMENT_BYTES)
    t0 = time.perf_counter()
    t.get_many(cids)                 # every read is a pread miss
    cold_s = time.perf_counter() - t0
    assert t.stats.tier_misses == len(cids)
    t0 = time.perf_counter()
    t.get_many(cids)                 # promoted: pure hot-tier hits
    hot_s = time.perf_counter() - t0
    mb = sum(len(r) for r in raws) / 1e6
    out = {"durable_cold_read_us": cold_s / len(cids) * 1e6,
           "durable_hot_read_us": hot_s / len(cids) * 1e6,
           "durable_cold_read_mb_s": mb / cold_s,
           "durable_promotion_speedup": cold_s / hot_s}
    t.close()
    emit("durable_cold_read", out["durable_cold_read_us"],
         f"{out['durable_cold_read_mb_s']:.0f}MB/s")
    emit("durable_hot_read", out["durable_hot_read_us"],
         f"x{out['durable_promotion_speedup']:.1f} vs cold")
    return out


def tier_hit_ratio(root: str, rng, raws) -> dict:
    """Skewed reads: 90% of gets target 10% of the keys; the hot tier
    holds ~20% of the inventory."""
    hot_bytes = (N_CHUNKS * CHUNK_SIZE) // 5
    t = open_durable(os.path.join(root, "skew"), hot_bytes=hot_bytes,
                     segment_bytes=SEGMENT_BYTES)
    cids = t.put_many(raws)
    t.flush()
    n_hot = max(1, len(cids) // 10)
    reads = 20_000
    picks = np.where(rng.random(reads) < 0.9,
                     rng.integers(0, n_hot, reads),
                     rng.integers(0, len(cids), reads))
    t0 = time.perf_counter()
    for i in picks:
        t.get(cids[int(i)])
    s = time.perf_counter() - t0
    # full field dump via StoreStats.as_dict() — headline keys stay for
    # run.py's summary, the rest rides along under durable_store_stats
    st = t.stats.as_dict()
    out = {"durable_tier_hit_rate": st["tier_hit_rate"],
           "durable_skewed_read_us": s / reads * 1e6,
           "durable_tier_demotions": st["tier_demotions"],
           "durable_tier_promotions": st["tier_promotions"],
           "durable_store_stats": st}
    t.close()
    emit("durable_skewed_read", out["durable_skewed_read_us"],
         f"hit-rate {out['durable_tier_hit_rate']:.2f}")
    return out


def compaction_reclaim(root: str, rng, raws) -> dict:
    """Delete 75% of a sealed-segment population (the GC sweep's output)
    and time the compaction its flush feeds."""
    be = SegmentBackend(os.path.join(root, "compact"),
                        segment_bytes=SEGMENT_BYTES)
    cids = be.put_many(raws)
    be.flush()
    doomed = [c for i, c in enumerate(cids) if i % 4]    # 75% dead
    be.delete_many(doomed)
    dead = be.dead_bytes()
    disk0 = be.disk_bytes()
    t0 = time.perf_counter()
    be.flush()                       # sweep flush IS the compaction feed
    s = time.perf_counter() - t0
    freed = disk0 - be.disk_bytes()
    out = {"durable_compaction_dead_bytes": dead,
           "durable_compaction_freed_bytes": freed,
           "durable_compaction_reclaim_frac": freed / max(1, dead),
           "durable_compaction_mb_s": freed / 1e6 / max(s, 1e-9),
           "durable_compactions": be.stats.compactions}
    be.close()
    emit("durable_compaction", s * 1e6,
         f"{freed / 1e6:.1f}MB freed "
         f"({out['durable_compaction_reclaim_frac']:.0%} of dead) "
         f"{out['durable_compaction_mb_s']:.0f}MB/s")
    return out


def run():
    rng = np.random.default_rng(11)
    raws = _chunks(rng)
    out = {}
    with tempfile.TemporaryDirectory(prefix="durable_bench_") as root:
        out.update(durable_put(root, raws))
        out.update(cold_vs_hot_read(root, raws))
        out.update(tier_hit_ratio(root, rng, raws))
        out.update(compaction_reclaim(root, rng, raws))
    merged = {}
    if os.path.exists(BENCH_JSON):       # put_breakdown writes wholesale;
        with open(BENCH_JSON) as f:      # we merge our keys in
            merged = json.load(f)
    merged.update(out)
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"# merged durable_* into {BENCH_JSON}")
