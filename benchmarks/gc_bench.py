"""GC & space-reclamation benchmark -> BENCH_gc.json.

Three workloads:
  * versioned blobs: N versions on two branches, drop one branch ->
    mark throughput (chunks/s over the live DAG) and sweep reclaim;
  * log compaction: same store on a log file -> on-disk size
    before/after compact_log;
  * ckpt retention: a simulated training run (small pytree, many steps),
    prune to keep_last + keep_every -> bytes reclaimed vs bytes kept.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core import FBlob, ForkBase
from repro.gc import GarbageCollector
from repro.storage import MemoryBackend

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_gc.json")


def _versioned_workload(db, rng, versions=12, size=120_000):
    data = bytearray(rng.bytes(size))
    db.put("k", FBlob(bytes(data)))
    db.fork("k", "master", "scratch")
    for i in range(versions):
        off = int(rng.integers(0, size - 256))
        data[off:off + 256] = rng.bytes(256)
        db.put("k", FBlob(bytes(data)), "scratch" if i % 2 else "master")


def run() -> None:
    rng = np.random.default_rng(0)
    out = {}

    # ---- mark + sweep over a two-branch version DAG ----
    db = ForkBase(MemoryBackend())
    _versioned_workload(db, rng)
    phys0 = db.store.stats.physical_bytes
    chunks0 = len(db.store)
    gc = GarbageCollector(db.store, branches=db.branches, pins=db.pins)
    t0 = time.perf_counter()
    live, rounds, _ = gc.mark()
    mark_s = time.perf_counter() - t0
    db.remove("k", "scratch")
    t0 = time.perf_counter()
    report = db.gc()
    collect_s = time.perf_counter() - t0
    out["store_chunks_before"] = chunks0
    out["store_chunks_after"] = len(db.store)
    out["mark_chunks_per_s"] = len(live) / max(mark_s, 1e-9)
    out["mark_rounds"] = rounds
    out["swept_chunks"] = report.swept_chunks
    out["reclaimed_bytes"] = report.reclaimed_bytes
    out["physical_bytes_before"] = phys0
    out["physical_bytes_after"] = db.store.stats.physical_bytes
    emit("gc_mark", mark_s / max(len(live), 1) * 1e6,
         f"{out['mark_chunks_per_s']:.0f} chunks/s")
    emit("gc_collect", collect_s * 1e6,
         f"swept {report.swept_chunks} ({report.reclaimed_bytes} B)")

    # ---- log compaction ----
    with tempfile.TemporaryDirectory() as tmp:
        log = os.path.join(tmp, "chunks.log")
        dbl = ForkBase(MemoryBackend(log_path=log))
        _versioned_workload(dbl, rng)
        dbl.remove("k", "scratch")
        dbl.gc()
        t0 = time.perf_counter()
        before, after = dbl.store.compact_log()
        compact_s = time.perf_counter() - t0
        out["log_bytes_before_compact"] = before
        out["log_bytes_after_compact"] = after
        emit("gc_compact_log", compact_s * 1e6,
             f"{before} -> {after} B")

    # ---- checkpoint retention across a simulated training run ----
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    state = {"w": rng.normal(size=(128, 128)).astype("float32"),
             "m": rng.normal(size=(128, 128)).astype("float32")}
    for step in range(16):
        state = {k: v + 0.01 * rng.normal(size=v.shape).astype(v.dtype)
                 for k, v in state.items()}
        cs.save(state, "run", step=step)
    ckpt_phys = cs.db.store.stats.physical_bytes
    t0 = time.perf_counter()
    kept, rep = cs.prune("run", keep_last=2, keep_every=8)
    prune_s = time.perf_counter() - t0
    out["ckpt_steps"] = 16
    out["ckpt_kept"] = len(kept)
    out["ckpt_bytes_before_prune"] = ckpt_phys
    out["ckpt_bytes_after_prune"] = cs.db.store.stats.physical_bytes
    out["ckpt_reclaimed_bytes"] = rep.reclaimed_bytes
    emit("ckpt_prune", prune_s * 1e6,
         f"16 -> {len(kept)} ckpts, {rep.reclaimed_bytes} B reclaimed")

    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2)


if __name__ == "__main__":
    run()
