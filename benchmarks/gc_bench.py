"""GC & space-reclamation benchmark -> BENCH_gc.json.

Four workloads:
  * versioned blobs: N versions on two branches, drop one branch ->
    mark throughput (chunks/s over the live DAG) and sweep reclaim;
  * incremental GC: the SAME collection run as budget-bounded slices
    under a mutating workload (a put between every slice) -> max and
    p99 pause per slice vs. the stop-the-world collect() time — the
    headline number for serving traffic during collection;
  * log compaction: same store on a log file -> on-disk size
    before/after compact_log;
  * ckpt retention: a simulated training run (small pytree, many steps),
    prune to keep_last + keep_every -> bytes reclaimed vs bytes kept.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core import FBlob, ForkBase
from repro.gc import GarbageCollector
from repro.storage import MemoryBackend

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_gc.json")


def _versioned_workload(db, rng, versions=12, size=120_000):
    data = bytearray(rng.bytes(size))
    db.put("k", FBlob(bytes(data), params=db.params))
    db.fork("k", "master", "scratch")
    for i in range(versions):
        off = int(rng.integers(0, size - 256))
        data[off:off + 256] = rng.bytes(256)
        db.put("k", FBlob(bytes(data), params=db.params),
               "scratch" if i % 2 else "master")


def run() -> None:
    rng = np.random.default_rng(0)
    out = {}

    # ---- mark + sweep over a two-branch version DAG ----
    db = ForkBase(MemoryBackend())
    _versioned_workload(db, rng)
    phys0 = db.store.stats.physical_bytes
    chunks0 = len(db.store)
    gc = GarbageCollector(db.store, branches=db.branches, pins=db.pins)
    t0 = time.perf_counter()
    live, rounds, _ = gc.mark()
    mark_s = time.perf_counter() - t0
    db.remove("k", "scratch")
    t0 = time.perf_counter()
    report = db.gc()
    collect_s = time.perf_counter() - t0
    out["store_chunks_before"] = chunks0
    out["store_chunks_after"] = len(db.store)
    out["mark_chunks_per_s"] = len(live) / max(mark_s, 1e-9)
    out["mark_rounds"] = rounds
    out["swept_chunks"] = report.swept_chunks
    out["reclaimed_bytes"] = report.reclaimed_bytes
    out["physical_bytes_before"] = phys0
    out["physical_bytes_after"] = db.store.stats.physical_bytes
    emit("gc_mark", mark_s / max(len(live), 1) * 1e6,
         f"{out['mark_chunks_per_s']:.0f} chunks/s")
    emit("gc_collect", collect_s * 1e6,
         f"swept {report.swept_chunks} ({report.reclaimed_bytes} B)")

    # ---- incremental GC: slice pauses under a mutating workload ----
    # identical store + garbage as the stop-the-world run above (same
    # seed), collected in budget-bounded slices with a committer putting
    # between every slice — the barrier is live, not idle
    from repro.core import ChunkParams
    from repro.gc import GCPhase
    budget = 32
    inc_params = ChunkParams(q=9)            # 512 B chunks: a real DAG
    rng_inc = np.random.default_rng(1)
    dbs = ForkBase(MemoryBackend(), inc_params)   # stop-the-world baseline
    _versioned_workload(dbs, np.random.default_rng(2), versions=24,
                        size=400_000)
    dbs.remove("k", "scratch")
    t0 = time.perf_counter()
    stw_report = dbs.gc()
    stw_s = time.perf_counter() - t0
    dbi = ForkBase(MemoryBackend(), inc_params)   # incremental, same load
    _versioned_workload(dbi, np.random.default_rng(2), versions=24,
                        size=400_000)
    dbi.remove("k", "scratch")
    col = dbi.incremental_gc()
    pauses = []
    mutations = 0
    while True:
        t0 = time.perf_counter()
        phase = col.step(budget)
        pauses.append(time.perf_counter() - t0)
        if phase is GCPhase.DONE:
            break
        dbi.put("mut%d" % (mutations % 4),
                FBlob(rng_inc.bytes(4_000), params=inc_params))
        mutations += 1
    assert col.report.swept_chunks == stw_report.swept_chunks
    p99 = float(np.percentile(pauses, 99))
    out["inc_budget"] = budget
    out["inc_slices"] = len(pauses)
    out["inc_mutations_during_collection"] = mutations
    out["inc_barriered_chunks"] = col.report.barriered
    out["inc_swept_chunks"] = col.report.swept_chunks
    out["stw_collect_us"] = stw_s * 1e6
    out["inc_max_pause_us"] = max(pauses) * 1e6
    out["inc_p99_pause_us"] = p99 * 1e6
    out["inc_total_us"] = sum(pauses) * 1e6
    out["inc_p99_pause_vs_stw"] = p99 / max(stw_s, 1e-9)
    emit("gc_incremental_p99_pause", p99 * 1e6,
         f"{len(pauses)} slices, p99/STW = {p99 / max(stw_s, 1e-9):.1%}")

    # ---- floating-garbage bound across consecutive epochs ----
    # keys the committer put DURING the collection above were marked
    # live by its barriers; orphaning them now makes them exactly the
    # snapshot-at-the-beginning floating garbage the next epoch counts
    for k in ("mut0", "mut1"):
        dbi.remove(k, "master")
    col2 = dbi.incremental_gc()
    while col2.step(budget) is not GCPhase.DONE:
        pass
    out["inc_floating_garbage"] = col2.report.floating_garbage
    out["inc_floating_swept"] = col2.report.swept_chunks
    assert col2.report.floating_garbage > 0
    emit("gc_floating_garbage", col2.report.floating_garbage,
         f"of {col2.report.swept_chunks} swept survived one extra epoch")

    # ---- log compaction ----
    with tempfile.TemporaryDirectory() as tmp:
        log = os.path.join(tmp, "chunks.log")
        dbl = ForkBase(MemoryBackend(log_path=log))
        _versioned_workload(dbl, rng)
        dbl.remove("k", "scratch")
        dbl.gc()
        t0 = time.perf_counter()
        before, after = dbl.store.compact_log()
        compact_s = time.perf_counter() - t0
        out["log_bytes_before_compact"] = before
        out["log_bytes_after_compact"] = after
        emit("gc_compact_log", compact_s * 1e6,
             f"{before} -> {after} B")

    # ---- checkpoint retention across a simulated training run ----
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    state = {"w": rng.normal(size=(128, 128)).astype("float32"),
             "m": rng.normal(size=(128, 128)).astype("float32")}
    for step in range(16):
        state = {k: v + 0.01 * rng.normal(size=v.shape).astype(v.dtype)
                 for k, v in state.items()}
        cs.save(state, "run", step=step)
    ckpt_phys = cs.db.store.stats.physical_bytes
    t0 = time.perf_counter()
    kept, rep = cs.prune("run", keep_last=2, keep_every=8)
    prune_s = time.perf_counter() - t0
    out["ckpt_steps"] = 16
    out["ckpt_kept"] = len(kept)
    out["ckpt_bytes_before_prune"] = ckpt_phys
    out["ckpt_bytes_after_prune"] = cs.db.store.stats.physical_bytes
    out["ckpt_reclaimed_bytes"] = rep.reclaimed_bytes
    emit("ckpt_prune", prune_s * 1e6,
         f"16 -> {len(kept)} ckpts, {rep.reclaimed_bytes} B reclaimed")

    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2)


if __name__ == "__main__":
    run()
