"""Forkless flat-state fast path -> BENCH_live.json.

Core measurement: at >= 1M keys, live-table get/put (flat dict path)
vs the per-op POS-Tree path on the same engine, plus the epoch fold —
latency of the batched Merkle commitment, its share of epoch
wall-clock, and the bit-identity of the folded root against a tree
built directly from the same content.

Also folds in the app-level live modes: ``blockchain_ops.run_live()``
(ForkBaseLedger live vs archival read/write/commit) and
``wiki_bench.run_live()`` (LiveWiki vs ForkBaseWiki vs Redis baseline),
so BENCH_live.json is the one artifact for the live/archive split.

``LIVE_BENCH_KEYS`` scales the core run (default 1_000_000).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FMap, ForkBase
from repro.live import EpochPolicy
from repro.storage import MemoryBackend

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_live.json")

KEY = b"state"


def _key(i: int) -> bytes:
    return b"k%07d" % i


def run() -> None:
    rng = np.random.default_rng(0)
    n = int(os.environ.get("LIVE_BENCH_KEYS", str(1_000_000)))
    out: dict = {"n_keys": n}
    db = ForkBase(MemoryBackend())
    t = db.live(KEY, policy=EpochPolicy(max_dirty_keys=None,
                                        max_dirty_bytes=None))
    model: dict[bytes, bytes] = {}

    # ---- seed: n flat puts, then ONE epoch fold builds the archive ----
    vals = rng.bytes(16 * n)
    t0 = time.perf_counter()
    for i in range(n):
        k = _key(i)
        v = vals[16 * i:16 * i + 16]
        t.put(k, v)
        model[k] = v
    seed_s = time.perf_counter() - t0
    rep = t.fold(context=b"seed")
    out["seed_put_ops_s"] = n / seed_s
    out["seed_fold_s"] = rep.seconds
    emit("live_seed_fold", rep.seconds * 1e6,
         f"{n} keys -> archive in one batched commit")

    # ---- flat path: random gets (cache-hot, the serving shape) ----
    n_get = min(200_000, n)
    picks = rng.integers(0, n, size=n_get)
    t0 = time.perf_counter()
    for i in picks:
        t.get(_key(int(i)))
    flat_get_s = time.perf_counter() - t0
    out["live_get_ops_s"] = n_get / flat_get_s
    emit("live_get", flat_get_s / n_get * 1e6,
         f"{out['live_get_ops_s']:.0f}ops/s")

    # ---- flat path: random puts (the epoch's dirty delta) ----
    n_put = min(100_000, n)
    picks = rng.integers(0, n, size=n_put)
    newv = rng.bytes(16 * n_put)
    t0 = time.perf_counter()
    for j, i in enumerate(picks):
        k = _key(int(i))
        v = newv[16 * j:16 * j + 16]
        t.put(k, v)
        model[k] = v
    flat_put_s = time.perf_counter() - t0
    out["live_put_ops_s"] = n_put / flat_put_s
    emit("live_put", flat_put_s / n_put * 1e6,
         f"{out['live_put_ops_s']:.0f}ops/s")

    # ---- the epoch fold: one batched splice of the dirty delta ----
    rep = t.fold(context=b"epoch1")
    epoch_s = flat_put_s + rep.seconds
    out["fold_epoch_ms"] = rep.seconds * 1e3
    out["fold_dirty_keys"] = rep.folded_keys
    out["fold_fraction_of_epoch"] = rep.seconds / epoch_s
    emit("live_fold_epoch", rep.seconds * 1e6,
         f"{rep.folded_keys} dirty keys, "
         f"{out['fold_fraction_of_epoch']:.1%} of epoch wall-clock")

    # ---- bit-identity: folded root == direct build from the model ----
    direct = FMap(model, params=db.params).commit(MemoryBackend())
    out["roots_bit_identical"] = bool(db.get(KEY).obj.data == direct)
    assert out["roots_bit_identical"], "fold diverged from direct build"

    # ---- tree path: the same ops through per-op POS-Tree commits ----
    n_tput = 12
    t0 = time.perf_counter()
    for _ in range(n_tput):
        m = db.get(KEY).map()
        m.set(_key(int(rng.integers(0, n))), rng.bytes(16))
        db.put(KEY, m)
    tree_put_s = (time.perf_counter() - t0) / n_tput
    n_tget = 3000
    m = db.get(KEY).map()
    picks = rng.integers(0, n, size=n_tget)
    t0 = time.perf_counter()
    for i in picks:
        m.get(_key(int(i)))
    tree_get_s = (time.perf_counter() - t0) / n_tget
    out["tree_get_ops_s"] = 1 / tree_get_s
    out["tree_put_ops_s"] = 1 / tree_put_s
    out["get_speedup"] = (n_get / flat_get_s) * tree_get_s
    out["put_speedup"] = (n_put / flat_put_s) * tree_put_s
    emit("tree_get", tree_get_s * 1e6,
         f"flat is x{out['get_speedup']:.0f}")
    emit("tree_put", tree_put_s * 1e6,
         f"flat is x{out['put_speedup']:.0f}")

    # ---- app-level live modes ----
    from .blockchain_ops import run_live as bc_live
    from .wiki_bench import run_live as wiki_live
    out.update(bc_live())
    out.update(wiki_live())

    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2)


if __name__ == "__main__":
    run()
