"""Fig. 11: commit latency under different Merkle structures — bucket
trees (nb = 16 / 256 / 4096), Patricia trie, and ForkBase Map objects
(which 'scale gracefully by dynamically adjusting the tree height and
bounding node sizes')."""
from __future__ import annotations

import numpy as np

from repro.apps.blockchain_kv import BucketTree, MerkleTrie
from repro.core import FMap, ForkBase

from .common import bench, emit


def run():
    rng = np.random.default_rng(0)
    n_keys = 4096
    batch = 50
    keys = [f"key{i}".encode() for i in range(n_keys)]

    for nb in [16, 256, 4096]:
        tree = BucketTree(nb)
        tree.update({k: rng.bytes(64) for k in keys})
        i = [0]

        def commit():
            tree.update({keys[(i[0] * 7 + j) % n_keys]: rng.bytes(64)
                         for j in range(batch)})
            i[0] += 1
        us = bench(commit, 20)
        emit(f"merkle_bucket_nb{nb}", us,
             f"hashed_bytes={tree.hashed_bytes}")

    trie = MerkleTrie()
    trie.update({k: rng.bytes(64) for k in keys})
    i = [0]

    def commit_trie():
        trie.update({keys[(i[0] * 7 + j) % n_keys]: rng.bytes(64)
                     for j in range(batch)})
        i[0] += 1
    emit("merkle_trie", bench(commit_trie, 20),
         f"hashed_bytes={trie.hashed_bytes}")

    db = ForkBase()
    m = FMap({k: rng.bytes(64) for k in keys})
    db.put("state", m)
    i = [0]

    def commit_fb():
        mm = db.get("state").map()
        for j in range(batch):
            mm.set(keys[(i[0] * 7 + j) % n_keys], rng.bytes(64))
        db.put("state", mm)
        i[0] += 1
    emit("merkle_forkbase_map", bench(commit_fb, 20),
         f"physical={db.store.stats.physical_bytes}")
