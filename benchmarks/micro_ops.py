"""Table 3: micro-benchmark of ForkBase operations — Put/Get for String,
Blob, Map at 1 KB / 20 KB request sizes, plus Get-Meta, Track, Fork."""
from __future__ import annotations

import numpy as np

from repro.core import FBlob, FMap, FString, ForkBase

from .common import bench, emit


def run():
    rng = np.random.default_rng(0)
    db = ForkBase()
    for size, tag in [(1024, "1KB"), (20480, "20KB")]:
        payload = rng.bytes(size)
        items = {f"k{i}".encode(): rng.bytes(max(1, size // 64))
                 for i in range(64)}

        i = [0]

        def put_string():
            db.put(f"s{tag}{i[0]}", FString(payload)); i[0] += 1
        emit(f"put_string_{tag}", bench(put_string, 200))

        def put_blob():
            db.put(f"b{tag}{i[0]}", FBlob(payload)); i[0] += 1
        emit(f"put_blob_{tag}", bench(put_blob, 200))

        def put_map():
            db.put(f"m{tag}{i[0]}", FMap(items)); i[0] += 1
        emit(f"put_map_{tag}", bench(put_map, 100))

        db.put(f"sx{tag}", FString(payload))
        db.put(f"bx{tag}", FBlob(payload))
        db.put(f"mx{tag}", FMap(items))
        emit(f"get_string_{tag}",
             bench(lambda: db.get(f"sx{tag}").string(), 500))
        emit(f"get_blob_meta_{tag}",
             bench(lambda: db.get(f"bx{tag}"), 500))
        emit(f"get_blob_full_{tag}",
             bench(lambda: db.get(f"bx{tag}").blob().read(), 300))
        emit(f"get_map_full_{tag}",
             bench(lambda: list(db.get(f"mx{tag}").map().items()), 300))

        for _ in range(20):     # history for track
            b = db.get(f"bx{tag}").blob()
            b.append(b"x")
            db.put(f"bx{tag}", b)
        emit(f"track_{tag}",
             bench(lambda: db.track(f"bx{tag}", "master", (0, 10)), 300))
        j = [0]

        def fork():
            db.fork(f"bx{tag}", "master", f"br{tag}{j[0]}"); j[0] += 1
        emit(f"fork_{tag}", bench(fork, 300))
