"""Observability overhead benchmark -> BENCH_obs.json.

Runs the SAME engine put/get workload with the metrics registry
disabled (every instrument call is a cheap no-op) and fully
instrumented (spans + histograms + events), and reports the
enabled/disabled overhead fraction per verb.  CI's obs-overhead job
fails the build when either fraction exceeds 10%: the tax for always-on
telemetry must stay in the noise.

Measurement shape matters more than repetition here: disabled and
enabled batches strictly ALTERNATE on the same engine (order flipping
every pair), so clock drift, allocator growth, and scheduler jitter
hit both modes symmetrically, and each mode's estimate is a trimmed
mean (slowest 20% of batches dropped) so one preempted batch cannot
fake a regression."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro import obs
from repro.core import FBlob, ForkBase

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_obs.json")

VALUE_BYTES = 16 << 10         # ~16KB blobs: a few chunks per commit
PUT_PAIRS, PUT_INNER = 120, 1  # alternating (dis, en) put batches
GET_PAIRS, GET_INNER = 120, 20


def _paired(fn, pairs: int, inner: int) -> dict[bool, float]:
    """Trimmed-mean µs/call per mode from strictly alternating batches."""
    fn()                                             # warm the path
    samples: dict[bool, list[float]] = {False: [], True: []}
    for j in range(pairs):
        order = (False, True) if j % 2 == 0 else (True, False)
        for enabled in order:
            (obs.enable if enabled else obs.disable)()
            t0 = time.perf_counter()
            for _ in range(inner):
                fn()
            samples[enabled].append(time.perf_counter() - t0)
    obs.enable()
    out = {}
    for mode, xs in samples.items():
        xs = sorted(xs)[:max(1, int(len(xs) * 0.8))]
        out[mode] = sum(xs) / len(xs) / inner * 1e6
    return out


def run():
    rng = np.random.default_rng(23)
    payload = rng.bytes(VALUE_BYTES)
    obs.reset()

    db = ForkBase()
    i = [0]

    def put():
        db.put(f"k{i[0]}", FBlob(payload)); i[0] += 1
    puts = _paired(put, PUT_PAIRS, PUT_INNER)
    gets = _paired(lambda: db.get("k0").blob().read(),
                   GET_PAIRS, GET_INNER)

    # the instrumented batches must actually have produced telemetry
    snap = obs.snapshot()
    hists = snap["metrics"]["histograms"]
    assert snap["enabled"], "registry should be enabled after the run"
    assert any(k.startswith("store_put_us") for k in hists), hists.keys()
    assert any(k.startswith("engine_get_us") for k in hists), hists.keys()
    assert snap["spans"], "instrumented puts should leave root spans"

    out = {
        "obs_disabled_put_us": puts[False],
        "obs_enabled_put_us": puts[True],
        "obs_put_overhead_frac": puts[True] / puts[False] - 1.0,
        "obs_disabled_get_us": gets[False],
        "obs_enabled_get_us": gets[True],
        "obs_get_overhead_frac": gets[True] / gets[False] - 1.0,
        "obs_value_bytes": VALUE_BYTES,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2)

    emit("obs_put_disabled", puts[False])
    emit("obs_put_enabled", puts[True],
         f"overhead {out['obs_put_overhead_frac']:+.1%}")
    emit("obs_get_disabled", gets[False])
    emit("obs_get_enabled", gets[True],
         f"overhead {out['obs_get_overhead_frac']:+.1%}")
    print(f"# wrote {BENCH_JSON}")
    # leave the registry in its default (enabled) state for later benches
    obs.enable()
