"""Proof subsystem benchmark -> BENCH_proof.json.

Three questions:
  * proof size: O(log n) — mean membership-proof bytes and heights for
    maps of growing cardinality;
  * prove/verify throughput: per-proof verification (every proof decodes
    its own path and hashes node-by-node) vs batched verification
    (``verify_member_many``: distinct nodes across the batch hashed with
    ONE ``content_hash_many`` dispatch and decoded once) — under the
    sha256 host hash and under the ``fphash`` dedup-path hash (one
    Pallas launch per batch on TPU; vectorized host sponge off-TPU);
  * verification accounting: StoreStats verifies/verify_failures over a
    verify-enabled store, surfaced in benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FMap, ForkBase, hashing
from repro.core.postree import POSTree
from repro.proof import prove_member, verify_member, verify_member_many
from repro.storage import MemoryBackend

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_proof.json")

N_PROOFS = 1024
MAP_N = 10_000


def _build_map(n: int, rng) -> tuple[bytes, POSTree, ForkBase]:
    db = ForkBase(MemoryBackend())
    db.put("m", FMap({b"k%07d" % i: rng.bytes(24) for i in range(n)}))
    obj = db.get("m").obj
    return obj.data, POSTree.from_root(db.store, obj.type, obj.data,
                                       db.params), db


def _proof_sizes(rng) -> list[dict]:
    out = []
    for n in (1_000, 10_000, 100_000):
        root, tree, _ = _build_map(n, rng)
        sizes = [prove_member(tree, pos=int(p)).size
                 for p in rng.integers(0, n, 24)]
        out.append({"n": n, "height": tree.height,
                    "avg_proof_bytes": sum(sizes) / len(sizes)})
        emit(f"proof_size_n{n}", out[-1]["avg_proof_bytes"],
             f"height {tree.height}")
    return out


def _throughput(rng) -> dict:
    res = {}
    for hash_name, use in [("sha256", hashing.use_sha256),
                           ("fphash", hashing.use_fphash)]:
        use()
        try:
            root, tree, _ = _build_map(MAP_N, rng)
            positions = [int(p) for p in rng.integers(0, MAP_N, N_PROOFS)]
            t0 = time.perf_counter()
            proofs = [prove_member(tree, pos=p) for p in positions]
            prove_s = time.perf_counter() - t0
            items = [(root, p) for p in proofs]
            # batched: dedup + ONE hash dispatch for the whole batch
            t0 = time.perf_counter()
            claims = verify_member_many(items)
            batched_s = time.perf_counter() - t0
            assert len(claims) == N_PROOFS
            # per-proof: every proof pays its own decode + hash batch
            t0 = time.perf_counter()
            for rc, p in items:
                verify_member(rc, p)
            per_proof_s = time.perf_counter() - t0
            res[f"prove_{hash_name}_us"] = prove_s / N_PROOFS * 1e6
            res[f"verify_per_proof_{hash_name}_us"] = \
                per_proof_s / N_PROOFS * 1e6
            res[f"verify_batched_{hash_name}_us"] = \
                batched_s / N_PROOFS * 1e6
            emit(f"proof_verify_per_proof_{hash_name}",
                 res[f"verify_per_proof_{hash_name}_us"])
            emit(f"proof_verify_batched_{hash_name}",
                 res[f"verify_batched_{hash_name}_us"],
                 f"x{per_proof_s / batched_s:.2f} vs per-proof")
        finally:
            hashing.use_sha256()
    res["batched_fphash_vs_per_proof_sha256"] = (
        res["verify_per_proof_sha256_us"]
        / res["verify_batched_fphash_us"])
    res["batched_vs_per_proof_sha256"] = (
        res["verify_per_proof_sha256_us"]
        / res["verify_batched_sha256_us"])
    return res


def _verify_accounting(rng) -> dict:
    store = MemoryBackend(verify=True)
    db = ForkBase(store, verify_get=True)
    db.put("m", FMap({b"k%05d" % i: rng.bytes(32) for i in range(2000)}))
    for _ in range(20):
        db.get("m").map().get(b"k00042")
    rep = db.audit()
    return {"store_verifies": store.stats.verifies,
            "store_verify_failures": store.stats.verify_failures,
            "audit_proofs_verified": rep.proofs_verified,
            "audit_ok": rep.ok}


def run() -> None:
    rng = np.random.default_rng(0)
    out = {"n_proofs": N_PROOFS, "map_n": MAP_N}
    out["proof_sizes"] = _proof_sizes(rng)
    out.update(_throughput(rng))
    out.update(_verify_accounting(rng))
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {BENCH_JSON}")


if __name__ == "__main__":
    run()
