"""Proof subsystem benchmark -> BENCH_proof.json.

Four questions:
  * proof size: O(log n) — mean membership-proof bytes and heights for
    maps of growing cardinality;
  * prove/verify throughput: per-proof verification (every proof decodes
    its own path and hashes node-by-node) vs batched verification
    (``verify_member_many``: distinct nodes across the batch hashed with
    ONE ``content_hash_many`` dispatch and decoded once) — under the
    sha256 host hash and under the ``fphash`` dedup-path hash (one
    Pallas launch per batch on TPU; vectorized host sponge off-TPU);
  * attest churn: delta attestations (``proof.delta``) vs full
    re-Merkle-ization after k single-head updates over n heads —
    hash-CALL counts (O(k log n) leaf/path rehashes vs O(n) rebuild)
    and wall-clock per attest;
  * verification accounting: StoreStats verifies/verify_failures over a
    verify-enabled store, surfaced in benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FMap, ForkBase, hashing
from repro.core.postree import POSTree
from repro.proof import prove_member, verify_member, verify_member_many
from repro.storage import MemoryBackend

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_proof.json")

N_PROOFS = 1024
MAP_N = 10_000


def _build_map(n: int, rng) -> tuple[bytes, POSTree, ForkBase]:
    db = ForkBase(MemoryBackend())
    db.put("m", FMap({b"k%07d" % i: rng.bytes(24) for i in range(n)}))
    obj = db.get("m").obj
    return obj.data, POSTree.from_root(db.store, obj.type, obj.data,
                                       db.params), db


def _proof_sizes(rng) -> list[dict]:
    out = []
    for n in (1_000, 10_000, 100_000):
        root, tree, _ = _build_map(n, rng)
        sizes = [prove_member(tree, pos=int(p)).size
                 for p in rng.integers(0, n, 24)]
        out.append({"n": n, "height": tree.height,
                    "avg_proof_bytes": sum(sizes) / len(sizes)})
        emit(f"proof_size_n{n}", out[-1]["avg_proof_bytes"],
             f"height {tree.height}")
    return out


def _throughput(rng) -> dict:
    res = {}
    for hash_name, use in [("sha256", hashing.use_sha256),
                           ("fphash", hashing.use_fphash)]:
        use()
        try:
            root, tree, _ = _build_map(MAP_N, rng)
            positions = [int(p) for p in rng.integers(0, MAP_N, N_PROOFS)]
            t0 = time.perf_counter()
            proofs = [prove_member(tree, pos=p) for p in positions]
            prove_s = time.perf_counter() - t0
            items = [(root, p) for p in proofs]
            # batched: dedup + ONE hash dispatch for the whole batch
            t0 = time.perf_counter()
            claims = verify_member_many(items)
            batched_s = time.perf_counter() - t0
            assert len(claims) == N_PROOFS
            # per-proof: every proof pays its own decode + hash batch
            t0 = time.perf_counter()
            for rc, p in items:
                verify_member(rc, p)
            per_proof_s = time.perf_counter() - t0
            res[f"prove_{hash_name}_us"] = prove_s / N_PROOFS * 1e6
            res[f"verify_per_proof_{hash_name}_us"] = \
                per_proof_s / N_PROOFS * 1e6
            res[f"verify_batched_{hash_name}_us"] = \
                batched_s / N_PROOFS * 1e6
            emit(f"proof_verify_per_proof_{hash_name}",
                 res[f"verify_per_proof_{hash_name}_us"])
            emit(f"proof_verify_batched_{hash_name}",
                 res[f"verify_batched_{hash_name}_us"],
                 f"x{per_proof_s / batched_s:.2f} vs per-proof")
        finally:
            hashing.use_sha256()
    res["batched_fphash_vs_per_proof_sha256"] = (
        res["verify_per_proof_sha256_us"]
        / res["verify_batched_fphash_us"])
    res["batched_vs_per_proof_sha256"] = (
        res["verify_per_proof_sha256_us"]
        / res["verify_batched_sha256_us"])
    return res


def _counting_hash():
    """Install a call-counting wrapper around the sha256 default; the
    counter sees every content_hash/content_hash_many item."""
    counter = {"calls": 0}

    def one(b):
        counter["calls"] += 1
        return hashing.sha256(b)

    def many(blobs):
        blobs = list(blobs)
        counter["calls"] += len(blobs)
        return hashing.sha256_many(blobs)

    hashing.set_default_hash(one, many)
    return counter


def _attest_churn(rng, n_heads: int = 1000, k_updates: int = 10,
                  rounds: int = 20) -> dict:
    """Delta vs full-rebuild attestation under head churn: per round,
    k single-head updates then one attest.  The full path re-hashes all
    n leaves + ~n internal nodes every time; the delta path re-hashes
    only the k touched O(log n) leaf paths."""
    from repro.core import FBlob, ForkBase
    from repro.proof.attest import attest_heads
    from repro.storage import MemoryBackend

    counter = _counting_hash()
    try:
        db = ForkBase(MemoryBackend())
        keys = [b"key%06d" % i for i in range(n_heads)]
        for i, key in enumerate(keys):
            db.put(key, FBlob(b"v%d" % i))
        att = db.attest()                     # delta tree: one full build
        delta_s = delta_calls = 0.0
        full_s = full_calls = 0.0
        version = 0
        for _ in range(rounds):
            picks = [keys[int(p)] for p in
                     rng.integers(0, n_heads, k_updates)]
            for key in picks:                 # k single-head updates
                version += 1
                db.put(key, FBlob(b"u%d" % version))
            c0 = counter["calls"]
            t0 = time.perf_counter()
            att = db.attest()
            delta_s += time.perf_counter() - t0
            delta_calls += counter["calls"] - c0
            # full rebuild of the SAME table for comparison
            c0 = counter["calls"]
            t0 = time.perf_counter()
            full = attest_heads(db.branches)
            full_s += time.perf_counter() - t0
            full_calls += counter["calls"] - c0
            assert att.root == full.root      # bit-identical commitment
        st = db._delta_attestor.stats
        out = {
            "heads": n_heads, "updates_per_round": k_updates,
            "rounds": rounds,
            "delta_attest_ms": delta_s / rounds * 1e3,
            "full_attest_ms": full_s / rounds * 1e3,
            "delta_hash_calls_per_attest": delta_calls / rounds,
            "full_hash_calls_per_attest": full_calls / rounds,
            "wallclock_speedup": full_s / max(delta_s, 1e-12),
            "hash_call_ratio": full_calls / max(delta_calls, 1e-12),
            "delta_full_rebuilds": st.full_rebuilds,
            "delta_leaf_hashes_total": st.leaf_hashes,
            "delta_node_hashes_total": st.node_hashes,
        }
    finally:
        hashing.use_sha256()
    emit("attest_churn_delta_ms", out["delta_attest_ms"],
         f"x{out['wallclock_speedup']:.1f} vs full rebuild "
         f"({out['delta_hash_calls_per_attest']:.0f} vs "
         f"{out['full_hash_calls_per_attest']:.0f} hash calls)")
    return out


def _verify_accounting(rng) -> dict:
    store = MemoryBackend(verify=True)
    db = ForkBase(store, verify_get=True)
    db.put("m", FMap({b"k%05d" % i: rng.bytes(32) for i in range(2000)}))
    for _ in range(20):
        db.get("m").map().get(b"k00042")
    rep = db.audit()
    return {"store_verifies": store.stats.verifies,
            "store_verify_failures": store.stats.verify_failures,
            "audit_proofs_verified": rep.proofs_verified,
            "audit_ok": rep.ok}


def run() -> None:
    rng = np.random.default_rng(0)
    out = {"n_proofs": N_PROOFS, "map_n": MAP_N}
    out["proof_sizes"] = _proof_sizes(rng)
    out.update(_throughput(rng))
    out["attest_churn"] = _attest_churn(rng)
    out.update(_verify_accounting(rng))
    with open(BENCH_JSON, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {BENCH_JSON}")


if __name__ == "__main__":
    run()
