"""Table 4: cost breakdown of a Put — serialization, deserialization,
cryptographic hash, rolling hash, persistence — for String and Blob at
1 KB / 20 KB.  Also reports the Pallas-kernel rolling-hash path, and the
per-chunk vs batched commit pipeline (put vs put_many, §4.6.1), emitting
BENCH_storage.json so the storage perf trajectory is tracked per PR."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FBlob, ForkBase, FString
from repro.core.chunk import encode_chunk
from repro.core.chunker import DEFAULT_PARAMS, boundary_bitmap
from repro.core.chunkstore import ChunkStore
from repro.core.fobject import FObject
from repro.core.hashing import sha256
from repro.kernels.ops import boundary_bitmap as pallas_bitmap

from .common import bench, emit

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_storage.json")


def storage_batching(n_chunks: int = 2048, chunk_size: int = 4096) -> dict:
    """Per-chunk put loop vs one put_many batch, plus the end-to-end value
    commit (POS-Tree build -> single batch) — the §4.6.1 pipeline win."""
    rng = np.random.default_rng(7)
    raws = [encode_chunk(3, rng.bytes(chunk_size)) for _ in range(n_chunks)]
    mb = n_chunks * (chunk_size + 1) / 1e6

    s1 = ChunkStore()
    t0 = time.perf_counter()
    for raw in raws:
        s1.put(raw)
    per_chunk_s = time.perf_counter() - t0

    s2 = ChunkStore()
    t0 = time.perf_counter()
    s2.put_many(raws)
    batched_s = time.perf_counter() - t0

    db = ForkBase()
    value = rng.bytes(8 << 20)
    t0 = time.perf_counter()
    db.put("v", FBlob(value))
    value_s = time.perf_counter() - t0
    st = db.store.stats

    result = {
        "chunks": n_chunks,
        "chunk_size": chunk_size,
        "per_chunk_put_us": per_chunk_s / n_chunks * 1e6,
        "batched_put_us": batched_s / n_chunks * 1e6,
        "per_chunk_put_mb_s": mb / per_chunk_s,
        "batched_put_mb_s": mb / batched_s,
        "batched_speedup": per_chunk_s / batched_s,
        "value_commit_mb_s": len(value) / 1e6 / value_s,
        "value_chunks": st.puts,
        "value_put_batches": st.put_batches,
    }
    emit("storage_put_per_chunk", result["per_chunk_put_us"],
         f"{result['per_chunk_put_mb_s']:.0f}MB/s")
    emit("storage_put_batched", result["batched_put_us"],
         f"{result['batched_put_mb_s']:.0f}MB/s "
         f"x{result['batched_speedup']:.2f}")
    emit("storage_value_commit", value_s * 1e6,
         f"{st.puts}chunks/{st.put_batches}batches "
         f"{result['value_commit_mb_s']:.0f}MB/s")
    return result


def run():
    rng = np.random.default_rng(0)
    for size, tag in [(1024, "1KB"), (20480, "20KB")]:
        payload = rng.bytes(size)
        arr = np.frombuffer(payload, dtype=np.uint8)
        obj = FObject(FString.TYPE, b"key", payload, 3,
                      (b"\x01" * 32,), b"")
        raw = obj.serialize()
        emit(f"serialize_string_{tag}", bench(lambda: obj.serialize(), 2000))
        emit(f"deserialize_string_{tag}",
             bench(lambda: FObject.deserialize(raw, b"\x00" * 32), 2000))
        emit(f"cryptohash_{tag}", bench(lambda: sha256(payload), 2000))
        emit(f"rollinghash_numpy_{tag}",
             bench(lambda: boundary_bitmap(arr, DEFAULT_PARAMS), 500))
        emit(f"rollinghash_pallas_{tag}",
             bench(lambda: pallas_bitmap(arr), 100),
             "interpret-mode on CPU; TPU path identical kernel")
        store = ChunkStore()
        chunkraw = encode_chunk(3, payload)
        n = [0]

        def persist():
            store.put(chunkraw + str(n[0]).encode()); n[0] += 1
        emit(f"persistence_{tag}", bench(persist, 1000))
    batching = storage_batching()
    with open(BENCH_JSON, "w") as f:
        json.dump(batching, f, indent=2)
    print(f"# wrote {BENCH_JSON}")
