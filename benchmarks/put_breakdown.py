"""Table 4: cost breakdown of a Put — serialization, deserialization,
cryptographic hash, rolling hash, persistence — for String and Blob at
1 KB / 20 KB.  Also reports the Pallas-kernel rolling-hash path."""
from __future__ import annotations

import numpy as np

from repro.core import FBlob, FString
from repro.core.chunk import cid_of, encode_chunk
from repro.core.chunker import DEFAULT_PARAMS, boundary_bitmap
from repro.core.chunkstore import ChunkStore
from repro.core.fobject import FObject
from repro.core.hashing import sha256
from repro.kernels.ops import boundary_bitmap as pallas_bitmap

from .common import bench, emit


def run():
    rng = np.random.default_rng(0)
    for size, tag in [(1024, "1KB"), (20480, "20KB")]:
        payload = rng.bytes(size)
        arr = np.frombuffer(payload, dtype=np.uint8)
        obj = FObject(FString.TYPE, b"key", payload, 3,
                      (b"\x01" * 32,), b"")
        raw = obj.serialize()
        emit(f"serialize_string_{tag}", bench(lambda: obj.serialize(), 2000))
        emit(f"deserialize_string_{tag}",
             bench(lambda: FObject.deserialize(raw, b"\x00" * 32), 2000))
        emit(f"cryptohash_{tag}", bench(lambda: sha256(payload), 2000))
        emit(f"rollinghash_numpy_{tag}",
             bench(lambda: boundary_bitmap(arr, DEFAULT_PARAMS), 500))
        emit(f"rollinghash_pallas_{tag}",
             bench(lambda: pallas_bitmap(arr), 100),
             "interpret-mode on CPU; TPU path identical kernel")
        store = ChunkStore()
        chunkraw = encode_chunk(3, payload)
        n = [0]

        def persist():
            store.put(chunkraw + str(n[0]).encode()); n[0] += 1
        emit(f"persistence_{tag}", bench(persist, 1000))
