"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (common.emit)."""
from __future__ import annotations

import sys
import time

MODULES = ["micro_ops", "put_breakdown", "scalability", "blockchain_ops",
           "merkle_trees", "scan_queries", "wiki_bench", "analytics_bench",
           "ckpt_dedup"]


def main() -> None:
    only = sys.argv[1:] if len(sys.argv) > 1 else MODULES
    print("name,us_per_call,derived")
    for mod in MODULES:
        if mod not in only:
            continue
        t0 = time.time()
        print(f"# --- {mod} ({time.strftime('%H:%M:%S')})", flush=True)
        m = __import__(f"benchmarks.{mod}", fromlist=["run"])
        m.run()
        print(f"# --- {mod} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
