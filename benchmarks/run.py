"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (common.emit).
``put_breakdown`` additionally emits BENCH_storage.json (per-chunk vs
batched commit throughput); the summary is echoed at the end."""
from __future__ import annotations

import json
import os
import sys
import time

MODULES = ["micro_ops", "put_breakdown", "durable_bench", "gc_bench",
           "proof_bench", "scalability", "blockchain_ops", "merkle_trees",
           "scan_queries", "wiki_bench", "analytics_bench", "ckpt_dedup",
           "live_bench", "obs_bench", "cluster_bench"]


def main() -> None:
    only = sys.argv[1:] if len(sys.argv) > 1 else MODULES
    print("name,us_per_call,derived")
    for mod in MODULES:
        if mod not in only:
            continue
        t0 = time.time()
        print(f"# --- {mod} ({time.strftime('%H:%M:%S')})", flush=True)
        m = __import__(f"benchmarks.{mod}", fromlist=["run"])
        m.run()
        print(f"# --- {mod} done in {time.time() - t0:.1f}s", flush=True)
    if "gc_bench" in only:
        from .gc_bench import BENCH_JSON as GC_JSON
        if os.path.exists(GC_JSON):
            g = json.load(open(GC_JSON))
            print(f"# gc: mark {g['mark_chunks_per_s']:.0f} chunks/s, "
                  f"swept {g['swept_chunks']} "
                  f"({g['reclaimed_bytes']} B); floating "
                  f"{g.get('inc_floating_garbage', 0)} of "
                  f"{g.get('inc_floating_swept', 0)} swept; log "
                  f"{g['log_bytes_before_compact']} -> "
                  f"{g['log_bytes_after_compact']} B; ckpt prune "
                  f"reclaimed {g['ckpt_reclaimed_bytes']} B")
    if "live_bench" in only:
        from .live_bench import BENCH_JSON as LIVE_JSON
        if os.path.exists(LIVE_JSON):
            ll = json.load(open(LIVE_JSON))
            print(f"# live: {ll['n_keys']} keys -> get x"
                  f"{ll['get_speedup']:.0f}, put x{ll['put_speedup']:.0f}"
                  f" vs tree path; fold {ll['fold_epoch_ms']:.0f}ms "
                  f"({ll['fold_fraction_of_epoch']:.1%} of epoch); "
                  f"roots identical: {ll['roots_bit_identical']}; "
                  f"ledger read x{ll['bc_read_speedup']:.1f}, wiki edit "
                  f"x{ll['wiki_edit_speedup_vs_tree']:.1f}")
    if "proof_bench" in only:
        from .proof_bench import BENCH_JSON as PROOF_JSON
        if os.path.exists(PROOF_JSON):
            p = json.load(open(PROOF_JSON))
            big = p["proof_sizes"][-1]
            print(f"# proofs: size n={big['n']} -> "
                  f"{big['avg_proof_bytes']:.0f} B (h={big['height']}); "
                  f"batched fphash verify "
                  f"{p['verify_batched_fphash_us']:.0f}us/proof vs "
                  f"per-proof sha256 "
                  f"{p['verify_per_proof_sha256_us']:.0f}us "
                  f"(x{p['batched_fphash_vs_per_proof_sha256']:.2f}); "
                  f"store verifies {p['store_verifies']} "
                  f"({p['store_verify_failures']} failures)")
    if "durable_bench" in only:
        from .durable_bench import BENCH_JSON as DUR_JSON
        if os.path.exists(DUR_JSON):
            d = json.load(open(DUR_JSON))
            if "durable_put_mb_s" in d:
                print(f"# durable: put {d['durable_put_mb_s']:.0f}MB/s "
                      f"({d['durable_segments']} segments); cold read "
                      f"{d['durable_cold_read_us']:.0f}us "
                      f"({d['durable_cold_read_mb_s']:.0f}MB/s), hot "
                      f"x{d['durable_promotion_speedup']:.1f}; skewed "
                      f"hit-rate {d['durable_tier_hit_rate']:.2f}; "
                      f"compaction freed "
                      f"{d['durable_compaction_freed_bytes'] / 1e6:.1f}MB "
                      f"({d['durable_compaction_reclaim_frac']:.0%} of "
                      f"dead) at {d['durable_compaction_mb_s']:.0f}MB/s")
    if "obs_bench" in only:
        from .obs_bench import BENCH_JSON as OBS_JSON
        if os.path.exists(OBS_JSON):
            o = json.load(open(OBS_JSON))
            print(f"# obs: put {o['obs_disabled_put_us']:.0f}us -> "
                  f"{o['obs_enabled_put_us']:.0f}us instrumented "
                  f"({o['obs_put_overhead_frac']:+.1%}); get "
                  f"{o['obs_disabled_get_us']:.0f}us -> "
                  f"{o['obs_enabled_get_us']:.0f}us "
                  f"({o['obs_get_overhead_frac']:+.1%})")
    if "cluster_bench" in only:
        from .cluster_bench import BENCH_JSON as CL_JSON
        if os.path.exists(CL_JSON):
            c = json.load(open(CL_JSON))
            print(f"# cluster: put {c['per_request_put_us']:.0f}us -> "
                  f"{c['coalesced_put_us']:.0f}us coalesced "
                  f"(x{c['coalesce_speedup']:.2f}, "
                  f"{c['per_request_put_batches']} -> "
                  f"{c['coalesced_put_batches']} routing batches); "
                  f"daemon p99 {c['daemon_off_put_p99_us']:.0f}us -> "
                  f"{c['daemon_on_put_p99_us']:.0f}us "
                  f"(x{c['daemon_p99_ratio']:.2f})")
    if "put_breakdown" in only:
        from .put_breakdown import BENCH_JSON
        if os.path.exists(BENCH_JSON):
            b = json.load(open(BENCH_JSON))
            print(f"# storage pipeline: per-chunk "
                  f"{b['per_chunk_put_mb_s']:.0f}MB/s -> batched "
                  f"{b['batched_put_mb_s']:.0f}MB/s "
                  f"(x{b['batched_speedup']:.2f}); value commit "
                  f"{b['value_chunks']} chunks in "
                  f"{b['value_put_batches']} batch(es)")


if __name__ == "__main__":
    main()
