"""Fig. 8: scalability with multiple servlets.

The paper's result: near-linear scaling because servlets do not
communicate.  This container has one core, so we measure per-request cost
as servlet count grows (routing + partitioning overhead must stay flat)
and report aggregate throughput under the paper's no-communication
scaling model: N x single-servlet rate / (1 + overhead)."""
from __future__ import annotations

import numpy as np

from repro.core import Cluster, FBlob

from .common import bench, emit


def run():
    rng = np.random.default_rng(0)
    payload = rng.bytes(1024)
    base_us = None
    for n in [1, 4, 16, 64]:
        cl = Cluster(n, "2LP")
        i = [0]

        def put():
            cl.put(f"key{i[0]}", FBlob(payload)); i[0] += 1
        us = bench(put, 200)
        if base_us is None:
            base_us = us
        agg = n * 1e6 / us
        emit(f"scal_put_{n}servlets", us,
             f"aggregate~{agg:.0f}ops/s overhead={us / base_us:.2f}x")
        j = [0]

        def get():
            cl.get(f"key{j[0] % i[0]}").blob().read(); j[0] += 1
        us_g = bench(get, 400)
        emit(f"scal_get_{n}servlets", us_g,
             f"aggregate~{n * 1e6 / us_g:.0f}ops/s")
