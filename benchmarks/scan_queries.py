"""Fig. 12: blockchain analytics — state scan (history of given keys) and
block scan (all states at a given block) on a populated chain, ForkBase vs
the delta-replay baseline (whose cost is dominated by the pre-processing
pass over all blocks)."""
from __future__ import annotations

import time

import numpy as np

from repro.apps import ForkBaseLedger, KVLedger

from .common import emit


def run():
    rng = np.random.default_rng(0)
    n_keys = 256
    n_blocks = 200
    batch = 32
    fb, kv = ForkBaseLedger(), KVLedger("bucket", 256)
    for blk in range(n_blocks):
        for sys_ in (fb, kv):
            for j in range(batch):
                sys_.write("kv", f"key{(blk * batch + j) % n_keys}",
                           f"v{blk}-{j}".encode())
            sys_.commit()

    # paper-faithful metric alongside wall time: STORAGE ACCESSES —
    # the replay baseline must touch every block's delta (pre-processing),
    # ForkBase touches only the queried keys' version chains.  In-memory
    # python dicts hide that cost; access counts don't.
    for scan_keys in [1, 16, 256]:
        g0 = fb.db.store.stats.gets
        t0 = time.perf_counter()
        for i in range(scan_keys):
            fb.state_scan("kv", f"key{i}")
        t_fb = (time.perf_counter() - t0) * 1e6
        fb_gets = fb.db.store.stats.gets - g0
        kv_touch = sum(len(b.delta) for b in kv.blocks)  # index pass
        t0 = time.perf_counter()
        idx = None
        for i in range(scan_keys):
            idx = kv.build_scan_index() if idx is None else idx  # amortizes
            kv.state_scan("kv", f"key{i}", idx)
        t_kv = (time.perf_counter() - t0) * 1e6
        emit(f"state_scan_{scan_keys}keys_forkbase", t_fb / scan_keys,
             f"accesses={fb_gets}")
        emit(f"state_scan_{scan_keys}keys_rocksdb", t_kv / scan_keys,
             f"accesses={kv_touch}+lookups "
             f"access_ratio={kv_touch / max(fb_gets, 1):.1f}x")

    for height in [10, n_blocks // 2, n_blocks - 2]:
        g0 = fb.db.store.stats.gets
        t0 = time.perf_counter()
        fb.block_scan(height)
        t_fb = (time.perf_counter() - t0) * 1e6
        fb_gets = fb.db.store.stats.gets - g0
        kv_touch = len(kv.kv) + sum(len(b.delta)
                                    for b in kv.blocks[height + 1:])
        t0 = time.perf_counter()
        kv.block_scan(height)
        t_kv = (time.perf_counter() - t0) * 1e6
        emit(f"block_scan_h{height}_forkbase", t_fb,
             f"accesses={fb_gets}")
        emit(f"block_scan_h{height}_rocksdb", t_kv,
             f"accesses={kv_touch} "
             f"access_ratio={kv_touch / max(fb_gets, 1):.1f}x")
