"""Fig. 13-15: wiki engine — edit throughput at varying in-place-update
ratios, storage consumption vs Redis, consecutive-version reads with a
client chunk cache, and storage distribution under a skewed workload for
1-layer vs 2-layer partitioning."""
from __future__ import annotations

import statistics
import time

import numpy as np

from repro.apps import ForkBaseWiki, RedisWiki
from repro.core import Cluster, FBlob

from .common import emit


def run():
    rng = np.random.default_rng(0)
    n_pages, page_size, edits = 32, 15 * 1024, 10

    for upd_ratio, tag in [(1.0, "100U"), (0.5, "50U"), (0.0, "0U")]:
        w, r = ForkBaseWiki(), RedisWiki()
        texts = {}
        for p in range(n_pages):
            t = rng.bytes(page_size)
            texts[p] = t
            w.create(f"page{p}", t)
            r.create(f"page{p}", t)
        t0 = time.perf_counter()
        for _ in range(edits):
            for p in range(n_pages):
                cur = texts[p]
                pos = int(rng.integers(0, len(cur) - 256))
                payload = rng.bytes(200)
                if rng.random() < upd_ratio:   # in-place update
                    new = cur[:pos] + payload + cur[pos + 200:]
                    w.edit(f"page{p}",
                           lambda b, q=pos, s=payload: b.replace(q, 200, s))
                else:                          # insertion
                    new = cur[:pos] + payload + cur[pos:]
                    w.edit(f"page{p}",
                           lambda b, q=pos, s=payload: b.insert(q, s))
                texts[p] = new
        us = (time.perf_counter() - t0) / (edits * n_pages) * 1e6
        emit(f"wiki_edit_{tag}_forkbase", us,
             f"throughput~{1e6 / us:.0f}ops/s")
        t0 = time.perf_counter()
        for _ in range(edits):
            for p in range(n_pages):
                r.edit(f"page{p}", texts[p])
        us_r = (time.perf_counter() - t0) / (edits * n_pages) * 1e6
        emit(f"wiki_edit_{tag}_redis", us_r)
        if upd_ratio == 0.5:
            emit("wiki_storage_forkbase_bytes", w.storage_bytes(),
                 f"vs redis {r.storage_bytes()} -> "
                 f"{r.storage_bytes() / w.storage_bytes():.2f}x smaller")

    # Fig. 14: read consecutive versions with client chunk cache
    w = ForkBaseWiki()
    r = RedisWiki()
    t = rng.bytes(page_size)
    w.create("p", t)
    r.create("p", t)
    for _ in range(16):
        pos = int(rng.integers(0, len(t) - 100))
        t = t[:pos] + rng.bytes(64) + t[pos:]
        w.edit("p", lambda b, q=pos, s=t[pos:pos + 64]: b.insert(q, s))
        r.edit("p", t)
    for k in [1, 4, 16]:
        cache: set = set()
        t0 = time.perf_counter()
        tot_f = tot_c = 0
        for back in range(k):
            _, f, ch = w.read_version("p", back, cache)
            tot_f, tot_c = tot_f + f, tot_c + ch
        us = (time.perf_counter() - t0) / k * 1e6
        emit(f"wiki_read_{k}vers_forkbase", us,
             f"cache_hit={tot_c}/{tot_c + tot_f}")
        t0 = time.perf_counter()
        for back in range(k):
            r.read_version("p", back)
        emit(f"wiki_read_{k}vers_redis",
             (time.perf_counter() - t0) / k * 1e6)

    _fig15(rng)


def _fig15(rng):
    # Fig. 15: skewed-workload storage distribution, 1LP vs 2LP
    for mode in ["1LP", "2LP"]:
        cl = Cluster(16, mode)
        zipf = rng.zipf(1.5, size=400)
        for i, z in enumerate(zipf):
            page = f"hot{int(z) % 8}"
            cl.put(page, FBlob(rng.bytes(8192)), branch=f"b{i}")
        dist = cl.storage_distribution()
        cv = statistics.pstdev(dist) / max(1, statistics.mean(dist))
        emit(f"wiki_skew_{mode}_cv", cv * 100,
             f"bytes={min(dist)}..{max(dist)}")


def run_live() -> dict:
    """``--live`` mode: LiveWiki (flat page table, per-epoch folds) vs
    ForkBaseWiki (per-edit tree commits) vs the Redis baseline — edit
    and load throughput plus fold amortization.  Returns the metrics
    merged into BENCH_live.json by live_bench."""
    from repro.apps import LiveWiki
    rng = np.random.default_rng(0)
    n_pages, page_size, epochs, edits = 256, 2048, 4, 4
    out: dict = {}
    lw, fw, rw = LiveWiki(), ForkBaseWiki(), RedisWiki()
    texts = {p: rng.bytes(page_size) for p in range(n_pages)}
    for p, t in texts.items():
        lw.create(f"page{p}", t)
        fw.create(f"page{p}", t)
        rw.create(f"page{p}", t)
    lw.fold()

    def edit_round(apply):
        t0 = time.perf_counter()
        for _ in range(edits):
            for p in range(n_pages):
                cur = texts[p]
                pos = int(rng.integers(0, len(cur) - 256))
                texts[p] = cur[:pos] + rng.bytes(200) + cur[pos + 200:]
                apply(p, pos)
        return time.perf_counter() - t0

    live_s = fold_s = 0.0
    for _ in range(epochs):
        live_s += edit_round(lambda p, pos:
                             lw.edit(f"page{p}", texts[p]))
        t0 = time.perf_counter()
        lw.fold()
        fold_s += time.perf_counter() - t0
    n_ops = epochs * edits * n_pages
    out["wiki_live_edit_ops_s"] = n_ops / live_s
    out["wiki_live_fold_ms_avg"] = fold_s / epochs * 1e3
    out["wiki_live_fold_fraction"] = fold_s / (live_s + fold_s)
    rng = np.random.default_rng(0)
    texts = {p: fw.load(f"page{p}") for p in range(n_pages)}
    tree_s = edit_round(
        lambda p, pos: fw.edit(f"page{p}",
                               lambda b, q=pos, s=texts[p][pos:pos + 200]:
                               b.replace(q, 200, s)))
    out["wiki_tree_edit_ops_s"] = n_ops / tree_s
    redis_s = edit_round(lambda p, pos: rw.edit(f"page{p}", texts[p]))
    out["wiki_redis_edit_ops_s"] = n_ops / redis_s
    out["wiki_edit_speedup_vs_tree"] = tree_s / live_s
    t0 = time.perf_counter()
    for p in range(n_pages):
        lw.load(f"page{p}")
    out["wiki_live_load_us"] = (time.perf_counter() - t0) / n_pages * 1e6
    t0 = time.perf_counter()
    for p in range(n_pages):
        fw.load(f"page{p}")
    out["wiki_tree_load_us"] = (time.perf_counter() - t0) / n_pages * 1e6
    out["wiki_load_speedup"] = (out["wiki_tree_load_us"]
                                / out["wiki_live_load_us"])
    emit("wiki_live_edit", live_s / n_ops * 1e6,
         f"x{out['wiki_edit_speedup_vs_tree']:.1f} vs tree path, fold "
         f"{out['wiki_live_fold_fraction']:.1%} of epoch")
    return out


if __name__ == "__main__":
    import sys
    run_live() if "--live" in sys.argv else run()
