"""Blockchain on ForkBase: a Hyperledger-style KV contract processing
batches of transactions, then analytics (state scan / block scan) that the
original storage design needs a full chain replay for.

Run:  PYTHONPATH=src python examples/blockchain_demo.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.apps import ForkBaseLedger, KVLedger


def main():
    rng = np.random.default_rng(7)
    fb, kv = ForkBaseLedger(), KVLedger("bucket", 256)
    n_blocks, batch, n_keys = 60, 25, 64
    print(f"committing {n_blocks} blocks x {batch} txs over {n_keys} keys")
    for blk in range(n_blocks):
        for j in range(batch):
            key = f"acct{int(rng.integers(0, n_keys)):03d}"
            val = f"balance={int(rng.integers(0, 10_000))}".encode()
            fb.write("bank", key, val)
            kv.write("bank", key, val)
        fb.commit()
        kv.commit()

    # state scan: full history of one account
    t0 = time.perf_counter()
    hist = fb.state_scan("bank", "acct007")
    t_fb = time.perf_counter() - t0
    t0 = time.perf_counter()
    hist_kv = kv.state_scan("bank", "acct007")     # pays the replay cost
    t_kv = time.perf_counter() - t0
    assert [v for _, v in hist] == hist_kv
    print(f"state scan acct007: {len(hist)} versions | "
          f"forkbase {t_fb * 1e3:.2f}ms vs replay {t_kv * 1e3:.2f}ms "
          f"({t_kv / t_fb:.0f}x)")

    # block scan: all balances at mid-chain
    t0 = time.perf_counter()
    snap = fb.block_scan(n_blocks // 2)
    t_fb = time.perf_counter() - t0
    print(f"block scan @h{n_blocks // 2}: {len(snap)} states in "
          f"{t_fb * 1e3:.1f}ms")

    # tamper evidence
    assert fb.verify_block(3)
    print("block 3 verified as ancestor of the chain head "
          "(hash-chain intact)")
    st = fb.db.store.stats
    print(f"storage: {st.physical_bytes / 1e6:.2f}MB physical, "
          f"{st.dedup_ratio:.2f}x dedup")


if __name__ == "__main__":
    main()
