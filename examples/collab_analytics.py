"""Collaborative analytics: two analysts fork a relational dataset, apply
independent transformations, merge, and run aggregations on row vs column
layouts (paper §5.3).

Run:  PYTHONPATH=src python examples/collab_analytics.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.apps import ColumnTable, OrpheusLite, RowTable
from repro.core import ForkBase


def main():
    rng = np.random.default_rng(3)
    db = ForkBase()
    n = 20_000
    recs = [[f"cust{i:08d}".encode(),
             str(int(rng.integers(18, 90))).encode(),       # age
             str(int(rng.integers(0, 100_000))).encode(),   # spend
             rng.bytes(int(rng.integers(80, 160)))]          # payload
            for i in range(n)]

    rt = RowTable(db, "purchases")
    t0 = time.perf_counter()
    v0 = rt.load({r[0]: r for r in recs})
    print(f"import {n} records: {time.perf_counter() - t0:.2f}s, "
          f"{db.store.stats.physical_bytes / 1e6:.1f}MB")

    # analyst A: data cleaning on a fork
    rt.fork("cleaning")
    rta = RowTable(db, "purchases", "cleaning")
    fixes = {recs[i][0]: [recs[i][0], b"30", recs[i][2], recs[i][3]]
             for i in range(0, n, 500)}
    t0 = time.perf_counter()
    va = rta.update(fixes)
    print(f"analyst A: {len(fixes)} fixes committed in "
          f"{(time.perf_counter() - t0) * 1e3:.1f}ms (copy-on-write)")

    # analyst B: behavioural analysis on master, untouched by A
    assert rt.get(recs[0][0])[1] != b"30"
    t0 = time.perf_counter()
    total_spend = rt.aggregate(2)
    print(f"analyst B: total spend {total_spend} in "
          f"{(time.perf_counter() - t0) * 1e3:.0f}ms (row layout)")

    # merge A's cleaning into master
    db.merge("purchases", "master", "cleaning")
    assert rt.get(recs[0][0])[1] == b"30"
    print("merged cleaning branch into master")

    # column layout: aggregation touches one column's chunks only
    ct = ColumnTable(db, "purchases_col", ["pk", "age", "spend", "payload"])
    ct.load(recs)
    t0 = time.perf_counter()
    s_col = ct.aggregate("spend")
    t_col = time.perf_counter() - t0
    ol = OrpheusLite()
    vo = ol.load(recs)
    t0 = time.perf_counter()
    s_or = ol.aggregate(vo, 2)
    t_or = time.perf_counter() - t0
    assert s_col == s_or == total_spend
    print(f"aggregate: column layout {t_col * 1e3:.0f}ms vs "
          f"orpheus-style {t_or * 1e3:.0f}ms ({t_or / t_col:.1f}x)")

    a, r, c = rt.diff(db.get("purchases", "master").uid, v0)
    print(f"version diff vs v0: {len(c)} changed rows "
          f"(found via POS-Tree cid-skip)")


if __name__ == "__main__":
    main()
