"""ForkBase quickstart: the paper's Fig. 4 flow + both fork semantics.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (FBlob, FInt, FMap, ForkBase, MergeConflict,
                        aggregate_resolver, choose_one)


def main():
    db = ForkBase()

    # --- Fig. 4: put a blob, fork, modify on the branch -----------------
    db.put("my key", FBlob(b"my value " * 400))
    db.fork("my key", "master", "new branch")
    value = db.get("my key", "new branch")
    blob = value.blob()
    blob.remove(0, 10)                  # buffered client-side
    blob.append(b" ... some more")
    db.put("my key", blob, "new branch")
    print("master :", db.get("my key").blob().read()[:20], "...")
    print("branch :", db.get("my key", "new branch").blob().read()[:20],
          "...")

    # --- versioning + tamper evidence ----------------------------------
    history = db.track("my key", "new branch")
    print(f"history: {len(history)} versions, head uid "
          f"{history[0].uid.hex()[:16]}")
    assert db.verify_lineage(history[0].uid, history[-1].uid)
    print("lineage verified: head provably derives from v0")

    # --- fork-on-conflict: concurrent writers --------------------------
    base = db.put("counter", FInt(100))
    c1 = db.get("counter", uid=base).integer()
    c1.add(5)
    u1 = db.put("counter", c1, base_uid=base)       # writer A
    c2 = db.get("counter", uid=base).integer()
    c2.add(7)
    u2 = db.put("counter", c2, base_uid=base)       # writer B (same base!)
    print("untagged heads:", [u.hex()[:8]
                              for u in db.list_untagged_branches("counter")])
    merged = db.merge("counter", u1, u2, resolver=aggregate_resolver)
    print("aggregate-merged counter:",
          db.get("counter", uid=merged).integer().value)   # 112

    # --- structured types + diff ----------------------------------------
    m = FMap({b"alice": b"42", b"bob": b"17"})
    v0 = db.put("scores", m)
    m2 = db.get("scores").map()
    m2.set(b"carol", b"99")
    m2.delete(b"bob")
    v1 = db.put("scores", m2)
    added, removed, changed = db.diff(v1, v0)
    print(f"diff: +{added} -{removed} ~{changed}")
    st = db.store.stats
    print(f"store: {st.puts} puts, {st.dedup_hits} dedup hits, "
          f"{st.dedup_ratio:.2f}x logical/physical")


if __name__ == "__main__":
    main()
