"""End-to-end driver: train a small LM for a few hundred steps with the
FULL production stack — ForkBase-backed checkpointing, injected failures
with deterministic restart, an experiment fork from a historical step, and
tamper-evident lineage.

Run:  PYTHONPATH=src python examples/train_with_forkbase_ckpt.py \
          [--steps 200] [--arch tinyllama-1.1b]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.ckpt import CheckpointStore
from repro.configs import ARCHS, smoke
from repro.core import ForkBase
from repro.runtime.controller import FailurePlan, TrainController
from repro.shardings import Sharding
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.data import SyntheticLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke(ARCHS[args.arch])
    shd = Sharding(None, cfg)
    print(f"arch={cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
          f"params~{sum(np.asarray(x).size for x in jax.tree.leaves(init_train_state(cfg, jax.random.PRNGKey(0), 4)['params'])):,}")
    state = init_train_state(cfg, jax.random.PRNGKey(0), shards=4)
    ds = SyntheticLM(cfg.vocab, args.seq, args.batch)
    step = jax.jit(make_train_step(
        cfg, shd, AdamWConfig(lr=3e-3, warmup_steps=20,
                              total_steps=args.steps)))

    ckpt = CheckpointStore(ForkBase())
    fail_at = {args.steps // 3, 2 * args.steps // 3}
    ctl = TrainController(step, state, ds, ckpt, branch="run",
                          ckpt_every=20,
                          failure_plan=FailurePlan(set(fail_at)))
    print(f"training {args.steps} steps, failures injected at {fail_at}")
    t0 = time.time()
    try:
        ctl.run(args.steps)
    except KeyboardInterrupt:
        pass
    dt = time.time() - t0
    losses = [l for _, l in ctl.metrics_log]
    print(f"done in {dt:.1f}s ({dt / max(1, len(losses)):.2f}s/step) | "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} | "
          f"restarts={ctl.restarts}")

    # experiment fork from the middle of the run (warm restart)
    mid = (args.steps // 2) // 20 * 20
    ctl.fork_experiment("lr-sweep", from_step=mid)
    forked = ckpt.restore(ctl.state, "lr-sweep")
    print(f"forked 'lr-sweep' from step {mid} "
          f"(zero-copy: POS-Tree chunks shared)")

    st = ckpt.dedup_stats
    print(f"checkpoint store: {st.logical_bytes / 1e6:.1f}MB logical -> "
          f"{st.physical_bytes / 1e6:.1f}MB physical "
          f"({st.dedup_ratio:.2f}x dedup, {st.dedup_hits} chunk hits)")
    hist = ckpt.history("run", 100)
    ok = ckpt.verify(hist[0][0], hist[-1][0])
    print(f"lineage: {len(hist)} checkpoints; head verifiably derives "
          f"from step-0 commit: {ok}")


if __name__ == "__main__":
    main()
