"""Wiki engine demo: versioned pages, chunk-dedup storage, client chunk
caching, and a two-author fork/merge flow.

Run:  PYTHONPATH=src python examples/wiki_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.apps import ForkBaseWiki, RedisWiki
from repro.core import ForkBase


def main():
    rng = np.random.default_rng(11)
    wiki, redis = ForkBaseWiki(ForkBase()), RedisWiki()
    text = rng.bytes(15 * 1024)
    wiki.create("JAX", text)
    redis.create("JAX", text)
    cur = text
    for i in range(25):
        pos = int(rng.integers(0, len(cur) - 300))
        ins = rng.bytes(120)
        cur = cur[:pos] + ins + cur[pos:]
        wiki.edit("JAX", lambda b, q=pos, s=ins: b.insert(q, s))
        redis.edit("JAX", cur)
    assert wiki.load("JAX") == redis.load("JAX")
    print(f"26 versions | forkbase {wiki.storage_bytes() / 1024:.0f} KB "
          f"vs redis {redis.storage_bytes() / 1024:.0f} KB "
          f"({redis.storage_bytes() / wiki.storage_bytes():.1f}x)")

    cache: set = set()
    for back in (0, 1, 2, 3):
        _, fetched, cached = wiki.read_version("JAX", back, cache)
        print(f"  read version -{back}: {fetched} chunks fetched, "
              f"{cached} from client cache")

    # fork/merge editing (the 'advanced collaboration' the paper targets)
    db = wiki.db
    db.fork("JAX", "master", "draft")
    d = db.get("JAX", "draft").blob()
    d.append(b"\n== Draft section ==")
    db.put("JAX", d, "draft")
    m = db.get("JAX", "master").blob()
    m.insert(0, b"== Header ==\n")
    db.put("JAX", m, "master")
    db.merge("JAX", "master", "draft")
    merged = db.get("JAX", "master").blob().read()
    assert merged.startswith(b"== Header ==") and \
        merged.endswith(b"== Draft section ==")
    print("fork + concurrent edits merged cleanly (3-way, POS-Tree diff)")
    ops = db.diff(db.get("JAX", "master").uid,
                  db.track("JAX", "master")[1].uid)
    print(f"diff vs previous version: {len(ops)} changed leaf runs")


if __name__ == "__main__":
    main()
