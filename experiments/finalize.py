"""Regenerate the EXPERIMENTS.md roofline snapshot from dry-run JSONs."""
import io
import json
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
from repro.roofline import roofline_terms  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]


def table(mesh: str) -> str:
    rows = []
    for f in sorted((ROOT / "experiments" / "dryrun").glob("*.json")):
        cell = json.loads(f.read_text())
        if cell["mesh"] != mesh or cell.get("variant", "base") != "base":
            continue
        t = roofline_terms(cell)
        rows.append((cell, t))
    out = io.StringIO()
    out.write(f"**Mesh {mesh}** — terms in seconds/step (decode: /token):\n\n")
    out.write("| arch | shape | compute_s | memory_s | coll_s | dominant |"
              " useful | roofline | peak GB |\n")
    out.write("|---|---|---|---|---|---|---|---|---|\n")
    for cell, t in rows:
        out.write(
            f"| {cell['arch']} | {cell['shape']} | {t['compute_s']:.3g} "
            f"| {t['memory_s']:.3g} | {t['collective_s']:.3g} "
            f"| **{t['dominant']}** | {t['useful_ratio']:.2f} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {cell['memory']['peak_per_device_gb']:.1f} |\n")
    return out.getvalue()


def variants_table() -> str:
    rows = []
    for f in sorted((ROOT / "experiments" / "dryrun").glob("*.json")):
        cell = json.loads(f.read_text())
        if cell.get("variant", "base") == "base":
            continue
        t = roofline_terms(cell)
        rows.append((cell, t))
    if not rows:
        return ""
    out = io.StringIO()
    out.write("\n**Hillclimb variants** (non-base, single-pod):\n\n")
    out.write("| arch | shape | variant | compute_s | memory_s | coll_s |"
              " peak GB |\n|---|---|---|---|---|---|---|\n")
    for cell, t in rows:
        out.write(f"| {cell['arch']} | {cell['shape']} "
                  f"| {cell['variant']} | {t['compute_s']:.3g} "
                  f"| {t['memory_s']:.3g} | {t['collective_s']:.3g} "
                  f"| {cell['memory']['peak_per_device_gb']:.1f} |\n")
    return out.getvalue()


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    snapshot = table("16x16") + "\n" + table("2x16x16") + variants_table()
    md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\nReading the table:)",
                "<!-- ROOFLINE_TABLE -->\n" + snapshot + "\n",
                md, flags=re.S)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md roofline snapshot updated "
          f"({snapshot.count(chr(10))} lines)")


if __name__ == "__main__":
    main()
