"""repro: ForkBase (storage engine for blockchain & forkable applications)
reproduced as the state substrate of a multi-pod JAX training/serving
framework.  See DESIGN.md for the system map."""
__version__ = "1.0.0"
