"""Repo-rule engine: AST-based concurrency & contract analysis.

Static companion to the runtime lock witness (repro.core.locking).  Rules
encode invariants the test suite cannot cheaply cover — lock-acquisition
order, blocking I/O under hot locks, typed-error discipline, monotonic-time
discipline, batched store access, guarded observability — and run in CI as
their own gate (``python -m repro.analysis src tests benchmarks``).

Suppression: ``# repro: allow(RULE[, RULE]): justification`` on the flagged
line or in the contiguous comment block immediately above it.  A bare allow
without a justification still suppresses the finding but raises META001;
an allow that never matches a finding raises META002 — so every suppression
stays load-bearing and documented.
"""
from .engine import (Allow, Finding, Rule, RULES, iter_py_files, run_paths,
                     scan_file)

__all__ = ["Allow", "Finding", "Rule", "RULES", "iter_py_files",
           "run_paths", "scan_file"]
