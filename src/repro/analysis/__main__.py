"""CLI: ``python -m repro.analysis [paths...] [--list-rules] [--json]``.

Exit 0 when every finding is suppressed (with justification), 1 otherwise
— wired into CI as its own gate next to ruff and the test tiers.
"""
from __future__ import annotations

import argparse
import json
import sys

from .engine import RULES, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based concurrency & contract rules for this repo")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, rule in sorted(RULES.items()):
            print(f"{code:12} {rule.summary}\n{'':12} fix: {rule.fixit}")
        return 0

    findings = run_paths(args.paths or ["src"])
    if args.as_json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        n = len(findings)
        print(f"{n} finding{'s' if n != 1 else ''} "
              f"in {', '.join(args.paths or ['src'])}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
