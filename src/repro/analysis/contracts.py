"""CONTRACT001 / CONTRACT002 — typed-errors-only and monotonic-time rules.

CONTRACT001: runtime invariants in the engine must surface as classes
from ``repro.errors`` (callers catch ``ReproError`` subtrees; asserts
vanish under ``python -O`` and generic ``Exception`` is uncatchable
precisely).  CONTRACT002: wall-clock ``time.time()`` steps under NTP and
breaks duration math — only exporters that serialize timestamps for
humans may use it.
"""
from __future__ import annotations

import ast

__all__ = ["check_monotonic_time", "check_typed_errors"]

_GENERIC = {"Exception", "BaseException", "AssertionError"}


def check_typed_errors(path, tree, lines):
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            findings.append((
                "CONTRACT001", node.lineno, node.col_offset,
                "assert used for a runtime invariant — it disappears "
                "under -O; raise InvariantViolation (or a more specific "
                "repro.errors class)"))
        elif isinstance(node, ast.Raise) and node.exc is not None:
            exc = node.exc
            name = None
            if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
                name = exc.func.id
            elif isinstance(exc, ast.Name):
                name = exc.id
            if name in _GENERIC:
                findings.append((
                    "CONTRACT001", node.lineno, node.col_offset,
                    f"raise {name} is untyped — raise a repro.errors "
                    f"class so callers can catch precisely"))
    return findings


def check_monotonic_time(path, tree, lines):
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr == "time"
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "time"):
                findings.append((
                    "CONTRACT002", node.lineno, node.col_offset,
                    "time.time() is wall clock — use time.monotonic() / "
                    "perf_counter() for durations and ordering"))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "time" and any(a.name == "time"
                                             for a in node.names):
                findings.append((
                    "CONTRACT002", node.lineno, node.col_offset,
                    "`from time import time` imports the wall clock — "
                    "import monotonic/perf_counter instead"))
    return findings
