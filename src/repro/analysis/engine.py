"""Rule engine core: file walking, suppression parsing, finding filtering.

Checkers (lockrules / contracts / perfrules) are pure functions
``(path, tree, lines) -> [(code, line, col, message), ...]`` — they know
nothing about suppression or scoping, which live here:

* **scope** — each rule declares a path predicate (e.g. CONTRACT001 is
  src-only and skips the ML scaffolding dirs).  Findings outside a rule's
  scope are dropped before suppression matching.
* **suppression** — ``# repro: allow(RULE[, RULE]): justification`` on the
  flagged line, or anywhere in the contiguous comment block immediately
  above it.  A used allow with no justification raises META001; an allow
  that matched nothing raises META002.  META findings are never
  suppressible, so every ``allow`` in the tree stays documented and
  load-bearing.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field

__all__ = ["Allow", "Finding", "Rule", "RULES", "iter_py_files",
           "run_paths", "scan_file"]


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        fixit = RULES[self.rule].fixit if self.rule in RULES else ""
        hint = f"  [{fixit}]" if fixit else ""
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} {self.message}{hint}")


@dataclass
class Allow:
    line: int                 # line the comment sits on
    target: int | None        # code line the allow applies to (None: dangling)
    rules: tuple[str, ...]
    justification: str
    used: bool = field(default=False)


@dataclass(frozen=True)
class Rule:
    code: str
    summary: str
    fixit: str


RULES: dict[str, Rule] = {}


def _rule(code: str, summary: str, fixit: str) -> None:
    RULES[code] = Rule(code, summary, fixit)


_rule("LOCK001", "lock acquired out of rank order",
      "acquire locks in LOCK_ORDER rank order (see repro/core/locking.py)")
_rule("LOCK002", "blocking call while holding a servlet/collector lock",
      "move fsync/sleep/join/compaction outside the lock block")
_rule("CONTRACT001", "bare assert/Exception for a runtime invariant",
      "raise a typed error from repro/errors.py")
_rule("CONTRACT002", "wall-clock time.time() outside exporters",
      "use time.monotonic()/perf_counter(); wall clock drifts and steps")
_rule("PERF001", "per-item store access inside a loop over cids",
      "batch with get_many/put_many or a WriteBuffer")
_rule("OBS001", "unguarded obs registry call on a hot path",
      "guard with `if REGISTRY.enabled:` or use the obs.* wrappers")
_rule("META001", "suppression without a justification",
      "append `: why` to the allow comment")
_rule("META002", "suppression that matches no finding",
      "delete the stale allow comment")


# --------------------------------------------------------------- scoping

# ML scaffolding kept out of the storage-engine contract rules: these
# trees follow JAX idiom (asserts on shapes, wall-clock step timers) and
# are exercised by their own test tiers.
_ML_DIRS = ("repro/models/", "repro/kernels/", "repro/train/",
            "repro/configs/", "repro/launch/", "repro/runtime/")
_ML_FILES = ("repro/roofline.py", "repro/shardings.py")


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _is_src(p: str) -> bool:
    return p.startswith("src/") or "/src/" in p


def _is_ml(p: str) -> bool:
    return any(d in p for d in _ML_DIRS) or p.endswith(_ML_FILES)


def rule_in_scope(code: str, path: str) -> bool:
    p = _norm(path)
    if code.startswith("LOCK") or code == "PERF001":
        return True
    if code == "CONTRACT001":
        return _is_src(p) and not _is_ml(p)
    if code == "CONTRACT002":
        # exporters serialize for humans/external systems: wall clock is
        # the point there
        return (_is_src(p) and not _is_ml(p)
                and not p.endswith("repro/obs/export.py"))
    if code == "OBS001":
        # the obs package itself is the guard's implementation
        return _is_src(p) and not _is_ml(p) and "repro/obs/" not in p
    return True


# ----------------------------------------------------------- suppression

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\(\s*([A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*)\s*\)"
    r"\s*(?::\s*(\S.*))?$")


def _comment_only(line: str) -> bool:
    s = line.strip()
    return s.startswith("#")


def parse_allows(lines: list[str]) -> list[Allow]:
    allows: list[Allow] = []
    n = len(lines)
    for i, raw in enumerate(lines, 1):
        m = _ALLOW_RE.search(raw)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(","))
        just = (m.group(2) or "").strip()
        if _comment_only(raw):
            # the allow governs the first code line below its contiguous
            # comment block (so multi-line justifications read naturally)
            j = i
            while j <= n and _comment_only(lines[j - 1]):
                j += 1
            target = j if j <= n and lines[j - 1].strip() else None
        else:
            target = i          # trailing comment: governs its own line
        allows.append(Allow(line=i, target=target, rules=rules,
                            justification=just))
    return allows


# ------------------------------------------------------------ file scan

def _checkers():
    from . import contracts, lockrules, perfrules
    return (lockrules.check_lock_order, lockrules.check_blocking_under_lock,
            contracts.check_typed_errors, contracts.check_monotonic_time,
            perfrules.check_n_plus_one, perfrules.check_obs_guard)


def scan_file(path: str) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding("SYNTAX", path, e.lineno or 1, 0, str(e.msg))]
    lines = src.splitlines()
    raw: list[tuple[str, int, int, str]] = []
    for checker in _checkers():
        raw.extend(checker(path, tree, lines))

    allows = parse_allows(lines)
    by_target: dict[int, list[Allow]] = {}
    for a in allows:
        if a.target is not None:
            by_target.setdefault(a.target, []).append(a)

    out: list[Finding] = []
    for code, line, col, msg in raw:
        if not rule_in_scope(code, path):
            continue
        hit = None
        for a in by_target.get(line, ()):
            if code in a.rules and not code.startswith("META"):
                hit = a
                break
        if hit is None:
            out.append(Finding(code, path, line, col, msg))
        else:
            hit.used = True
    for a in allows:
        if a.used and not a.justification:
            out.append(Finding("META001", path, a.line, 0,
                               f"allow({', '.join(a.rules)}) has no "
                               f"justification"))
        if not a.used:
            out.append(Finding("META002", path, a.line, 0,
                               f"allow({', '.join(a.rules)}) matched no "
                               f"finding — stale?"))
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def iter_py_files(paths: list[str]):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git"))
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def run_paths(paths: list[str]) -> list[Finding]:
    findings: list[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(scan_file(f))
    return findings
