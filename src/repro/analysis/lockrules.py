"""LOCK001 / LOCK002 — static lock-order and blocking-under-lock checks.

Lock identity is resolved purely by attribute name: every ranked lock in
the tree has a repo-unique attribute name registered in
``repro.core.locking.LOCK_ATTRS`` (the single source of truth — this
module imports it, never copies it).  That convention is what makes the
analysis sound without type inference; unranked leaf mutexes must be
named ``*mutex*`` (NOT ``*lock*``) and must never wrap other
acquisitions.
"""
from __future__ import annotations

import ast

from ..core.locking import LOCK_ATTRS, LOCK_ORDER

__all__ = ["check_blocking_under_lock", "check_lock_order"]


def _lock_name(expr: ast.expr) -> str | None:
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _rank_of(expr: ast.expr) -> tuple[str, int] | None:
    name = _lock_name(expr)
    if name in LOCK_ATTRS:
        rank_name = LOCK_ATTRS[name]
        return rank_name, LOCK_ORDER[rank_name]
    return None


def _is_lockish(expr: ast.expr) -> bool:
    name = _lock_name(expr)
    return name is not None and "lock" in name.lower()


# ---------------------------------------------------------------- LOCK001

def check_lock_order(path, tree, lines):
    findings = []

    def walk(node, held):
        # a nested def is a new execution context: its body does not run
        # while the enclosing with-block's locks are (necessarily) held
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            for child in ast.iter_child_nodes(node):
                walk(child, [])
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            pushed = 0
            for item in node.items:
                ce = item.context_expr
                ranked = _rank_of(ce)
                if ranked is not None:
                    rname, rank = ranked
                    for hname, hrank, _ in held:
                        if rank < hrank:
                            findings.append((
                                "LOCK001", ce.lineno, ce.col_offset,
                                f"acquires {rname} lock (rank {rank}) while "
                                f"holding {hname} lock (rank {hrank}); "
                                f"order is "
                                + " ≺ ".join(sorted(
                                    LOCK_ORDER, key=LOCK_ORDER.get))))
                            break
                    held.append((rname, rank, ce))
                    pushed += 1
                elif _is_lockish(ce) and held:
                    hname = held[-1][0]
                    findings.append((
                        "LOCK001", ce.lineno, ce.col_offset,
                        f"acquires unranked lock "
                        f"'{_lock_name(ce)}' while holding ranked "
                        f"{hname} lock — register it in LOCK_ATTRS or "
                        f"release first"))
            for child in node.body:
                walk(child, held)
            for _ in range(pushed):
                held.pop()
            return
        for child in ast.iter_child_nodes(node):
            walk(child, held)

    walk(tree, [])
    return findings


# ---------------------------------------------------------------- LOCK002

# calls that stall the calling thread on I/O or another thread
_BLOCKING_EXACT = {"fsync", "fdatasync", "sleep", "replace_durably",
                   "write_durably", "fsync_dir"}
_THREADISH = ("thread", "worker", "daemon", "proc", "pool")
# only these ranks guard latency-critical sections: a blocked servlet
# stalls its request queue; a blocked collector stalls every writer at
# the put barrier
_HOT_RANKS = ("servlet", "collector")


def _call_name(call: ast.Call) -> str | None:
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None


def _is_blocking_call(call: ast.Call) -> bool:
    name = _call_name(call)
    if name is None:
        return False
    if name in _BLOCKING_EXACT:
        return True
    if "flush" in name or "compact" in name:
        return True
    if name == "join" and isinstance(call.func, ast.Attribute):
        recv = ast.unparse(call.func.value).lower()
        return any(t in recv for t in _THREADISH)
    return False


def _self_callee(call: ast.Call) -> str | None:
    f = call.func
    if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
            and f.value.id == "self"):
        return f.attr
    return None


def _blocking_methods(cls: ast.ClassDef) -> set[str]:
    """Fixpoint over ``self.m()`` edges: a method is blocking if it makes
    a blocking call directly or via another method of the same class."""
    methods = {m.name: m for m in cls.body
               if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
    blocking: set[str] = set()
    for name, fn in methods.items():
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _is_blocking_call(node):
                blocking.add(name)
                break
    changed = True
    while changed:
        changed = False
        for name, fn in methods.items():
            if name in blocking:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    callee = _self_callee(node)
                    if callee in blocking:
                        blocking.add(name)
                        changed = True
                        break
    return blocking


def check_blocking_under_lock(path, tree, lines):
    findings = []

    def scan(node, hot_rank, blocking_methods):
        if isinstance(node, ast.ClassDef):
            bm = _blocking_methods(node)
            for child in ast.iter_child_nodes(node):
                scan(child, hot_rank, bm)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # lambdas/nested defs under a with-block run later, elsewhere
            for child in ast.iter_child_nodes(node):
                scan(child, None, blocking_methods)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = hot_rank
            for item in node.items:
                ranked = _rank_of(item.context_expr)
                if ranked is not None and ranked[0] in _HOT_RANKS:
                    inner = ranked[0]
            for child in node.body:
                scan(child, inner, blocking_methods)
            return
        if isinstance(node, ast.Call) and hot_rank is not None:
            name = _call_name(node)
            if _is_blocking_call(node):
                findings.append((
                    "LOCK002", node.lineno, node.col_offset,
                    f"blocking call {name}() inside a {hot_rank}-lock "
                    f"block"))
            else:
                callee = _self_callee(node)
                if callee in blocking_methods:
                    findings.append((
                        "LOCK002", node.lineno, node.col_offset,
                        f"self.{callee}() reaches a blocking call while "
                        f"the {hot_rank} lock is held"))
        for child in ast.iter_child_nodes(node):
            scan(child, hot_rank, blocking_methods)

    scan(tree, None, set())
    return findings
