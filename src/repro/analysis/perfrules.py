"""PERF001 / OBS001 — batched-store-access and guarded-observability rules.

PERF001: the storage API is batch-first (paper §4.4 — one round trip,
one barrier, one index probe per *batch*); per-cid ``get``/``put`` in a
loop silently multiplies every fixed cost by the batch size.  OBS001:
registry calls on hot paths must sit behind ``REGISTRY.enabled`` so the
disabled-obs configuration stays zero-cost (the PR-8 overhead gate
enforces the budget; this rule points at the offending line).
"""
from __future__ import annotations

import ast

__all__ = ["check_n_plus_one", "check_obs_guard"]

_VERBS = {"get", "put", "has", "delete"}
_BATCH_VERBS = {"get_many", "put_many", "has_many", "delete_many"}
_STOREISH = ("store", "backend")


def _receiver_text(call: ast.Call) -> str:
    if isinstance(call.func, ast.Attribute):
        return ast.unparse(call.func.value).lower()
    return ""


def check_n_plus_one(path, tree, lines):
    findings = []

    def scan(node, in_loop):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            for child in ast.iter_child_nodes(node):
                scan(child, False)
            return
        if isinstance(node, (ast.For, ast.AsyncFor)):
            for child in node.body:
                scan(child, True)
            for child in node.orelse:
                scan(child, in_loop)
            return
        if isinstance(node, ast.Call) and in_loop:
            name = (node.func.attr if isinstance(node.func, ast.Attribute)
                    else None)
            recv = _receiver_text(node)
            storeish = any(s in recv for s in _STOREISH)
            if (name in _VERBS and storeish
                    # two-positional-arg .get(k, default) is dict-style
                    and not (name == "get" and len(node.args) > 1)):
                findings.append((
                    "PERF001", node.lineno, node.col_offset,
                    f"per-item {recv}.{name}() inside a loop — batch the "
                    f"cids and make one {name}_many() call"))
            elif (name in _BATCH_VERBS and node.args
                    and isinstance(node.args[0], ast.List)
                    and len(node.args[0].elts) == 1):
                findings.append((
                    "PERF001", node.lineno, node.col_offset,
                    f"{name}() with a single-element list inside a loop "
                    f"— hoist the batch out of the loop"))
        for child in ast.iter_child_nodes(node):
            scan(child, in_loop)

    scan(tree, False)
    return findings


# ---------------------------------------------------------------- OBS001

_REG_METHODS = {"histogram", "counter", "gauge"}


def _is_registry_recv(expr: ast.expr) -> bool:
    text = ast.unparse(expr)
    return text in ("_OBS", "REGISTRY") or text.endswith(".REGISTRY")


def _test_mentions_enabled(test: ast.expr) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "enabled"
               for n in ast.walk(test))


def _guarded_by_early_return(fn, lineno: int) -> bool:
    """``if not X.enabled: return`` (or raise) above the call, at the top
    level of the enclosing function body."""
    for stmt in fn.body:
        if stmt.lineno >= lineno:
            break
        if (isinstance(stmt, ast.If) and _test_mentions_enabled(stmt.test)
                and stmt.body
                and isinstance(stmt.body[-1], (ast.Return, ast.Raise))):
            return True
    return False


def check_obs_guard(path, tree, lines):
    findings = []
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _REG_METHODS
                and _is_registry_recv(node.func.value)):
            continue
        guarded = False
        fn = None
        cur = node
        while cur in parents:
            cur = parents[cur]
            if isinstance(cur, ast.If) and _test_mentions_enabled(cur.test):
                guarded = True
                break
            if (fn is None and isinstance(cur, (ast.FunctionDef,
                                                ast.AsyncFunctionDef))):
                fn = cur
        if not guarded and fn is not None:
            guarded = _guarded_by_early_return(fn, node.lineno)
        if not guarded:
            findings.append((
                "OBS001", node.lineno, node.col_offset,
                f"REGISTRY.{node.func.attr}() not behind an "
                f"`.enabled` guard — hot paths must be free when obs "
                f"is off"))
    return findings
