from .analytics import ColumnTable, OrpheusLite, RowTable
from .blockchain import FlatStateProof, ForkBaseLedger, Tx
from .blockchain_kv import BucketTree, KVLedger, MerkleTrie
from .wiki import ForkBaseWiki, LiveWiki, RedisWiki
