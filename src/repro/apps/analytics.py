"""Collaborative analytics (paper §5.3, §6.4): versioned relational
datasets on ForkBase — row and column layouts — vs an OrpheusDB-style
version-vector baseline.

ForkBase layouts:
  * row-oriented:    Map pk -> Tuple-packed record (good for point ops);
  * column-oriented: one List per column under "<ds>/<col>" (aggregations
    touch only the queried column's chunks — Fig. 17b's 10x gap).

OrpheusDB baseline: a shared append-only record heap + one rid-vector per
dataset version (checkout materializes, commit appends new records + a
full new vector; version diff compares full vectors — Fig. 16/17a).
"""
from __future__ import annotations

import struct

from ..core import FList, FMap, FTuple, ForkBase

_I64 = struct.Struct("<q")


def pack_record(fields: list[bytes]) -> bytes:
    return FTuple(fields).encode()


def unpack_record(data: bytes) -> list[bytes]:
    return FTuple.decode(data).fields


# =============================================================== ForkBase

class RowTable:
    """Row layout: Map pk -> packed record, one ForkBase key per dataset."""

    def __init__(self, db: ForkBase, name: str, branch: str = "master"):
        self.db = db
        self.name = name
        self.branch = branch

    def load(self, records: dict[bytes, list[bytes]]) -> bytes:
        m = FMap({pk: pack_record(f) for pk, f in records.items()})
        return self.db.put(self.name, m, self.branch)

    def checkout(self) -> FMap:
        return self.db.get(self.name, self.branch).map()

    def update(self, updates: dict[bytes, list[bytes]]) -> bytes:
        m = self.checkout()            # handle only — chunks fetched lazily
        for pk, fields in updates.items():
            m.set(pk, pack_record(fields))
        return self.db.put(self.name, m, self.branch)

    def get(self, pk: bytes) -> list[bytes]:
        v = self.checkout().get(pk)
        return unpack_record(v) if v is not None else None

    def aggregate(self, field_idx: int) -> int:
        """Sum an integer field across all records (full row scan)."""
        total = 0
        for _, v in self.checkout().items():
            total += int(unpack_record(v)[field_idx])
        return total

    def diff(self, uid1: bytes, uid2: bytes):
        return self.db.diff(uid1, uid2)

    def fork(self, new_branch: str) -> None:
        self.db.fork(self.name, self.branch, new_branch)


class ColumnTable:
    """Column layout: one List per column."""

    def __init__(self, db: ForkBase, name: str, columns: list[str],
                 branch: str = "master"):
        self.db = db
        self.name = name
        self.columns = columns
        self.branch = branch

    def _key(self, col: str) -> str:
        return f"{self.name}/{col}"

    def load(self, rows: list[list[bytes]]) -> None:
        for ci, col in enumerate(self.columns):
            l = FList([r[ci] for r in rows])
            self.db.put(self._key(col), l, self.branch)

    def update_rows(self, updates: dict[int, list[bytes]]) -> None:
        for ci, col in enumerate(self.columns):
            l = self.db.get(self._key(col), self.branch).list()
            for ridx, fields in updates.items():
                l.set(ridx, fields[ci])
            self.db.put(self._key(col), l, self.branch)

    def aggregate(self, col: str) -> int:
        """Sum an integer column: touches only this column's chunks."""
        l = self.db.get(self._key(col), self.branch).list()
        return sum(int(v) for v in l)

    def fork(self, new_branch: str) -> None:
        for col in self.columns:
            self.db.fork(self._key(col), self.branch, new_branch)


# =============================================================== OrpheusDB

class OrpheusLite:
    """Version-vector dataset store in the OrpheusDB style: shared record
    heap + rid array per version."""

    def __init__(self):
        self.heap: list[bytes] = []          # append-only records
        self.versions: dict[int, list[int]] = {}
        self._next = 0
        self.storage_bytes = 0

    def load(self, records: list[list[bytes]]) -> int:
        rids = []
        for r in records:
            self.heap.append(pack_record(r))
            self.storage_bytes += len(self.heap[-1])
            rids.append(len(self.heap) - 1)
        return self._new_version(rids)

    def _new_version(self, rids: list[int]) -> int:
        vid = self._next
        self._next += 1
        self.versions[vid] = rids
        self.storage_bytes += 8 * len(rids)   # the version's rid vector
        return vid

    def checkout(self, vid: int) -> list[list[bytes]]:
        """Materialize a working copy (the paper notes this full
        reconstruction is what makes OrpheusDB checkouts slow)."""
        return [unpack_record(self.heap[r]) for r in self.versions[vid]]

    def commit(self, vid: int, updates: dict[int, list[bytes]]) -> int:
        rids = list(self.versions[vid])
        for ridx, fields in updates.items():
            self.heap.append(pack_record(fields))
            self.storage_bytes += len(self.heap[-1])
            rids[ridx] = len(self.heap) - 1
        return self._new_version(rids)

    def diff(self, v1: int, v2: int) -> list[int]:
        """Full vector comparison (paper §6.4.2)."""
        a, b = self.versions[v1], self.versions[v2]
        return [i for i, (x, y) in enumerate(zip(a, b)) if x != y]

    def aggregate(self, vid: int, field_idx: int) -> int:
        return sum(int(unpack_record(self.heap[r])[field_idx])
                   for r in self.versions[vid])
