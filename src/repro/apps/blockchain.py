"""Hyperledger-v0.6-style blockchain on ForkBase (paper §5.1, Fig. 7b).

Data model: the Merkle tree + state delta of Fig. 7(a) collapse into
ForkBase-native structures:

  * per (contract, key) the value lives in a Blob under ForkBase key
    "<contract>/<key>" — its version chain IS the state history, so
    *state scan* is just Track (no chain replay);
  * a two-level Map mirrors Fig. 7(b): level-1 Map contract -> uid of the
    level-2 Map (key -> value-Blob uid).  The level-1 Map's uid replaces
    the Merkle state hash;
  * each block is a Put on key "chain": an FMap {state root uid, txs};
    the block's ``bases`` chain is the hash-linked ledger, tamper-evident
    for free (§3.2).

*Block scan* walks the block's level-1/level-2 Maps directly.  The paper's
headline: this replaced 1918 lines of Hyperledger state-management code
with ~18 lines of ForkBase calls — the commit path below is the analogous
handful of Puts.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

from ..core import FBlob, FMap, ForkBase
from ..core.fobject import load_fobject


@dataclass
class Tx:
    contract: str
    op: str                 # 'put' | 'get'
    key: str
    value: bytes | None = None


class ForkBaseLedger:
    def __init__(self, db: ForkBase | None = None):
        self.db = db if db is not None else ForkBase()
        self.height = 0
        self._pending: list[Tx] = []
        self._writes: dict[tuple[str, str], bytes] = {}

    # ---------------------------------------------------- tx processing
    def read(self, contract: str, key: str) -> bytes | None:
        w = self._writes.get((contract, key))
        if w is not None:
            return w
        h = self.db.get(f"{contract}/{key}")
        return h.blob().read() if h is not None else None

    def write(self, contract: str, key: str, value: bytes) -> None:
        # buffered in the tx context until commit (paper Fig. 9b: a write
        # only buffers the new value)
        self._writes[(contract, key)] = value
        self._pending.append(Tx(contract, "put", key, value))

    # ----------------------------------------------------------- commit
    def commit(self) -> bytes:
        """Batch-commit buffered writes into a new block."""
        by_contract: dict[str, dict[str, bytes]] = {}
        for (c, k), v in self._writes.items():
            by_contract.setdefault(c, {})[k] = v
        # 1) value blobs — one versioned Put per state key
        l2_uids: dict[str, bytes] = {}
        for c, kv in by_contract.items():
            for k, v in kv.items():
                h = self.db.get(f"{c}/{k}")
                if h is None:
                    uid = self.db.put(f"{c}/{k}", FBlob(v))
                else:
                    b = h.blob()
                    b.replace(0, len(b), v)
                    uid = self.db.put(f"{c}/{k}", b)
            # 2) level-2 map for this contract (key -> blob uid)
            h2 = self.db.get(f"__l2__/{c}")
            m2 = h2.map() if h2 is not None else FMap()
            for k in kv:
                head = self.db.get(f"{c}/{k}")
                m2.set(k.encode(), head.uid)
            l2_uids[c] = self.db.put(f"__l2__/{c}", m2)
        # 3) level-1 map (contract -> level-2 uid)
        h1 = self.db.get("__l1__")
        m1 = h1.map() if h1 is not None else FMap()
        for c, uid in l2_uids.items():
            m1.set(c.encode(), uid)
        state_root = self.db.put("__l1__", m1)
        # 4) block
        blk = FMap({b"state": state_root,
                    b"txs": json.dumps(
                        [(t.contract, t.op, t.key) for t in self._pending]
                    ).encode()})
        block_uid = self.db.put("chain", blk,
                                context=json.dumps(
                                    {"height": self.height}).encode())
        self.height += 1
        self._pending.clear()
        self._writes.clear()
        return block_uid

    # -------------------------------------------------------- analytics
    def state_scan(self, contract: str, key: str, limit: int = 1 << 30):
        """History of one state key: follow the Blob version chain —
        no chain replay, no pre-processing (paper Fig. 12a)."""
        out = []
        for obj in self.db.track(f"{contract}/{key}", "master",
                                 (0, limit)):
            h = self.db.get(f"{contract}/{key}", uid=obj.uid)
            out.append((obj.uid, h.blob().read()))
        return out

    def block_scan(self, height: int):
        """All states at a given block: walk that block's 2-level Map."""
        blocks = self.db.track("chain", "master")
        blk = blocks[self.height - 1 - height]
        bm = self.db.get("chain", uid=blk.uid).map()
        state_root = bm.get(b"state")
        m1 = self.db.get("__l1__", uid=state_root).map()
        out = {}
        for c, l2uid in m1.items():
            m2 = self.db.get(f"__l2__/{c.decode()}", uid=l2uid).map()
            for k, buid in m2.items():
                h = self.db.get(f"{c.decode()}/{k.decode()}", uid=buid)
                out[(c.decode(), k.decode())] = h.blob().read()
        return out

    def verify_block(self, height: int) -> bool:
        """Tamper evidence: block at `height` must be an ancestor of the
        chain head."""
        blocks = self.db.track("chain", "master")
        head = blocks[0].uid
        target = blocks[self.height - 1 - height].uid
        return self.db.verify_lineage(head, target)
