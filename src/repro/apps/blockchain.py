"""Hyperledger-v0.6-style blockchain on ForkBase (paper §5.1, Fig. 7b).

Data model: the Merkle tree + state delta of Fig. 7(a) collapse into
ForkBase-native structures:

  * per (contract, key) the value lives in a Blob under ForkBase key
    "<contract>/<key>" — its version chain IS the state history, so
    *state scan* is just Track (no chain replay);
  * a two-level Map mirrors Fig. 7(b): level-1 Map contract -> uid of the
    level-2 Map (key -> value-Blob uid).  The level-1 Map's uid replaces
    the Merkle state hash;
  * each block is a Put on key "chain": an FMap {state root uid, txs};
    the block's ``bases`` chain is the hash-linked ledger, tamper-evident
    for free (§3.2).

*Block scan* walks the block's level-1/level-2 Maps directly.  The paper's
headline: this replaced 1918 lines of Hyperledger state-management code
with ~18 lines of ForkBase calls — the commit path below is the analogous
handful of Puts.

``live=True`` switches the ledger onto the forkless flat-state fast
path (repro.live): all state lives as "<contract>/<key>" -> value-bytes
entries of ONE LiveTable on key ``__state__``.  Reads and writes are
O(1) dict operations; ``commit`` folds the dirty delta into the backing
POS-Tree map with a single batched splice, and the block references the
folded root uid as its state root.  History granularity becomes
per-block instead of per-op — exactly the ledger contract, since intra-
block intermediate states were never observable anyway — and state
proofs flatten to one membership proof (``prove_state_flat``).
"""
from __future__ import annotations

import json
from dataclasses import dataclass

from ..core import FBlob, FMap, ForkBase


@dataclass
class Tx:
    contract: str
    op: str                 # 'put' | 'get'
    key: str
    value: bytes | None = None


STATE_KEY = "__state__"          # LiveTable key of the flat state (live mode)


class ForkBaseLedger:
    def __init__(self, db: ForkBase | None = None, *, live: bool = False):
        self.db = db if db is not None else ForkBase()
        self.height = 0
        self.live = live
        self._state = self.db.live(STATE_KEY) if live else None
        self._pending: list[Tx] = []
        self._writes: dict[tuple[str, str], bytes] = {}

    @staticmethod
    def _sk(contract: str, key: str) -> bytes:
        return f"{contract}/{key}".encode()

    # ---------------------------------------------------- tx processing
    def read(self, contract: str, key: str) -> bytes | None:
        w = self._writes.get((contract, key))
        if w is not None:
            return w
        if self.live:
            return self._state.get(self._sk(contract, key))
        h = self.db.get(f"{contract}/{key}")
        return h.blob().read() if h is not None else None

    def write(self, contract: str, key: str, value: bytes) -> None:
        # buffered in the tx context until commit (paper Fig. 9b: a write
        # only buffers the new value)
        self._writes[(contract, key)] = value
        self._pending.append(Tx(contract, "put", key, value))

    # ----------------------------------------------------------- commit
    def commit(self) -> bytes:
        """Batch-commit buffered writes into a new block."""
        if self.live:
            return self._commit_live()
        by_contract: dict[str, dict[str, bytes]] = {}
        for (c, k), v in self._writes.items():
            by_contract.setdefault(c, {})[k] = v
        # 1) value blobs — one versioned Put per state key
        l2_uids: dict[str, bytes] = {}
        for c, kv in by_contract.items():
            for k, v in kv.items():
                h = self.db.get(f"{c}/{k}")
                if h is None:
                    uid = self.db.put(f"{c}/{k}", FBlob(v))
                else:
                    b = h.blob()
                    b.replace(0, len(b), v)
                    uid = self.db.put(f"{c}/{k}", b)
            # 2) level-2 map for this contract (key -> blob uid)
            h2 = self.db.get(f"__l2__/{c}")
            m2 = h2.map() if h2 is not None else FMap()
            for k in kv:
                head = self.db.get(f"{c}/{k}")
                m2.set(k.encode(), head.uid)
            l2_uids[c] = self.db.put(f"__l2__/{c}", m2)
        # 3) level-1 map (contract -> level-2 uid)
        h1 = self.db.get("__l1__")
        m1 = h1.map() if h1 is not None else FMap()
        for c, uid in l2_uids.items():
            m1.set(c.encode(), uid)
        state_root = self.db.put("__l1__", m1)
        # 4) block
        blk = FMap({b"state": state_root,
                    b"txs": json.dumps(
                        [(t.contract, t.op, t.key) for t in self._pending]
                    ).encode()})
        block_uid = self.db.put("chain", blk,
                                context=json.dumps(
                                    {"height": self.height}).encode())
        self.height += 1
        self._pending.clear()
        self._writes.clear()
        return block_uid

    def _commit_live(self) -> bytes:
        """Live-mode commit: buffered writes land in the flat table
        (O(1) each), ONE epoch fold batch-splices the delta into the
        ``__state__`` POS-Tree map, and the block binds the folded root
        uid — the flat-path replacement for steps 1-3 above."""
        for (c, k), v in self._writes.items():
            self._state.put(self._sk(c, k), v)
        rep = self._state.fold(
            context=json.dumps({"height": self.height}).encode())
        blk = FMap({b"state": rep.uid,
                    b"txs": json.dumps(
                        [(t.contract, t.op, t.key) for t in self._pending]
                    ).encode()})
        block_uid = self.db.put("chain", blk,
                                context=json.dumps(
                                    {"height": self.height}).encode())
        self.height += 1
        self._pending.clear()
        self._writes.clear()
        return block_uid

    # -------------------------------------------------------- analytics
    def state_scan(self, contract: str, key: str, limit: int = 1 << 30):
        """History of one state key: follow the Blob version chain —
        no chain replay, no pre-processing (paper Fig. 12a).  In live
        mode the chain is the per-epoch version chain of the flat state
        map (one entry per block that changed the key)."""
        out = []
        if self.live:
            sk = self._sk(contract, key)
            prev = object()
            for obj in self.db.track(STATE_KEY, "master", (0, limit)):
                v = self.db.get(STATE_KEY, uid=obj.uid).map().get(sk)
                if v is not None and v != prev:
                    out.append((obj.uid, bytes(v)))
                    prev = bytes(v)
            return out
        for obj in self.db.track(f"{contract}/{key}", "master",
                                 (0, limit)):
            h = self.db.get(f"{contract}/{key}", uid=obj.uid)
            out.append((obj.uid, h.blob().read()))
        return out

    def block_scan(self, height: int):
        """All states at a given block: walk that block's 2-level Map
        (archive mode) or its flat state map (live mode)."""
        blocks = self.db.track("chain", "master")
        blk = blocks[self.height - 1 - height]
        bm = self.db.get("chain", uid=blk.uid).map()
        state_root = bm.get(b"state")
        out = {}
        if self.live:
            m = self.db.get(STATE_KEY, uid=state_root).map()
            for sk, v in m.items():
                c, _, k = sk.decode().partition("/")
                out[(c, k)] = bytes(v)
            return out
        m1 = self.db.get("__l1__", uid=state_root).map()
        for c, l2uid in m1.items():
            m2 = self.db.get(f"__l2__/{c.decode()}", uid=l2uid).map()
            for k, buid in m2.items():
                h = self.db.get(f"{c.decode()}/{k.decode()}", uid=buid)
                out[(c.decode(), k.decode())] = h.blob().read()
        return out

    def verify_block(self, height: int) -> bool:
        """Tamper evidence: block at `height` must be an ancestor of the
        chain head."""
        blocks = self.db.track("chain", "master")
        head = blocks[0].uid
        target = blocks[self.height - 1 - height].uid
        return self.db.verify_lineage(head, target)

    # ------------------------------------------------- light-client proofs
    def attest(self, secret: bytes | None = None):
        """Delta head attestation over the ledger engine (HMAC-signed
        with ``secret``): committing a block re-hashes only the touched
        heads' O(log n) paths, so attest-per-block is cheap.  A light
        client refreshes its trust anchor from (attestation,
        ``prove_chain_head()``) instead of an out-of-band head uid."""
        return self.db.attest(context=b"ledger", secret=secret)

    def prove_chain_head(self):
        """Audit path binding the chain head to ``attest()``'s root."""
        return self.db.prove_head("chain")

    def block_uid(self, height: int) -> bytes:
        return self.db.track("chain", "master")[self.height - 1 - height].uid

    def prove_block(self, height: int):
        """Lineage proof chain-head -> block (proof subsystem): a light
        client holding only the head uid authenticates the block and its
        distance from the head."""
        return self.db.prove_lineage(self.db.get("chain").uid,
                                     self.block_uid(height))

    def prove_state(self, contract: str, key: str,
                    height: int | None = None) -> "StateProof":
        """Full stateless state proof for one (contract, key) at a block:
        chain-head lineage -> block meta -> Fig. 7(b)'s two-level Map by
        membership proofs -> the value Blob, one leaf proof per chunk.
        Everything an untrusting client needs; no store handle anywhere."""
        from ..core.postree import POSTree
        from ..proof.membership import prove_member
        height = self.height - 1 if height is None else height
        db = self.db
        block_uid = self.block_uid(height)
        lineage = db.prove_lineage(db.get("chain").uid, block_uid)
        block_raw = db.prove_version(block_uid)
        state_entry = db.prove_member("chain", uid=block_uid,
                                      item_key=b"state")
        l1_uid = bytes(db.get("chain", uid=block_uid).map().get(b"state"))
        l1_raw = db.prove_version(l1_uid)
        l1_entry = db.prove_member("__l1__", uid=l1_uid,
                                   item_key=contract.encode())
        l2_uid = bytes(db.get("__l1__", uid=l1_uid).map()
                       .get(contract.encode()))
        l2_raw = db.prove_version(l2_uid)
        l2_entry = db.prove_member(f"__l2__/{contract}", uid=l2_uid,
                                   item_key=key.encode())
        blob_uid = bytes(db.get(f"__l2__/{contract}", uid=l2_uid).map()
                         .get(key.encode()))
        blob_obj = db.get(f"{contract}/{key}", uid=blob_uid).obj
        value_raw = db.prove_version(blob_uid)
        tree = POSTree.from_root(db.store, blob_obj.type, blob_obj.data,
                                 db.params)
        value = tree.read_bytes(0, tree.total_count)
        # one membership proof per leaf: their payloads tile the value
        starts, s = [], 0
        for e in tree.levels[0]:
            starts.append(s)
            s += e.count
        value_proofs = tuple(prove_member(tree, pos=p).to_bytes()
                             for p in starts) if value else ()
        return StateProof(lineage.to_bytes(), block_raw,
                          state_entry.to_bytes(), l1_raw,
                          l1_entry.to_bytes(), l2_raw,
                          l2_entry.to_bytes(), value_raw, value,
                          value_proofs)

    def prove_state_flat(self, contract: str, key: str,
                         height: int | None = None) -> "FlatStateProof":
        """Live-mode stateless state proof: the two-level Map of
        ``prove_state`` collapses to ONE membership proof into the flat
        ``__state__`` map, whose leaf carries the value bytes directly —
        chain-head lineage -> block meta -> state-root entry -> kv
        entry.  Strictly smaller than the archival StateProof."""
        if not self.live:
            raise ValueError("prove_state_flat requires live mode")
        height = self.height - 1 if height is None else height
        db = self.db
        block_uid = self.block_uid(height)
        lineage = db.prove_lineage(db.get("chain").uid, block_uid)
        block_raw = db.prove_version(block_uid)
        state_entry = db.prove_member("chain", uid=block_uid,
                                      item_key=b"state")
        state_uid = bytes(db.get("chain", uid=block_uid).map()
                          .get(b"state"))
        state_raw = db.prove_version(state_uid)
        kv_entry = db.prove_member(STATE_KEY, uid=state_uid,
                                   item_key=self._sk(contract, key))
        return FlatStateProof(lineage.to_bytes(), block_raw,
                              state_entry.to_bytes(), state_raw,
                              kv_entry.to_bytes())


@dataclass(frozen=True)
class StateProof:
    """Server-emitted bundle for LightClient.verify_state.  Each layer is
    an independent stateless proof; the client threads the trust anchor
    through them: head uid -> block -> state root -> contract map ->
    value blob -> value bytes."""
    lineage: bytes            # head -> block meta-chunk chain
    block_raw: bytes          # block version record
    state_entry: bytes        # b"state" in the block Map
    l1_raw: bytes             # level-1 Map version record
    l1_entry: bytes           # contract -> level-2 uid
    l2_raw: bytes             # level-2 Map version record
    l2_entry: bytes           # key -> value-blob uid
    value_raw: bytes          # value Blob version record
    value: bytes              # the claimed state bytes
    value_proofs: tuple[bytes, ...]   # one leaf proof per value chunk

    @property
    def size(self) -> int:
        return (len(self.lineage) + len(self.block_raw)
                + len(self.state_entry) + len(self.l1_raw)
                + len(self.l1_entry) + len(self.l2_raw)
                + len(self.l2_entry) + len(self.value_raw)
                + len(self.value) + sum(map(len, self.value_proofs)))


@dataclass(frozen=True)
class FlatStateProof:
    """Live-mode counterpart of StateProof: head uid -> block -> flat
    state-map root -> (key, value) leaf entry, value bytes inline."""
    lineage: bytes            # head -> block meta-chunk chain
    block_raw: bytes          # block version record
    state_entry: bytes        # b"state" in the block Map
    state_raw: bytes          # flat __state__ map version record
    kv_entry: bytes           # "<contract>/<key>" -> value bytes

    @property
    def size(self) -> int:
        return (len(self.lineage) + len(self.block_raw)
                + len(self.state_entry) + len(self.state_raw)
                + len(self.kv_entry))


class LightClient:
    """Holds ONLY the trusted chain-head uid — no ledger, no store.
    The paper's tamper-evidence story (§3.2) made operational: a replica
    cannot present a spliced history, a substituted block, or a forged
    state value without breaking one of the hash chains checked here."""

    def __init__(self, head_uid: bytes):
        self.head_uid = bytes(head_uid)
        self.attested_epoch: int | None = None   # GC epoch of the anchor

    def refresh_head(self, attestation, head_proof,
                     secret: bytes | None = None) -> bytes:
        """Adopt a new trust anchor from a (signed) delta attestation +
        head proof: the attested chain head becomes ``head_uid`` only if
        the proof closes against the attestation root (and the HMAC
        checks out when ``secret`` is given).  Records the attestation's
        GC epoch: the epoch-fence handshake guarantees proofs against
        this anchor stay servable until the second collection after the
        attested epoch begins, so a client comparing epochs knows when
        it must refresh."""
        from ..proof import InvalidProof, verify_head
        from ..proof.delta import attestation_epoch
        from ..proof.attest import verify_attestation
        key, tag, uid = verify_head(attestation, head_proof, secret=secret)
        if key != b"chain" or tag != "master":
            raise InvalidProof("attested head is not the chain head")
        self.head_uid = bytes(uid)
        self.attested_epoch = attestation_epoch(
            verify_attestation(attestation))
        return self.head_uid

    def verify_block(self, lineage_proof, block_uid: bytes) -> int:
        """Authenticates ``block_uid`` as an ancestor of the trusted
        head; returns its distance from the head."""
        from ..proof import verify_lineage
        return len(verify_lineage(self.head_uid, block_uid,
                                  lineage_proof)) - 1

    def verify_state(self, proof: StateProof,
                     contract: str, key: str) -> tuple[int, bytes]:
        """Returns (block distance from head, authenticated value bytes);
        raises proof.InvalidProof on any forged layer."""
        from ..core import chunk as ck
        from ..core.hashing import content_hash_many
        from ..proof import (InvalidProof, LineageProof, MembershipProof,
                             verify_lineage, verify_member,
                             verify_version)
        lp = LineageProof.from_bytes(proof.lineage)
        if not lp.raws:
            raise InvalidProof("empty lineage")
        # the chain from the trusted head authenticates its own tail
        block_uid = content_hash_many([lp.raws[-1]])[0]
        chain = verify_lineage(self.head_uid, block_uid, lp)
        block = verify_version(block_uid, proof.block_raw)
        claim = verify_member(block.data, proof.state_entry)
        if claim.key != b"state":
            raise InvalidProof("state-root entry proves the wrong key")
        l1 = verify_version(claim.value, proof.l1_raw)
        claim = verify_member(l1.data, proof.l1_entry)
        if claim.key != contract.encode():
            raise InvalidProof("contract entry proves the wrong key")
        l2 = verify_version(claim.value, proof.l2_raw)
        claim = verify_member(l2.data, proof.l2_entry)
        if claim.key != key.encode():
            raise InvalidProof("state-key entry proves the wrong key")
        blob = verify_version(claim.value, proof.value_raw)
        # value completeness: verified leaf payloads must tile the
        # claimed bytes exactly and cover the tree's full item count;
        # an EMPTY claim is only accepted when the authenticated root
        # IS the canonical empty-blob leaf (a server cannot present a
        # non-empty state as empty by dropping the leaf proofs)
        if not proof.value_proofs:
            empty_root = content_hash_many(
                [ck.encode_chunk(ck.BLOB, b"")])[0]
            if proof.value != b"" or blob.data != empty_root:
                raise InvalidProof("value proof does not cover the value")
            return len(chain) - 1, b""
        pos, total = 0, None
        for vp in proof.value_proofs:
            mp = MembershipProof.from_bytes(vp)
            c = verify_member(blob.data, mp)
            if c.pos != pos:
                raise InvalidProof("value leaves not contiguous")
            payload = ck.chunk_payload(mp.leaf)
            if proof.value[pos:pos + len(payload)] != payload:
                raise InvalidProof("claimed value bytes diverge")
            pos += len(payload)
            if total is None:
                total = (_root_count(mp) if mp.nodes
                         else len(payload))
        if pos != len(proof.value) or (total or 0) != len(proof.value):
            raise InvalidProof("value proof does not cover the value")
        return len(chain) - 1, proof.value

    def verify_state_flat(self, proof: FlatStateProof,
                          contract: str, key: str) -> tuple[int, bytes]:
        """Live-mode verifier: same trust threading as ``verify_state``
        but through the flat state map — the kv leaf IS the value, so
        there is no per-chunk tiling to check."""
        from ..core.hashing import content_hash_many
        from ..proof import (InvalidProof, LineageProof, verify_lineage,
                             verify_member, verify_version)
        lp = LineageProof.from_bytes(proof.lineage)
        if not lp.raws:
            raise InvalidProof("empty lineage")
        block_uid = content_hash_many([lp.raws[-1]])[0]
        chain = verify_lineage(self.head_uid, block_uid, lp)
        block = verify_version(block_uid, proof.block_raw)
        claim = verify_member(block.data, proof.state_entry)
        if claim.key != b"state":
            raise InvalidProof("state-root entry proves the wrong key")
        state = verify_version(claim.value, proof.state_raw)
        claim = verify_member(state.data, proof.kv_entry)
        if claim.key != f"{contract}/{key}".encode():
            raise InvalidProof("kv entry proves the wrong key")
        return len(chain) - 1, bytes(claim.value)


def _root_count(mp) -> int:
    """Authenticated total item count from a proof's root index node."""
    from ..core import chunk as ck
    entries = ck.decode_uindex(ck.chunk_payload(mp.nodes[0]))
    return sum(e.count for e in entries)
