"""Baseline: Hyperledger v0.6's original storage design on a plain KV
store (paper §5.1.1, Fig. 7a) — what ForkBase replaces.

Components, faithful to the paper's description:
  * a key-value store (stand-in for RocksDB);
  * a Merkle **bucket tree** over the state: a fixed number of buckets,
    key-hash -> bucket, bucket hash = H(sorted kv pairs), state hash =
    binary Merkle reduction over bucket hashes.  Fewer buckets => more
    write amplification per commit (Fig. 11);
  * an alternative **trie** (Patricia-style over key nibbles) with
    per-path rehashing (Fig. 11's 'trie' series);
  * **state deltas**: each commit stores the overwritten values, so
    historical reads require replaying deltas backward — analytics need a
    pre-processing pass over all blocks (Fig. 12's Rocksdb series).
"""
from __future__ import annotations

import hashlib
import json
from collections import defaultdict
from dataclasses import dataclass


def H(b: bytes) -> bytes:
    return hashlib.sha256(b).digest()


# ----------------------------------------------------------- bucket tree

class BucketTree:
    def __init__(self, n_buckets: int = 1024):
        self.n = n_buckets
        self.kv: dict[bytes, bytes] = {}
        self.bucket_hash = [b"\x00" * 32] * n_buckets
        self.hashed_bytes = 0        # write-amplification counter

    def _bucket(self, k: bytes) -> int:
        return int.from_bytes(H(k)[:8], "little") % self.n

    def update(self, writes: dict[bytes, bytes]) -> bytes:
        touched = set()
        for k, v in writes.items():
            self.kv[k] = v
            touched.add(self._bucket(k))
        for b in touched:
            items = sorted((k, v) for k, v in self.kv.items()
                           if self._bucket(k) == b)
            payload = b"".join(k + v for k, v in items)
            self.hashed_bytes += len(payload)
            self.bucket_hash[b] = H(payload)
        return self.root()

    def root(self) -> bytes:
        level = list(self.bucket_hash)
        while len(level) > 1:
            nxt = []
            for i in range(0, len(level), 2):
                pair = level[i] + (level[i + 1] if i + 1 < len(level)
                                   else b"")
                nxt.append(H(pair))
            level = nxt
        return level[0]


# ----------------------------------------------------------------- trie

class TrieNode:
    __slots__ = ("children", "value", "hash")

    def __init__(self):
        self.children: dict[int, "TrieNode"] = {}
        self.value: bytes | None = None
        self.hash = b"\x00" * 32


class MerkleTrie:
    def __init__(self):
        self.root = TrieNode()
        self.hashed_bytes = 0

    def update(self, writes: dict[bytes, bytes]) -> bytes:
        for k, v in writes.items():
            nibbles = [b >> 4 for b in H(k)[:8]] + \
                      [b & 15 for b in H(k)[:8]]
            path = [self.root]
            node = self.root
            for nb in nibbles:
                node = node.children.setdefault(nb, TrieNode())
                path.append(node)
            node.value = v
            for n in reversed(path):        # rehash the touched path
                payload = (n.value or b"") + b"".join(
                    c.hash for c in n.children.values())
                self.hashed_bytes += len(payload)
                n.hash = H(payload)
        return self.root.hash


# ------------------------------------------------------------- the ledger

@dataclass
class Block:
    height: int
    prev: bytes
    state_hash: bytes
    txs: list
    delta: dict          # key -> previous value (state delta)

    def hash(self) -> bytes:
        return H(self.prev + self.state_hash
                 + json.dumps(self.txs).encode())


class KVLedger:
    """The Fig. 7(a) stack: KV store + Merkle structure + state deltas."""

    def __init__(self, merkle: str = "bucket", n_buckets: int = 1024):
        self.kv: dict[bytes, bytes] = {}          # "RocksDB"
        self.tree = (BucketTree(n_buckets) if merkle == "bucket"
                     else MerkleTrie())
        self.blocks: list[Block] = []
        self._writes: dict[bytes, bytes] = {}
        self._pending: list = []
        self.storage_bytes = 0

    def read(self, contract: str, key: str) -> bytes | None:
        kk = f"{contract}/{key}".encode()
        return self._writes.get(kk, self.kv.get(kk))

    def write(self, contract: str, key: str, value: bytes) -> None:
        # must eagerly maintain temporary structures (paper: "Rocksdb and
        # ForkBase-KV need to compute temporary updates for the internal
        # structures")
        kk = f"{contract}/{key}".encode()
        self._writes[kk] = value
        self._pending.append((contract, "put", key))

    def commit(self) -> bytes:
        delta = {k.decode(): (self.kv.get(k) or b"").decode("latin1")
                 for k in self._writes}
        state_hash = self.tree.update(dict(self._writes))
        for k, v in self._writes.items():
            self.kv[k] = v
            self.storage_bytes += len(k) + len(v)
        prev = self.blocks[-1].hash() if self.blocks else b"\x00" * 32
        blk = Block(len(self.blocks), prev, state_hash,
                    list(self._pending), delta)
        self.blocks.append(blk)
        self.storage_bytes += sum(len(k) + len(v.encode("latin1"))
                                  for k, v in delta.items()) + 96
        self._writes.clear()
        self._pending.clear()
        return blk.hash()

    # -------------------------------------------------------- analytics
    def build_scan_index(self):
        """Pre-processing pass (paper §5.1.2): parse every block's delta
        to build an in-memory history index."""
        index: dict[str, list] = defaultdict(list)
        for blk in self.blocks:
            for k, old in blk.delta.items():
                index[k].append((blk.height, old))
        return index

    def state_scan(self, contract: str, key: str, index=None):
        if index is None:
            index = self.build_scan_index()   # cost paid per query
        kk = f"{contract}/{key}"
        cur = self.kv.get(kk.encode())
        hist = [cur]
        for _h, old in reversed(index.get(kk, [])):
            hist.append(old.encode("latin1"))
        return hist[:-1]

    def block_scan(self, height: int, index=None):
        """Replay deltas backward from the head to `height`."""
        state = dict(self.kv)
        for blk in reversed(self.blocks[height + 1:]):
            for k, old in blk.delta.items():
                state[k.encode()] = old.encode("latin1")
        return state
