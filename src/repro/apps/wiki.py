"""Wiki engine (paper §5.2): ForkBase Blob pages vs a Redis-style
multi-versioned list baseline.

ForkBase: each page is a Blob under its name; every edit is a Put on the
default branch — versioning, diff and chunk dedup come from the engine.
Client-side chunk caching makes reading consecutive versions cheap
(Fig. 14): unchanged chunks hit the cache.

Redis baseline: page -> list of full version payloads (RPUSH per edit),
optionally zlib-compressed at rest (the paper notes Redis compresses on
persistence).
"""
from __future__ import annotations

import zlib

from ..core import FBlob, ForkBase


class ForkBaseWiki:
    def __init__(self, db: ForkBase | None = None):
        self.db = db if db is not None else ForkBase()

    def create(self, page: str, text: bytes) -> bytes:
        return self.db.put(page, FBlob(text))

    def load(self, page: str) -> bytes:
        return self.db.get(page).blob().read()

    def edit(self, page: str, fn) -> bytes:
        """fn: FBlob -> None applies buffered edits (insert/remove/append);
        commit is one incremental Put."""
        b = self.db.get(page).blob()
        fn(b)
        return self.db.put(page, b)

    def read_version(self, page: str, back: int, chunk_cache: set | None = None):
        """Read the version `back` steps behind head; with a client chunk
        cache, returns (bytes, chunks_fetched, chunks_cached)."""
        objs = self.db.track(page, "master", (back, back + 1))
        h = self.db.get(page, uid=objs[0].uid)
        tree = h.blob().tree
        fetched = cached = 0
        parts = []
        for i, e in enumerate(tree.levels[0]):
            if chunk_cache is not None and e.cid in chunk_cache:
                cached += 1
            else:
                fetched += 1
                if chunk_cache is not None:
                    chunk_cache.add(e.cid)
            parts.append(tree._leaf_payload(i))
        return b"".join(parts), fetched, cached

    def diff(self, page: str, back1: int, back2: int):
        objs = self.db.track(page, "master", (0, max(back1, back2) + 1))
        return self.db.diff(objs[back1].uid, objs[back2].uid)

    def storage_bytes(self) -> int:
        return self.db.store.stats.physical_bytes


class LiveWiki:
    """Forkless flat-path wiki (repro.live): every page's current text
    lives as one entry of a LiveTable on key ``__wiki__``, so loads and
    edits are O(1) dict operations with Redis-like latency — while each
    epoch ``fold()`` batch-splices the accumulated edits into the
    backing POS-Tree map, keeping per-epoch history, chunk dedup and
    membership proofs.  The live answer to §5.2's Redis baseline:
    flat-path speed without giving up the archive."""

    PAGES_KEY = "__wiki__"

    def __init__(self, db: ForkBase | None = None, *, policy=None):
        self.db = db if db is not None else ForkBase()
        self.pages = self.db.live(self.PAGES_KEY, policy=policy)

    def create(self, page: str, text: bytes) -> None:
        self.pages.put(page.encode(), text)

    def load(self, page: str) -> bytes:
        return self.pages.get(page.encode())

    def edit(self, page: str, new_text: bytes) -> None:
        self.pages.put(page.encode(), new_text)

    def fold(self):
        """Epoch boundary: one batched Merkle commitment of all edits
        since the last fold; returns the live.FoldReport."""
        return self.pages.fold()

    def read_version(self, page: str, back: int) -> bytes:
        """Read the page as of ``back`` epochs behind the folded head
        (live history granularity is per-fold, not per-edit)."""
        objs = self.db.track(self.PAGES_KEY, "master", (back, back + 1))
        m = self.db.get(self.PAGES_KEY, uid=objs[0].uid).map()
        return bytes(m.get(page.encode()))

    def storage_bytes(self) -> int:
        return self.db.store.stats.physical_bytes


class RedisWiki:
    """Baseline: list-of-versions per page (paper §5.2)."""

    def __init__(self, compress: bool = True):
        self.pages: dict[str, list[bytes]] = {}
        self.compress = compress

    def create(self, page: str, text: bytes) -> None:
        self.pages[page] = [self._enc(text)]

    def load(self, page: str) -> bytes:
        return self._dec(self.pages[page][-1])

    def edit(self, page: str, new_text: bytes) -> None:
        self.pages[page].append(self._enc(new_text))   # full copy (RPUSH)

    def read_version(self, page: str, back: int) -> bytes:
        return self._dec(self.pages[page][-1 - back])

    def storage_bytes(self) -> int:
        return sum(len(v) for vs in self.pages.values() for v in vs)

    def _enc(self, b: bytes) -> bytes:
        return zlib.compress(b) if self.compress else b

    def _dec(self, b: bytes) -> bytes:
        return zlib.decompress(b) if self.compress else b
