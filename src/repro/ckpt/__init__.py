from .store import (CheckpointStore, restore_tree, save_tree)

__all__ = ["CheckpointStore", "save_tree", "restore_tree"]
