"""ForkBase-backed checkpointing — the paper's storage engine as the
training framework's state substrate (DESIGN.md §2).

Layout per checkpoint:
  * every tensor leaf -> an FBlob (POS-Tree over its raw bytes): chunk-level
    dedup across steps (optimizer moments / embeddings barely change
    between nearby steps) and across experiment forks;
  * one FMap manifest per checkpoint: tree path -> JSON{root cid, dtype,
    shape}; committed as a single Put on the run's branch, so the manifest
    uid is the tamper-evident version of the WHOLE training state and its
    ``bases`` chain is the training lineage;
  * fork-on-demand  = hyperparameter fork / warm restart from any step;
  * fork-on-conflict = two pods racing to commit the same step leave two
    untagged heads; the controller resolves (runtime/controller.py).

Restore materializes tensors host-side and re-shards onto whatever mesh
the restarted job has (elastic resize — the checkpoint is mesh-agnostic).
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import jax
import numpy as np

from ..core import ForkBase, FBlob, FMap, POSTree, load_fobject
from ..core import chunk as ck
from ..storage import WriteBuffer


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out, treedef


class CheckpointStore:
    def __init__(self, db: ForkBase | None = None, key: str = "ckpt"):
        self.db = db if db is not None else ForkBase()
        self.key = key

    # ------------------------------------------------------------- save
    def save(self, state, branch: str, *, step: int,
             extra: dict | None = None) -> bytes:
        """Commit `state` (pytree of arrays) as one version on `branch`.
        Returns the checkpoint uid."""
        leaves, _ = _leaf_paths(state)
        head = self.db.get(self.key, branch)
        manifest = (head.map() if head is not None else FMap())
        # one put_many for the chunks of ALL tensors in this checkpoint
        batch = WriteBuffer(self.db.store)
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            blob = FBlob(arr.tobytes())
            root = blob.commit(batch)
            meta = {"cid": root.hex(), "dtype": str(arr.dtype),
                    "shape": list(arr.shape)}
            manifest.set(name.encode(), json.dumps(meta).encode())
        batch.flush()
        ctx = json.dumps({"step": step, **(extra or {})}).encode()
        return self.db.put(self.key, manifest, branch, context=ctx)

    def save_on_base(self, state, base_uid: bytes, *, step: int,
                     extra: dict | None = None) -> bytes:
        """Fork-on-conflict commit path: Put against an explicit base
        version (two pods racing on the same step produce two untagged
        heads, paper §3.3.2)."""
        leaves, _ = _leaf_paths(state)
        manifest = self.db.get(self.key, uid=base_uid).map()
        batch = WriteBuffer(self.db.store)
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            blob = FBlob(arr.tobytes())
            root = blob.commit(batch)
            manifest.set(name.encode(), json.dumps(
                {"cid": root.hex(), "dtype": str(arr.dtype),
                 "shape": list(arr.shape)}).encode())
        batch.flush()
        ctx = json.dumps({"step": step, **(extra or {})}).encode()
        return self.db.put(self.key, manifest, base_uid=base_uid,
                           context=ctx)

    # ---------------------------------------------------------- restore
    def restore(self, like, branch: str | None = None,
                uid: bytes | None = None, mesh=None, specs=None):
        """Rebuild the pytree of `like` (shapes/dtypes template).  With
        mesh+specs the tensors are device_put with the target sharding —
        the restart mesh need not match the writer's (elastic)."""
        handle = self.db.get(self.key, branch, uid=uid)
        assert handle is not None, "no checkpoint found"
        manifest = handle.map()
        leaves, treedef = _leaf_paths(like)
        spec_leaves = None
        if specs is not None:
            spec_leaves, _ = _leaf_paths(specs)
        out = []
        for i, (name, leaf) in enumerate(leaves):
            raw = manifest.get(name.encode())
            assert raw is not None, f"missing tensor {name}"
            meta = json.loads(raw)
            tree = POSTree.from_root(self.db.store, ck.BLOB,
                                     bytes.fromhex(meta["cid"]))
            data = tree.read_bytes(0, tree.total_count)
            arr = np.frombuffer(data, dtype=meta["dtype"]).reshape(
                meta["shape"])
            if mesh is not None and spec_leaves is not None:
                from jax.sharding import NamedSharding
                arr = jax.device_put(
                    arr, NamedSharding(mesh, spec_leaves[i][1]))
            else:
                arr = jax.numpy.asarray(arr)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------ meta
    def step_of(self, uid: bytes) -> int:
        obj = load_fobject(self.db.store, uid)
        return json.loads(obj.context or b"{}").get("step", -1)

    def history(self, branch: str, limit: int = 100):
        return [(o.uid, json.loads(o.context or b"{}"))
                for o in self.db.track(self.key, branch, (0, limit))]

    def fork(self, ref: str | bytes, new_branch: str) -> None:
        """Experiment fork (warm restart from any historical version)."""
        self.db.fork(self.key, ref, new_branch)

    def verify(self, uid: bytes, ancestor: bytes) -> bool:
        """Tamper-evident lineage check: does `uid` derive from
        `ancestor`? (model provenance, DESIGN.md §2)."""
        return self.db.verify_lineage(uid, ancestor)

    def racing_heads(self):
        return self.db.list_untagged_branches(self.key)

    def resolve_race(self, *uids, prefer: str = "step") -> bytes:
        """Merge racing pod commits: keep the head with the greatest
        data progress (context step), paper-style choose-one resolution."""
        best = max(uids, key=self.step_of)

        def resolver(conflict):
            return None  # unused: choose-one at version level
        # choose-one at the version level: merge with ours=best
        others = [u for u in uids if u != best]
        from ..core.merge import choose_one
        acc = best
        for u in others:
            acc = self.db.merge(self.key, acc, u, resolver=choose_one(0))
        return acc

    @property
    def dedup_stats(self):
        return self.db.store.stats


def save_tree(state, db: ForkBase, branch: str = "master", step: int = 0):
    return CheckpointStore(db).save(state, branch, step=step)


def restore_tree(like, db: ForkBase, branch: str = "master"):
    return CheckpointStore(db).restore(like, branch)
