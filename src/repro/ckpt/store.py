"""ForkBase-backed checkpointing — the paper's storage engine as the
training framework's state substrate (DESIGN.md §2).

Layout per checkpoint:
  * every tensor leaf -> an FBlob (POS-Tree over its raw bytes): chunk-level
    dedup across steps (optimizer moments / embeddings barely change
    between nearby steps) and across experiment forks;
  * one FMap manifest per checkpoint: tree path -> JSON{root cid, dtype,
    shape}; committed as a single Put on the run's branch, so the manifest
    uid is the tamper-evident version of the WHOLE training state and its
    ``bases`` chain is the training lineage;
  * fork-on-demand  = hyperparameter fork / warm restart from any step;
  * fork-on-conflict = two pods racing to commit the same step leave two
    untagged heads; the controller resolves (runtime/controller.py).

Restore materializes tensors host-side and re-shards onto whatever mesh
the restarted job has (elastic resize — the checkpoint is mesh-agnostic).
"""
from __future__ import annotations

import json

import jax
import numpy as np

from ..core import ForkBase, FBlob, FMap, POSTree, load_fobject
from ..core import chunk as ck
from ..errors import CheckpointMissing, TensorMissing
from ..storage import WriteBuffer


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out, treedef


def manifest_refs(raw: bytes) -> list[bytes]:
    """GC link extractor for checkpoint manifests: a manifest is an FMap
    whose values are JSON ``{"cid": <hex tensor-tree root>, ...}`` — an
    application-level reference the chunk format can't expose.  This hook
    (gc.mark ``ref_hooks``) surfaces those roots so the mark phase walks
    the tensor trees of every live manifest.  Non-JSON / cid-less values
    are skipped; gc validates extracted refs before following them."""
    if ck.chunk_type(raw) != ck.MAP:
        return []
    refs = []
    for _, v in ck.unpack_kv_stream(ck.chunk_payload(raw)):
        try:
            meta = json.loads(v)
            cid = bytes.fromhex(meta["cid"])
        except (ValueError, KeyError, TypeError, UnicodeDecodeError):
            continue
        if len(cid) == 32:
            refs.append(cid)
    return refs


class CheckpointStore:
    def __init__(self, db: ForkBase | None = None, key: str = "ckpt", *,
                 durable_root: str | None = None):
        """``durable_root`` (without an explicit ``db``) opens the engine
        over the durable tiered store (storage.durable): checkpoints
        survive process death, and ``sync()`` is the barrier that makes
        a just-saved step restorable after a crash."""
        if db is None:
            db = (ForkBase(durable_root=durable_root)
                  if durable_root is not None else ForkBase())
        self.db = db
        self.key = key
        if manifest_refs not in self.db.gc_hooks:
            self.db.gc_hooks.append(manifest_refs)

    def sync(self) -> None:
        """Durability barrier: flush chunks + snapshot branch heads (see
        ``ForkBase.sync``).  A restore after a crash sees exactly the
        checkpoints saved before the last ``sync()``."""
        self.db.sync()

    # ------------------------------------------------------------- save
    def save(self, state, branch: str, *, step: int,
             extra: dict | None = None) -> bytes:
        """Commit `state` (pytree of arrays) as one version on `branch`.
        Returns the checkpoint uid.

        A checkpoint save is an epoch boundary for the engine's live
        tables (repro.live): any flat-path deltas are folded into their
        POS-Trees first, so the checkpoint never lands on a store whose
        durable state lags the served state."""
        if getattr(self.db, "_live", None):
            self.db.commit_epoch(context=json.dumps(
                {"ckpt_step": step}).encode())
        leaves, _ = _leaf_paths(state)
        head = self.db.get(self.key, branch)
        manifest = (head.map() if head is not None else FMap())
        # one put_many for the chunks of ALL tensors in this checkpoint
        batch = WriteBuffer(self.db.store)
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            blob = FBlob(arr.tobytes())
            root = blob.commit(batch)
            meta = {"cid": root.hex(), "dtype": str(arr.dtype),
                    "shape": list(arr.shape)}
            manifest.set(name.encode(), json.dumps(meta).encode())
        batch.flush()
        ctx = json.dumps({"step": step, **(extra or {})}).encode()
        return self.db.put(self.key, manifest, branch, context=ctx)

    def save_on_base(self, state, base_uid: bytes, *, step: int,
                     extra: dict | None = None) -> bytes:
        """Fork-on-conflict commit path: Put against an explicit base
        version (two pods racing on the same step produce two untagged
        heads, paper §3.3.2)."""
        leaves, _ = _leaf_paths(state)
        manifest = self.db.get(self.key, uid=base_uid).map()
        batch = WriteBuffer(self.db.store)
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            blob = FBlob(arr.tobytes())
            root = blob.commit(batch)
            manifest.set(name.encode(), json.dumps(
                {"cid": root.hex(), "dtype": str(arr.dtype),
                 "shape": list(arr.shape)}).encode())
        batch.flush()
        ctx = json.dumps({"step": step, **(extra or {})}).encode()
        return self.db.put(self.key, manifest, base_uid=base_uid,
                           context=ctx)

    # ---------------------------------------------------------- restore
    def restore(self, like, branch: str | None = None,
                uid: bytes | None = None, mesh=None, specs=None):
        """Rebuild the pytree of `like` (shapes/dtypes template).  With
        mesh+specs the tensors are device_put with the target sharding —
        the restart mesh need not match the writer's (elastic)."""
        handle = self.db.get(self.key, branch, uid=uid)
        if handle is None:
            raise CheckpointMissing(f"{self.key!r}@{branch or uid!r}")
        manifest = handle.map()
        leaves, treedef = _leaf_paths(like)
        spec_leaves = None
        if specs is not None:
            spec_leaves, _ = _leaf_paths(specs)
        out = []
        for i, (name, _leaf) in enumerate(leaves):
            raw = manifest.get(name.encode())
            if raw is None:
                raise TensorMissing(name)
            meta = json.loads(raw)
            tree = POSTree.from_root(self.db.store, ck.BLOB,
                                     bytes.fromhex(meta["cid"]))
            data = tree.read_bytes(0, tree.total_count)
            arr = np.frombuffer(data, dtype=meta["dtype"]).reshape(
                meta["shape"])
            if mesh is not None and spec_leaves is not None:
                from jax.sharding import NamedSharding
                arr = jax.device_put(
                    arr, NamedSharding(mesh, spec_leaves[i][1]))
            else:
                arr = jax.numpy.asarray(arr)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------ meta
    def step_of(self, uid: bytes) -> int:
        obj = load_fobject(self.db.store, uid)
        return json.loads(obj.context or b"{}").get("step", -1)

    def history(self, branch: str, limit: int = 100):
        return [(o.uid, json.loads(o.context or b"{}"))
                for o in self.db.track(self.key, branch, (0, limit))]

    def fork(self, ref: str | bytes, new_branch: str) -> None:
        """Experiment fork (warm restart from any historical version)."""
        self.db.fork(self.key, ref, new_branch)

    # -------------------------------------------------------- retention
    def prune(self, branch: str, *, keep_last: int = 1,
              keep_every: int | None = None, collect: bool = True,
              incremental: bool = False, budget: int = 256):
        """Retention policy over a training run: keep the newest
        ``keep_last`` checkpoints plus every ``keep_every``-th step,
        rewrite the branch's manifest chain to exactly those versions
        (``ForkBase.truncate_history``) and — unless ``collect=False`` —
        run GC so the retired manifests and any tensor chunks only they
        referenced are reclaimed.  Tensor chunks shared with surviving
        checkpoints (the dedup win) stay, of course.

        The kept versions get new uids (their ``bases`` are relinked);
        returns (kept uids newest-first, GCReport | None).  History
        shared with another branch is never rewritten: the walk stops at
        the first version some other head can reach and the rewritten
        chain is *anchored* on it, so forks keep their full lineage and
        ``lca``/``merge`` across related runs still find the common
        ancestor.  Pinned uids (``hold``) survive regardless of the
        policy.

        ``incremental=True`` drives the collection through
        ``gc.IncrementalCollector`` in ``budget``-bounded slices, so a
        retention pass on a live training run never stalls committers
        for a full-DAG mark (checkpoint manifests are traced through
        the ``manifest_refs`` hook either way)."""
        head = self.db.get(self.key, branch)
        if head is None:
            from ..core import NoSuchRef
            raise NoSuchRef(branch)
        chain = self.db.track(self.key, branch)   # newest first
        head_uid = head.uid
        tagged = self.db.list_tagged_branches(self.key)
        # heads of every OTHER branch (by name: a twin tag sharing our
        # head uid still protects it) + untagged racing heads
        other_heads = {u for b, u in tagged.items() if b != branch}
        other_heads |= (set(self.db.list_untagged_branches(self.key))
                        - {head_uid})
        external = self._reachable_versions(other_heads)
        keep: list[bytes] = []
        anchor: bytes | None = None
        for i, obj in enumerate(chain):
            if obj.uid in external:               # shared lineage: stop
                anchor = obj.uid
                break
            step = json.loads(obj.context or b"{}").get("step", -1)
            if i < keep_last or (keep_every is not None and step >= 0
                                 and step % keep_every == 0):
                keep.append(obj.uid)
        if keep:
            mapping = self.db.truncate_history(self.key, branch, keep,
                                               base_uid=anchor)
            kept = [mapping[u] for u in keep]
        else:
            kept = []                             # head itself is shared
        return kept, (self.db.gc(incremental=incremental, budget=budget)
                      if collect else None)

    def _reachable_versions(self, heads) -> set[bytes]:
        """Meta-level reachability (bases chains only) from ``heads`` —
        batched like gc.mark: one get_many per DAG level."""
        from ..core.fobject import FObject
        seen: set[bytes] = set(heads)
        frontier = list(seen)
        while frontier:
            nxt: list[bytes] = []
            for raw in self.db.store.get_many(frontier):
                for b in FObject.deserialize(raw, b"").bases:
                    if b not in seen:
                        seen.add(b)
                        nxt.append(b)
            frontier = nxt
        return seen

    def hold(self, *uids: bytes):
        """Retention hold (context manager): pin checkpoint versions an
        external consumer still reads, shielding them from prune+gc."""
        return self.db.pins.hold(*uids)

    def verify(self, uid: bytes, ancestor: bytes) -> bool:
        """Tamper-evident lineage check: does `uid` derive from
        `ancestor`? (model provenance, DESIGN.md §2)."""
        return self.db.verify_lineage(uid, ancestor)

    def racing_heads(self):
        return self.db.list_untagged_branches(self.key)

    def resolve_race(self, *uids, prefer: str = "step") -> bytes:
        """Merge racing pod commits: keep the head with the greatest
        data progress (context step), paper-style choose-one resolution."""
        best = max(uids, key=self.step_of)

        def resolver(conflict):
            return None  # unused: choose-one at version level
        # choose-one at the version level: merge with ours=best
        others = [u for u in uids if u != best]
        from ..core.merge import choose_one
        acc = best
        for u in others:
            acc = self.db.merge(self.key, acc, u, resolver=choose_one(0))
        return acc

    @property
    def dedup_stats(self):
        return self.db.store.stats


def save_tree(state, db: ForkBase, branch: str = "master", step: int = 0):
    return CheckpointStore(db).save(state, branch, step=step)


def restore_tree(like, db: ForkBase, branch: str = "master"):
    return CheckpointStore(db).restore(like, branch)
