"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from .base import (ArchConfig, ShapeConfig, SHAPES, input_specs, shapes_for,
                   smoke)
from . import (deepseek_moe_16b, internlm2_1_8b, internvl2_2b,
               musicgen_large, olmoe_1b_7b, qwen1_5_110b, qwen2_7b,
               tinyllama_1_1b, xlstm_125m, zamba2_2_7b)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    olmoe_1b_7b, deepseek_moe_16b, tinyllama_1_1b, qwen1_5_110b,
    internlm2_1_8b, qwen2_7b, musicgen_large, zamba2_2_7b, internvl2_2b,
    xlstm_125m)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "get_arch", "ArchConfig", "ShapeConfig", "SHAPES",
           "input_specs", "shapes_for", "smoke"]
