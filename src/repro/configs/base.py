"""Architecture config schema + assigned input shapes.

One ``<arch>.py`` per assigned architecture defines ``CONFIG`` with the
exact published numbers (source cited in its docstring) and a reduced
``smoke()`` variant for CPU tests.  The FULL configs are only ever lowered
via ShapeDtypeStructs (launch/dryrun.py) — never allocated here.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid (zamba2-style: shared attn block every `attn_every`)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0
    # xLSTM: indices of sLSTM layers (others are mLSTM)
    slstm_at: tuple[int, ...] = ()
    # modality frontend stub: none | vision | audio
    frontend: str = "none"
    n_patches: int = 256
    # capabilities
    sub_quadratic: bool = False  # may run long_500k
    fsdp: bool = False           # ZeRO-3 weight sharding over 'data'
    # MoE dispatch implementation: 'gather' (shard_map EP, zero dispatch
    # FLOPs — production default) | 'onehot' (GShard-style einsum dispatch,
    # kept as the reference/baseline for §Perf comparisons)
    moe_impl: str = "gather"
    remat: bool = True
    # remat policy for scanned layer bodies: 'dots' saves projection
    # outputs (fastest backward, highest memory); 'none' saves only scan
    # carries (recompute-everything, fits the big archs)
    remat_policy: str = "dots"
    # gradient-accumulation microbatches for train_4k (activation memory
    # divides by this; chosen so peak/device fits 16 GB HBM)
    train_microbatch: int = 1
    # int8 KV-cache quantization (per token x head scales): halves decode
    # cache footprint; enabled where the bf16 cache would bust HBM
    kv_quant: bool = False
    # AdamW moment storage dtype ('bf16' compresses optimizer state 2x on
    # the 100B-class archs)
    opt_moments: str = "f32"

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def params_count(self) -> int:
        """Total parameter count (for 6ND model-FLOPs accounting)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        n = 2 * V * d                      # embed + unembed
        n += d                             # final norm
        if self.family == "ssm":           # xLSTM
            for i in range(L):
                if i in self.slstm_at:
                    n += 4 * 2 * d * d + 2 * d   # slstm: i,f,z,o x (Wx+Wh)
                else:
                    n += 2 * d * 2 * d + 2 * d * d + 4 * d  # mlstm qkv+up/out
            return n
        per_attn = (d * self.n_heads * self.dh              # wq
                    + 2 * d * self.n_kv_heads * self.dh     # wk, wv
                    + self.n_heads * self.dh * d)           # wo
        per_mlp_d = (3 if self.act == "swiglu" else 2) * d * self.d_ff
        if self.family == "moe":
            per_ffn = (self.n_experts *
                       (3 if self.act == "swiglu" else 2) * d * self.moe_d_ff
                       + d * self.n_experts)
            per_ffn += (self.n_shared_experts *
                        (3 if self.act == "swiglu" else 2) * d * self.moe_d_ff)
            n += L * (per_attn + per_ffn + 2 * d)
        elif self.family == "hybrid":
            di, N, H = self.d_inner, self.ssm_state, self.n_ssm_heads
            per_mamba = (d * (2 * di + 2 * N + H)  # in_proj (z,x,B,C,dt)
                         + 4 * di                   # conv
                         + di * d + 2 * H + d)      # out_proj, A/D, norm
            n += L * per_mamba
            n_attn_blocks = 1  # shared block (weight tying!)
            n += n_attn_blocks * (per_attn + per_mlp_d + 2 * d)
        else:
            n += L * (per_attn + per_mlp_d + 2 * d)
        return n

    def active_params_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.params_count()
        d, L = self.d_model, self.n_layers
        dense = self.params_count()
        per_expert = (3 if self.act == "swiglu" else 2) * d * self.moe_d_ff
        inactive = L * (self.n_experts - self.top_k) * per_expert
        return dense - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig) -> list[str]:
    """Live cells per arch: long_500k only for sub-quadratic archs
    (DESIGN.md §5 records the skips)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
    shardable, no device allocation.  Modality frontends are STUBS — the
    vision tower / EnCodec encoder is replaced by precomputed embeddings."""
    B, S = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), bf16)
            spec["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.n_patches), i32)
            spec["labels"] = jax.ShapeDtypeStruct((B, S - cfg.n_patches), i32)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), bf16)
            spec["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.n_patches), i32)
        return spec
    # decode: one new token against a cache/state of length S
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((B,), i32)}


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width,
    few experts, tiny vocab)."""
    smoke_attn_every = min(cfg.attn_every, 2) if cfg.attn_every else 0
    return replace(
        cfg,
        n_layers=max(2, min(4, cfg.n_layers // 8)) if smoke_attn_every == 0
        else 2 * smoke_attn_every,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        n_experts=8 if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else 64,
        attn_every=smoke_attn_every,
        slstm_at=tuple(i for i in cfg.slstm_at if i < 4)[:2]
        if cfg.slstm_at else (),
        n_patches=16 if cfg.frontend == "vision" else cfg.n_patches,
        fsdp=False,
        train_microbatch=1,
    )
