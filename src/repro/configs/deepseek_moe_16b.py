"""deepseek-moe-16b [moe] — DeepSeekMoE: Towards Ultimate Expert
Specialization [arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base].

28L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=102400,
fine-grained MoE: 2 shared + 64 routed experts, top-6.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    remat_policy="none", train_microbatch=4, kv_quant=True, fsdp=True,
    opt_moments="bf16",
)
