"""internvl2-2b [vlm] — InternVL2 [arXiv:2404.16821; hf OpenGVLab/InternVL2-2B].

InternLM2-1.8B backbone: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The InternViT-300M vision tower is a STUB — input_specs()
provides precomputed patch embeddings (B, 256, d_model) prepended to the
text tokens.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, frontend="vision", n_patches=256,
    remat_policy="none", train_microbatch=2,
)
