"""musicgen-large [audio] — Simple and Controllable Music Generation
[arXiv:2306.05284; hf facebook/musicgen-large].

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 — decoder-only over
EnCodec tokens, GELU MLP.  The EnCodec frontend is a STUB: the backbone
consumes the token stream directly (single-codebook stream stands in for
the 4-codebook delay pattern; noted in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, act="gelu", frontend="audio",
    remat_policy="none", train_microbatch=4, kv_quant=True,
)
