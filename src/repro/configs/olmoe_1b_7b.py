"""olmoe-1b-7b [moe] — OLMoE: Open Mixture-of-Experts Language Models
[arXiv:2409.02060; hf allenai/OLMoE-1B-7B-0924].

16L d_model=2048 16H (GQA kv=16) per-expert d_ff=1024 vocab=50304,
MoE 64 experts top-8, SwiGLU, RoPE.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, moe_d_ff=1024, remat_policy="none", train_microbatch=2,
)
