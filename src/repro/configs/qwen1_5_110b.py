"""qwen1.5-110b [dense] — Qwen1.5 series [hf Qwen/Qwen1.5-110B; config
family per hf:Qwen/Qwen1.5-0.5B scaled card].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
Largest assigned arch: FSDP (ZeRO-3) weight sharding over the data axis.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab=152064, qkv_bias=True, fsdp=True,
    remat_policy="none", train_microbatch=8, kv_quant=True,
    opt_moments="bf16",
)
