"""qwen2-7b [dense] — Qwen2 Technical Report
[arXiv:2407.10671; hf Qwen/Qwen2-7B].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, QKV bias.
28 heads is not divisible by the 16-way model axis -> attention runs with
sequence sharding (SP) instead of head sharding (see shardings.py).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, qkv_bias=True,
    remat_policy="none", train_microbatch=4, fsdp=True,
)
