"""xlstm-125m [ssm] — xLSTM: Extended Long Short-Term Memory
[arXiv:2405.04517; config tier: unverified — 125M band model].

12L d_model=768 4H vocab=50304, d_ff=0 (no separate FFN; the mLSTM block
carries an internal up-projection).  sLSTM blocks at layers (0, 4, 8)
(xLSTM[7:1]-style sparse sLSTM placement), mLSTM elsewhere.
Recurrent state is O(1) in context -> runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_at=(0, 4, 8), sub_quadratic=True,
)
