"""zamba2-2.7b [hybrid] — Zamba2 suite [arXiv:2411.15242; hf Zyphra/Zamba2-2.7B].

54 Mamba2 layers d_model=2560 + ONE shared attention+MLP block (weights
tied) applied every 6 mamba layers; 32H (kv=32) d_ff=10240 for the shared
block; ssm_state=64, vocab=32000.  Sub-quadratic: runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6,
    sub_quadratic=True, remat_policy="none", train_microbatch=2,
)
