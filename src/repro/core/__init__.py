"""ForkBase core — the paper's storage engine.

Public surface:
  ForkBase (db.py)          — embedded engine, APIs M1–M17 (Table 1)
  Cluster (cluster.py)      — distributed deployment, 2-layer partitioning
  FBlob/FList/FMap/FSet     — chunkable types (POS-Tree backed)
  FString/FTuple/FInt       — primitive types
  POSTree (postree.py)      — Pattern-Oriented-Split Tree
  ChunkStore                — content-addressed chunk storage (alias of
                              repro.storage.MemoryBackend; every store
                              implements storage.StorageBackend, batched)
"""
from .branch import (DEFAULT_BRANCH, BranchExists, GuardFailed, NoSuchRef)
from .chunker import ChunkParams, DEFAULT_PARAMS
from .chunkstore import ChunkStore, ReplicatedStore
from .cluster import Cluster, RoutingIndexMiss
from .db import ForkBase, TypeNotMatch, ValueHandle
from .runtime import (Backpressure, ClusterRuntime, MaintenanceDaemon,
                      RuntimeConfig)
from .fobject import FObject, load_fobject, make_fobject
from .merge import (BUILTIN_RESOLVERS, Conflict, MergeConflict,
                    aggregate_resolver, append_resolver, choose_one, lca)
from .postree import POSTree
from .types import FBlob, FInt, FList, FMap, FSet, FString, FTuple
from ..storage import (ChunkMissing, StorageBackend, TamperedChunk,
                       WriteBuffer, make_backend)

__all__ = [
    "ForkBase", "Cluster", "ChunkStore", "ReplicatedStore", "POSTree",
    "FBlob", "FList", "FMap", "FSet", "FString", "FTuple", "FInt",
    "FObject", "ChunkParams", "DEFAULT_PARAMS", "DEFAULT_BRANCH",
    "GuardFailed", "BranchExists", "NoSuchRef", "TypeNotMatch",
    "ValueHandle", "MergeConflict", "Conflict", "BUILTIN_RESOLVERS",
    "choose_one", "append_resolver", "aggregate_resolver", "lca",
    "load_fobject", "make_fobject", "StorageBackend", "ChunkMissing",
    "TamperedChunk", "WriteBuffer", "make_backend",
    "Backpressure", "ClusterRuntime", "MaintenanceDaemon",
    "RuntimeConfig", "RoutingIndexMiss",
]
