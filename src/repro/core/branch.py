"""Branch management (paper §4.5): per-key TB-table (tagged branches:
name -> head uid) and UB-table (untagged branch heads = leaves of the
object derivation graph)."""
from __future__ import annotations

from dataclasses import dataclass, field

DEFAULT_BRANCH = "master"


class GuardFailed(Exception):
    """Guarded Put failed: current head != guard_uid (paper §4.5.1)."""


@dataclass
class KeyBranches:
    tb: dict[str, bytes] = field(default_factory=dict)   # tag -> head uid
    ub: set[bytes] = field(default_factory=set)          # DAG leaf uids


class BranchTable:
    """One per servlet; serializes concurrent updates per key (§4.5.1)."""

    def __init__(self):
        self._keys: dict[bytes, KeyBranches] = {}

    def of(self, key: bytes) -> KeyBranches:
        return self._keys.setdefault(bytes(key), KeyBranches())

    def known(self, key: bytes) -> bool:
        return bytes(key) in self._keys

    def keys(self) -> list[bytes]:
        return sorted(self._keys)

    # ---- update rules (§4.5.1) ----
    def on_new_version(self, key: bytes, uid: bytes,
                       bases: tuple[bytes, ...]) -> None:
        """UB-table: add the new head, retire its bases.  A base not present
        means it was already derived -> implicit fork (FoC) keeps both."""
        kb = self.of(key)
        for b in bases:
            kb.ub.discard(b)
        kb.ub.add(uid)

    def set_head(self, key: bytes, branch: str, uid: bytes,
                 guard: bytes | None = None) -> None:
        kb = self.of(key)
        if guard is not None and kb.tb.get(branch) != guard:
            raise GuardFailed(branch)
        kb.tb[branch] = uid

    def head(self, key: bytes, branch: str) -> bytes | None:
        return self.of(key).tb.get(branch)

    def fork(self, key: bytes, new_branch: str, uid: bytes) -> None:
        kb = self.of(key)
        assert new_branch not in kb.tb, f"branch exists: {new_branch}"
        kb.tb[new_branch] = uid

    def rename(self, key: bytes, old: str, new: str) -> None:
        kb = self.of(key)
        assert new not in kb.tb, f"branch exists: {new}"
        kb.tb[new] = kb.tb.pop(old)

    def remove(self, key: bytes, branch: str) -> None:
        self.of(key).tb.pop(branch, None)

    def tagged(self, key: bytes) -> dict[str, bytes]:
        return dict(self.of(key).tb)

    def untagged(self, key: bytes) -> list[bytes]:
        return sorted(self.of(key).ub)
