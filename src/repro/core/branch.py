"""Branch management (paper §4.5): per-key TB-table (tagged branches:
name -> head uid) and UB-table (untagged branch heads = leaves of the
object derivation graph)."""
from __future__ import annotations

import json
from dataclasses import dataclass, field

from ..errors import BranchExists, GuardFailed, NoSuchRef

DEFAULT_BRANCH = "master"

__all__ = ["BranchExists", "BranchTable", "DEFAULT_BRANCH",
           "GuardFailed", "KeyBranches", "NoSuchRef"]


@dataclass
class KeyBranches:
    tb: dict[str, bytes] = field(default_factory=dict)   # tag -> head uid
    ub: set[bytes] = field(default_factory=set)          # DAG leaf uids
    foc: set[bytes] = field(default_factory=set)  # genuine FoC racing heads


class BranchTable:
    """One per servlet; serializes concurrent updates per key (§4.5.1)."""

    def __init__(self):
        self._keys: dict[bytes, KeyBranches] = {}
        self._listeners: list = []
        # incremental head refcounts: uid -> number of (key, tag) slots
        # plus UB memberships pointing at it.  all_heads() — hammered by
        # every attest() and every GC root snapshot — reads this instead
        # of walking the whole table.
        self._head_rc: dict[bytes, int] = {}

    # ---- mutation hooks (delta attestations) ----
    def add_listener(self, fn) -> None:
        """Register ``fn(key)`` to fire after any head-state mutation of
        that key — the dirty-key feed for incremental attestations."""
        self._listeners.append(fn)

    def remove_listener(self, fn) -> None:
        try:
            self._listeners.remove(fn)
        except ValueError:
            pass

    def _touch(self, key: bytes) -> None:
        for fn in self._listeners:
            fn(key)

    def _inc(self, uid: bytes) -> None:
        self._head_rc[uid] = self._head_rc.get(uid, 0) + 1

    def _dec(self, uid: bytes) -> None:
        n = self._head_rc.get(uid, 0) - 1
        if n > 0:
            self._head_rc[uid] = n
        else:
            self._head_rc.pop(uid, None)

    def of(self, key: bytes) -> KeyBranches:
        return self._keys.setdefault(bytes(key), KeyBranches())

    def known(self, key: bytes) -> bool:
        return bytes(key) in self._keys

    def keys(self) -> list[bytes]:
        return sorted(self._keys)

    # ---- update rules (§4.5.1) ----
    def on_new_version(self, key: bytes, uid: bytes,
                       bases: tuple[bytes, ...], *,
                       foc: bool = False) -> None:
        """UB-table: add the new head, retire its bases.  A base not present
        means it was already derived -> implicit fork (FoC) keeps both.
        ``foc=True`` marks the head as a *genuine* fork-on-conflict head
        (created against an explicit base version, or by merging untagged
        heads): such heads are live in their own right, independent of
        any tag that may later alias them — remove() consults this."""
        kb = self.of(key)
        for b in bases:
            if b in kb.ub:
                kb.ub.discard(b)
                self._dec(b)
            kb.foc.discard(b)       # derived from -> no longer a leaf
        if uid not in kb.ub:
            kb.ub.add(uid)
            self._inc(uid)
        if foc:
            kb.foc.add(uid)
        self._touch(bytes(key))

    def set_head(self, key: bytes, branch: str, uid: bytes,
                 guard: bytes | None = None) -> None:
        kb = self.of(key)
        if guard is not None and kb.tb.get(branch) != guard:
            raise GuardFailed(branch)
        old = kb.tb.get(branch)
        if old is not None:
            self._dec(old)
        kb.tb[branch] = uid
        self._inc(uid)
        self._touch(bytes(key))

    def head(self, key: bytes, branch: str) -> bytes | None:
        return self.of(key).tb.get(branch)

    def fork(self, key: bytes, new_branch: str, uid: bytes) -> None:
        kb = self.of(key)
        if new_branch in kb.tb:
            raise BranchExists(new_branch)
        kb.tb[new_branch] = uid
        self._inc(uid)
        self._touch(bytes(key))

    def rename(self, key: bytes, old: str, new: str) -> None:
        kb = self.of(key)
        if new in kb.tb:
            raise BranchExists(new)
        if old not in kb.tb:
            raise NoSuchRef(old)
        kb.tb[new] = kb.tb.pop(old)
        self._touch(bytes(key))

    def remove(self, key: bytes, branch: str) -> None:
        """Drop the tagged branch; its head also leaves the UB table, so
        the detached line of development becomes collectable by GC —
        UNLESS the head is live independently of this tag: another tag
        still points at it, or it is a genuine fork-on-conflict racing
        head (``foc``), which a tag only ever *aliased* — removing the
        alias restores the pre-tag state regardless of removal order."""
        kb = self.of(key)
        uid = kb.tb.pop(branch, None)
        if uid is not None:
            self._dec(uid)
            if (uid not in kb.foc and uid not in kb.tb.values()
                    and uid in kb.ub):
                kb.ub.discard(uid)
                self._dec(uid)
            self._touch(bytes(key))

    def tagged(self, key: bytes) -> dict[str, bytes]:
        return dict(self.of(key).tb)

    def untagged(self, key: bytes) -> list[bytes]:
        return sorted(self.of(key).ub)

    def all_heads(self) -> set[bytes]:
        """Every live head across all keys — the GC root set (TB + UB).
        Served from the incremental refcounts: O(distinct heads), not
        O(keys x branches)."""
        return set(self._head_rc)

    def heads_of(self, key: bytes) -> set[bytes]:
        """Live heads (TB + UB) of ONE key — the per-key slice of
        ``all_heads`` the delta attest path pins for a dirty key, so an
        attest after k head changes pins O(k) uids instead of
        O(all heads)."""
        kb = self._keys.get(bytes(key))
        if kb is None:
            return set()
        return set(kb.tb.values()) | kb.ub

    # ---- durable head persistence (storage.durable) ----
    def snapshot(self) -> bytes:
        """Canonical serialization of the full head state (TB + UB +
        foc), byte-identical for identical state — the unit the durable
        engine persists with ``write_durably`` on every ``sync()``."""
        doc = {k.hex(): {"tb": {n: u.hex() for n, u in kb.tb.items()},
                         "ub": sorted(u.hex() for u in kb.ub),
                         "foc": sorted(u.hex() for u in kb.foc)}
               for k, kb in sorted(self._keys.items())}
        return json.dumps(doc, sort_keys=True,
                          separators=(",", ":")).encode()

    def restore(self, blob: bytes) -> None:
        """Load a ``snapshot()`` into this (empty, freshly constructed)
        table, rebuilding the incremental head refcounts.  Listeners are
        not fired: restoring is reopening, not mutating."""
        doc = json.loads(blob)
        for khex, d in doc.items():
            kb = self.of(bytes.fromhex(khex))
            for name, uhex in d["tb"].items():
                uid = bytes.fromhex(uhex)
                kb.tb[name] = uid
                self._inc(uid)
            for uhex in d["ub"]:
                uid = bytes.fromhex(uhex)
                kb.ub.add(uid)
                self._inc(uid)
            kb.foc.update(bytes.fromhex(u) for u in d["foc"])
