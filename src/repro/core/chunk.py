"""Chunk wire format (paper §4.2, Table 2).

A chunk is the basic storage unit: 1 type byte + payload; its cid is the
content hash of the full serialized bytes, so equal content <=> equal cid
(the dedup + tamper-evidence invariant).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

from .hashing import content_hash

# chunk type tags (Table 2)
META = 0
UINDEX = 1
SINDEX = 2
BLOB = 3
LIST = 4
SET = 5
MAP = 6

CHUNK_TYPE_NAMES = {META: "Meta", UINDEX: "UIndex", SINDEX: "SIndex",
                    BLOB: "Blob", LIST: "List", SET: "Set", MAP: "Map"}

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")


def encode_chunk(ctype: int, payload: bytes) -> bytes:
    return bytes([ctype]) + payload


def chunk_type(raw: bytes) -> int:
    return raw[0]


def chunk_payload(raw: bytes) -> bytes:
    return raw[1:]


def cid_of(raw: bytes) -> bytes:
    return content_hash(raw)


# ---------------------------------------------------------------- elements

def pack_lv(b: bytes) -> bytes:
    """length-value encoding for one element."""
    return _U32.pack(len(b)) + b


def pack_kv(k: bytes, v: bytes) -> bytes:
    return _U32.pack(len(k)) + k + _U32.pack(len(v)) + v


def unpack_lv_stream(payload: bytes) -> list[bytes]:
    out = []
    i, n = 0, len(payload)
    while i < n:
        (ln,) = _U32.unpack_from(payload, i)
        i += 4
        out.append(payload[i:i + ln])
        i += ln
    return out


def unpack_kv_stream(payload: bytes) -> list[tuple[bytes, bytes]]:
    out = []
    i, n = 0, len(payload)
    while i < n:
        (kl,) = _U32.unpack_from(payload, i)
        i += 4
        k = payload[i:i + kl]
        i += kl
        (vl,) = _U32.unpack_from(payload, i)
        i += 4
        out.append((k, payload[i:i + vl]))
        i += vl
    return out


def kv_key(elem: bytes) -> bytes:
    """key of a serialized Map element (for SIndex split keys)."""
    (kl,) = _U32.unpack_from(elem, 0)
    return elem[4:4 + kl]


# ---------------------------------------------------------------- index nodes

@dataclass(frozen=True)
class Entry:
    """One index entry: child cid + subtree item count (+ max key for sorted
    types).  count is in *base items*: bytes for Blob, elements otherwise."""

    cid: bytes
    count: int
    key: bytes | None = None


def encode_uindex(entries: list[Entry]) -> bytes:
    parts = []
    for e in entries:
        parts.append(e.cid)
        parts.append(_U64.pack(e.count))
    return encode_chunk(UINDEX, b"".join(parts))


def decode_uindex(payload: bytes) -> list[Entry]:
    out = []
    i, n = 0, len(payload)
    while i < n:
        cid = payload[i:i + 32]
        i += 32
        (cnt,) = _U64.unpack_from(payload, i)
        i += 8
        out.append(Entry(cid, cnt))
    return out


def encode_sindex(entries: list[Entry]) -> bytes:
    parts = []
    for e in entries:
        parts.append(e.cid)
        parts.append(_U64.pack(e.count))
        parts.append(pack_lv(e.key or b""))
    return encode_chunk(SINDEX, b"".join(parts))


def decode_sindex(payload: bytes) -> list[Entry]:
    out = []
    i, n = 0, len(payload)
    while i < n:
        cid = payload[i:i + 32]
        i += 32
        (cnt,) = _U64.unpack_from(payload, i)
        i += 8
        (kl,) = _U32.unpack_from(payload, i)
        i += 4
        k = payload[i:i + kl]
        i += kl
        out.append(Entry(cid, cnt, k))
    return out
