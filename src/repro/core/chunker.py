"""Content-defined chunking with element alignment (paper §2.1, §4.3).

Splits a byte stream (Blob) or a stream of serialized elements (List / Map /
Set) into chunks at *pattern* positions from the rolling hash.  Two paper
rules on top of the raw bitmap:

  * element alignment — "if a pattern occurs in the middle of an element the
    chunk boundary is extended to cover the whole element, so that no
    elements are stored in more than one chunk" (§4.3.2);
  * forced split — "the chunk size cannot be alpha times bigger than the
    average size; otherwise it is forcefully chunked" (§4.3.3).

Cut positions are derived from the *global* boundary bitmap (the rolling
window never resets at cuts), so cuts strictly before an edit are unaffected
by it, and cuts re-align k bytes after the edit — the property incremental
commits rely on (postree.py) and tests/test_chunker.py asserts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from . import rolling


@dataclass(frozen=True)
class ChunkParams:
    """Knobs from §4.3.3.  Defaults reproduce the paper's 4 KB chunks."""

    window: int = 48          # rolling-hash window k (bytes)
    q: int = 12               # leaf pattern bits -> E[chunk] = 2^q = 4 KB
    max_factor: int = 8       # alpha: forced split at alpha * 2^q bytes
    index_r: int = 6          # index-node pattern bits -> E[fanout] = 2^r
    index_max_factor: int = 8  # forced split for index fanout

    @property
    def avg_size(self) -> int:
        return 1 << self.q

    @property
    def max_size(self) -> int:
        return self.max_factor * self.avg_size

    @property
    def index_fanout(self) -> int:
        return 1 << self.index_r

    @property
    def index_max_fanout(self) -> int:
        return self.index_max_factor * self.index_fanout


DEFAULT_PARAMS = ChunkParams()

# Kernel hook: set by repro.kernels.ops.use_pallas_chunker() so the whole
# storage engine transparently switches to the Pallas boundary kernel.
_bitmap_impl = rolling.boundary_bitmap


def set_bitmap_impl(fn) -> None:
    global _bitmap_impl
    _bitmap_impl = fn


def boundary_bitmap(data: np.ndarray, params: ChunkParams = DEFAULT_PARAMS) -> np.ndarray:
    return _bitmap_impl(data, params.window, params.q)


def cut_bytes(data: np.ndarray, params: ChunkParams = DEFAULT_PARAMS,
              bitmap: np.ndarray | None = None) -> list[int]:
    """Exclusive cut offsets for a raw byte stream (Blob).

    Returns offsets c_1 < c_2 < ... <= n such that chunks are
    [0,c_1), [c_1,c_2), ...; the final offset n is always included.
    """
    data = np.asarray(data, dtype=np.uint8)
    n = int(data.shape[0])
    if n == 0:
        return []
    if bitmap is None:
        bitmap = boundary_bitmap(data, params)
    hits = np.flatnonzero(bitmap) + 1  # cut AFTER the pattern byte
    return _apply_max_size(hits.tolist(), n, params.max_size)


def _apply_max_size(hits: list[int], end: int, max_size: int) -> list[int]:
    cuts: list[int] = []
    start = 0
    i = 0
    m = len(hits)
    while start < end:
        # next pattern cut after start
        while i < m and hits[i] <= start:
            i += 1
        nxt = hits[i] if i < m else end
        if nxt - start > max_size:
            nxt = start + max_size  # forced split (§4.3.3)
        elif nxt > end:
            nxt = end
        cuts.append(nxt)
        start = nxt
    if not cuts or cuts[-1] != end:
        cuts.append(end)
    return cuts


def cut_elements(lengths: Sequence[int], bitmap: np.ndarray,
                 params: ChunkParams = DEFAULT_PARAMS) -> list[int]:
    """Element-aligned cuts.

    lengths: per-element serialized byte lengths; bitmap: boundary bitmap of
    the concatenated element stream.  Returns exclusive cut indices in
    *element* space (last == len(lengths)).  A pattern inside element e cuts
    after e; forced split caps chunk bytes at max_size but never splits a
    single oversized element.
    """
    n_el = len(lengths)
    if n_el == 0:
        return []
    ends = np.cumsum(np.asarray(lengths, dtype=np.int64))  # byte end of each element
    total = int(ends[-1])
    hits = np.flatnonzero(bitmap) + 1  # byte positions after patterns
    # element whose byte-range contains each pattern -> cut after that element
    el_of_hit = np.searchsorted(ends, hits, side="left")
    cut_after = np.unique(el_of_hit[el_of_hit < n_el]) + 1  # element-space cuts
    cuts: list[int] = []
    start_el = 0
    start_byte = 0
    i = 0
    m = len(cut_after)
    max_size = params.max_size
    while start_el < n_el:
        while i < m and cut_after[i] <= start_el:
            i += 1
        nxt = int(cut_after[i]) if i < m else n_el
        # forced split in byte space, snapped to element ends
        if int(ends[nxt - 1]) - start_byte > max_size:
            j = int(np.searchsorted(ends, start_byte + max_size, side="right"))
            j = max(j, start_el + 1)  # never split below one element
            nxt = min(j, nxt)
        cuts.append(nxt)
        start_el = nxt
        start_byte = int(ends[nxt - 1])
    if not cuts or cuts[-1] != n_el:
        cuts.append(n_el)
    return cuts


def index_cuts(cids: Sequence[bytes], params: ChunkParams = DEFAULT_PARAMS) -> list[int]:
    """Index-node splitting (§4.3.3): pattern iff cid & (2^r - 1) == 0.

    P' reads the already-random child cid instead of re-hashing, matching the
    paper's optimization (rolling hash = 20% of build cost).  Returns
    exclusive cut indices in entry space.
    """
    n = len(cids)
    if n == 0:
        return []
    mask = (1 << params.index_r) - 1
    cuts: list[int] = []
    start = 0
    count = 0
    for i, cid in enumerate(cids):
        count += 1
        if (cid[0] & mask) == 0 or count >= params.index_max_fanout:
            cuts.append(i + 1)
            start = i + 1
            count = 0
    if not cuts or cuts[-1] != n:
        cuts.append(n)
    return cuts
