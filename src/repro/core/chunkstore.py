"""Content-addressed chunk storage (paper §4.4) — compatibility facade.

The implementations live in ``repro.storage`` behind the single
``StorageBackend`` protocol; the historical names are preserved here:

  ChunkStore      -> storage.MemoryBackend (memory + optional log file)
  ReplicatedStore -> storage.ReplicatedBackend
"""
from __future__ import annotations

from ..storage import (ChunkMissing, MemoryBackend, ReplicatedBackend,
                       StorageBackend, StoreStats)

ChunkStore = MemoryBackend
ReplicatedStore = ReplicatedBackend

__all__ = ["ChunkStore", "ReplicatedStore", "StoreStats", "StorageBackend",
           "ChunkMissing"]
