"""Content-addressed chunk storage (paper §4.4).

Key = cid, value = raw chunk bytes.  Immutable chunks, dedup on Put (an
existing cid is acknowledged without rewriting), optional log-structured
file persistence, optional k-way replication across instances (cluster.py
wires multiple stores into the cid-partitioned pool).
"""
from __future__ import annotations

import os
import struct
from dataclasses import dataclass, field

from .chunk import cid_of
from .hashing import CID_LEN

_LEN = struct.Struct("<I")


@dataclass
class StoreStats:
    puts: int = 0                 # Put-Chunk requests
    dedup_hits: int = 0           # Puts acknowledged via existing cid
    gets: int = 0
    logical_bytes: int = 0        # sum of bytes across all Puts
    physical_bytes: int = 0       # bytes actually stored (post-dedup)

    @property
    def dedup_ratio(self) -> float:
        return self.logical_bytes / max(1, self.physical_bytes)


class ChunkStore:
    """In-memory content-addressed store with optional append-only log."""

    def __init__(self, log_path: str | None = None, verify: bool = False):
        self._data: dict[bytes, bytes] = {}
        self.stats = StoreStats()
        self.verify = verify
        self._log = open(log_path, "ab") if log_path else None
        if log_path and os.path.getsize(log_path) > 0:
            self._replay(log_path)

    # -- core KV interface ---------------------------------------------
    def put(self, raw: bytes, cid: bytes | None = None) -> bytes:
        if cid is None:
            cid = cid_of(raw)
        elif self.verify:
            assert cid == cid_of(raw), "cid/content mismatch on Put-Chunk"
        st = self.stats
        st.puts += 1
        st.logical_bytes += len(raw)
        if cid in self._data:
            st.dedup_hits += 1     # immediate ack, chunk reused (§4.4)
            return cid
        self._data[cid] = raw
        st.physical_bytes += len(raw)
        if self._log is not None:
            self._log.write(cid + _LEN.pack(len(raw)) + raw)
        return cid

    def get(self, cid: bytes) -> bytes:
        self.stats.gets += 1
        raw = self._data[cid]
        if self.verify:
            assert cid_of(raw) == cid, "tampered chunk detected"
        return raw

    def has(self, cid: bytes) -> bool:
        return cid in self._data

    def __len__(self) -> int:
        return len(self._data)

    def flush(self) -> None:
        if self._log is not None:
            self._log.flush()
            os.fsync(self._log.fileno())

    def _replay(self, path: str) -> None:
        with open(path, "rb") as f:
            while True:
                head = f.read(CID_LEN + 4)
                if len(head) < CID_LEN + 4:
                    break
                cid = head[:CID_LEN]
                (ln,) = _LEN.unpack(head[CID_LEN:])
                raw = f.read(ln)
                if len(raw) < ln:
                    break  # torn tail write: recover prefix
                self._data[cid] = raw
                self.stats.physical_bytes += ln


class ReplicatedStore:
    """k-way replication over several ChunkStores (paper §4.4): dedup is
    preserved globally — at most k copies of any chunk exist."""

    def __init__(self, stores: list[ChunkStore], k: int = 2):
        assert stores
        self.stores = stores
        self.k = min(k, len(stores))

    def _ring(self, cid: bytes) -> list[ChunkStore]:
        h = int.from_bytes(cid[:8], "little")
        n = len(self.stores)
        return [self.stores[(h + i) % n] for i in range(self.k)]

    def put(self, raw: bytes, cid: bytes | None = None) -> bytes:
        if cid is None:
            cid = cid_of(raw)
        for s in self._ring(cid):
            s.put(raw, cid)
        return cid

    def get(self, cid: bytes) -> bytes:
        err: Exception | None = None
        for s in self._ring(cid):
            try:
                return s.get(cid)
            except KeyError as e:  # replica lost -> fail over
                err = e
        raise err  # type: ignore[misc]

    def has(self, cid: bytes) -> bool:
        return any(s.has(cid) for s in self._ring(cid))
