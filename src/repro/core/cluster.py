"""Distributed deployment (paper §4.1 Fig. 5, §4.6): master + request
dispatcher + servlets + chunk-storage pool, with hash-based two-layer
partitioning:

  1. dispatcher -> servlet : request-key hash;
  2. servlet   -> storage  : chunk cid hash (meta chunks stay local).

Because cids are cryptographic hashes, layer 2 spreads chunks uniformly
even under severely skewed key workloads (Fig. 15).  ``mode='1LP'``
reproduces the paper's one-layer baseline (all of a key's chunks stored on
its servlet's node).  Runs in-process; per-node byte/op counters stand in
for real placement, and POS-Tree construction rebalancing (§4.6.1) is a
work-queue transfer between servlets.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

from . import chunk as ck
from .chunker import ChunkParams, DEFAULT_PARAMS
from .chunkstore import ChunkStore
from .db import ForkBase
from .. import obs
from ..storage import BackendBase, resolve_cids
from ..storage.backend import group_by, put_via


def _h(data: bytes) -> int:
    return int.from_bytes(hashlib.sha256(data).digest()[:8], "little")


@dataclass
class NodeStats:
    chunk_bytes: int = 0
    chunks: int = 0
    requests: int = 0
    build_work: int = 0      # POS-Tree construction work units (bytes)


def _delete_on_node(cluster: "Cluster", ni: int, cids,
                    stats=None) -> tuple[int, int]:
    """One node's share of a sweep: delete the chunks, debit the node's
    placement counters, drop master-index entries.  ``stats`` (optional,
    a routing store's) absorbs the delete/reclaim counters but is NEVER
    debited physical bytes: routing stats count what that servlet wrote,
    and the deleted chunk's writer is unknown, so a debit would skew the
    caller negative (physical truth lives in the node stores).  Returns
    (removed chunks, freed bytes)."""
    nd = cluster.nodes[ni]
    d0 = nd.store.stats.deletes
    r0 = nd.store.stats.reclaimed_bytes
    nd.store.delete_many(cids)
    removed = nd.store.stats.deletes - d0
    freed = nd.store.stats.reclaimed_bytes - r0
    if stats is not None:
        stats.deletes += removed
        stats.reclaimed_bytes += freed
    nd.stats.chunks -= removed
    nd.stats.chunk_bytes -= freed
    for cid in cids:            # absent on the owner either way now
        cluster.index.pop(cid, None)
    return removed, freed


class _RoutingStore(BackendBase):
    """StorageBackend a servlet writes through: meta chunks pinned locally,
    data chunks placed by cid hash across the pool (2LP) or locally (1LP).
    Batched puts group chunks per target node — one put_many per node per
    batch, the cluster analogue of the §4.6.1 pipeline.  Reads go straight
    to the owning node (dispatcher fast path, §4.6)."""

    OBS_NAME = "routing"

    def __init__(self, cluster: "Cluster", home: int):
        super().__init__()
        self.cluster = cluster
        self.home = home

    def _owner(self, cid: bytes) -> int:
        if self.cluster.mode == "1LP":
            return self.home
        return _h(cid) % len(self.cluster.nodes)

    def _placement(self, raws):
        """owner_of for put batches: meta chunks pin to the home servlet
        (§4.6), data chunks place by cid hash."""
        def owner(i, cid):
            if ck.chunk_type(raws[i]) == ck.META:
                return self.home
            return self._owner(cid)
        return owner

    def _location(self, i, cid):
        """owner_of for read batches: master index, else cid placement."""
        node = self.cluster.index.get(cid)
        return self._owner(cid) if node is None else node

    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        out = resolve_cids(raws, cids)
        st = self.stats
        st.put_batches += 1
        st.puts += len(raws)
        st.logical_bytes += sum(len(r) for r in raws)
        for node, (_, cs, rs) in group_by(self._placement(raws),
                                          out, raws).items():
            n = self.cluster.nodes[node]
            _, new_chunks, new_bytes = put_via(st, n.store, rs, cs)
            n.stats.chunks += new_chunks
            n.stats.chunk_bytes += new_bytes
            for cid in cs:
                self.cluster.index[cid] = node
        self._notify_put(out)
        return out

    def _get_many_impl(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        st.gets += len(cids)
        out: list[bytes | None] = [None] * len(cids)
        for node, (idx, cs, _) in group_by(self._location, cids).items():
            n = self.cluster.nodes[node]
            n.stats.requests += len(cs)
            for i, raw in zip(idx, n.store.get_many(cs)):
                out[i] = raw
        return out  # type: ignore[return-value]

    def has_many(self, cids) -> list[bool]:
        out = [False] * len(cids)
        for node, (idx, cs, _) in group_by(self._location, cids).items():
            for i, p in zip(idx, self.cluster.nodes[node].store.has_many(cs)):
                out[i] = p
        return out

    def _delete_many_impl(self, cids) -> int:
        """Sweep fan-out by owning node; the master index and per-node
        placement counters shrink with the deleted chunks."""
        n = 0
        for node, (_, cs, _) in group_by(self._location, cids).items():
            n += _delete_on_node(self.cluster, node, cs, self.stats)[0]
        return n

    def iter_cids(self):
        return iter(list(self.cluster.index))

    def __len__(self) -> int:
        return len(self.cluster.index)

    def flush(self) -> None:
        for n in self.cluster.nodes:
            n.store.flush()


@dataclass
class Node:
    store: ChunkStore
    stats: NodeStats
    servlet: ForkBase | None = None


class Cluster:
    """In-process ForkBase cluster."""

    def __init__(self, n_nodes: int = 4, mode: str = "2LP",
                 params: ChunkParams = DEFAULT_PARAMS,
                 verify: bool = False, *,
                 durable_root: str | None = None,
                 hot_bytes: int = 64 << 20,
                 segment_bytes: int = 4 << 20):
        assert mode in ("1LP", "2LP")
        self.mode = mode
        self.params = params
        self.durable_root = durable_root
        self.index: dict[bytes, int] = {}   # master's chunk location map
        # one attestation/GC epoch fence for the whole cluster:
        # collections are cluster-wide, so servlet attestations pin into
        # (and collections consume from) the dispatcher's fence
        from ..gc.incremental import EpochFence
        self.gc_fence = EpochFence()
        self._audit_daemon = None
        if durable_root is None:
            stores = [ChunkStore(verify=verify) for _ in range(n_nodes)]
        else:
            # durable pool: each node's chunks live in a tiered segment
            # store under ``durable_root/node-XX``; reopening the same
            # root resumes the cluster (see ``sync``/``_restore_durable``)
            from ..storage.durable import open_durable
            stores = [open_durable(self._node_root(i), hot_bytes=hot_bytes,
                                   segment_bytes=segment_bytes,
                                   verify=verify)
                      for i in range(n_nodes)]
        self.nodes = [Node(store, NodeStats()) for store in stores]
        for i, node in enumerate(self.nodes):
            node.servlet = ForkBase(_RoutingStore(self, i), params)
        if durable_root is not None:
            self._restore_durable()
        # bloom spill path of the shared fence recovers cap-overflowed
        # pins by filtering the cluster-wide current heads
        self.gc_fence.heads_fn = self._all_heads

    # ---- durable pool (storage.durable) ----
    def _node_root(self, i: int) -> str:
        import os
        return os.path.join(self.durable_root, f"node-{i:02d}")

    def _heads_path(self, i: int) -> str:
        import os
        return os.path.join(self._node_root(i), "heads.json")

    def _restore_durable(self) -> None:
        """Resume a durable cluster: reload each servlet's branch heads
        from its last ``sync()`` snapshot and rebuild the master chunk
        location map by streaming every node store's cids (meta chunks
        are pinned to their home servlet, so the hash-placement fallback
        of ``_location`` alone would misroute them after a restart)."""
        import os
        for i, node in enumerate(self.nodes):
            path = self._heads_path(i)
            if os.path.exists(path):
                with open(path, "rb") as f:
                    node.servlet.branches.restore(f.read())
            for cid in node.store.iter_cids():
                self.index[cid] = i
            node.stats.chunks = len(node.store)
            node.stats.chunk_bytes = node.store.stats.physical_bytes
            node.stats.build_work = node.stats.chunk_bytes

    def sync(self) -> None:
        """Cluster durability point: flush every node store (hot-tier
        write-back + segment fsync + GC-fed compaction) and atomically
        snapshot every servlet's branch heads.  After ``sync()``, a new
        ``Cluster(durable_root=...)`` over the same root resumes with
        bit-identical heads.  A plain flush when not durable."""
        for i, node in enumerate(self.nodes):
            node.store.flush()
            if self.durable_root is not None:
                from ..storage.durable import write_durably
                write_durably(self._heads_path(i),
                              node.servlet.branches.snapshot())

    def _all_heads(self) -> set[bytes]:
        out: set[bytes] = set()
        for node in self.nodes:
            out |= node.servlet.branches.all_heads()
        return out

    # ---- dispatcher (layer 1) ----
    def _home_index(self, key) -> int:
        """Key-hash routing (hashed exactly once per dispatch)."""
        key = key.encode() if isinstance(key, str) else bytes(key)
        return _h(key) % len(self.nodes)

    def servlet_of(self, key: bytes) -> ForkBase:
        i = self._home_index(key)
        self.nodes[i].stats.requests += 1
        return self.nodes[i].servlet

    # public API mirrors ForkBase, routed per key
    def put(self, key, value, branch=None, **kw):
        with obs.trace("cluster.put", key=key if isinstance(key, (bytes,
                       str)) else str(key)):
            svc = self._build_servlet_for(key, value)
            return svc.put(key, value, branch, **kw)

    def get(self, key, branch=None, **kw):
        return self.servlet_of(key).get(key, branch, **kw)

    def fork(self, key, ref, new_branch):
        return self.servlet_of(key).fork(key, ref, new_branch)

    def merge(self, key, target, *refs, **kw):
        return self.servlet_of(key).merge(key, target, *refs, **kw)

    def track(self, key, ref, dist_rng=(0, 1 << 30)):
        return self.servlet_of(key).track(key, ref, dist_rng)

    def remove(self, key, branch):
        return self.servlet_of(key).remove(key, branch)

    # ---- live fast path (repro.live), routed per key ----
    def live(self, key, branch=None, *, policy=None):
        """The key's home servlet's LiveTable — hot traffic is served
        off the flat path while the POS-Tree archive (and its 2LP chunk
        placement) is only touched at epoch folds."""
        return self.servlet_of(key).live(key, branch, policy=policy)

    def commit_epoch(self, context: bytes = b"", *, attest: bool = False,
                     secret: bytes | None = None):
        """Cluster epoch boundary: fold every servlet's dirty live
        tables (each fold is one batched Put on its home servlet) and
        optionally attest per servlet.  Returns the per-servlet
        live.EpochReports."""
        return [node.servlet.commit_epoch(context, attest=attest,
                                          secret=secret)
                for node in self.nodes]

    # ---- garbage collection (cluster-wide) ----
    def _gc_roots_hooks(self, pins, extra_roots, extra_hooks):
        """Global root-set snapshot: union every servlet's TB/UB heads
        (branch-table copy per servlet) plus servlet pin sets, optional
        extra ``pins``, and caller-supplied roots/hooks — e.g. an
        external ForkBase sharing a routing store."""
        roots: set[bytes] = set(extra_roots)
        hooks: list = list(extra_hooks)
        for node in self.nodes:
            roots |= node.servlet.branches.all_heads()
            roots |= node.servlet.pins.uids()
            hooks.extend(h for h in node.servlet.gc_hooks
                         if h not in hooks)
        if pins is not None:
            roots |= pins.uids()
        return roots, hooks

    def gc(self, pins=None, extra_roots=(), extra_hooks=(), *,
           incremental: bool = False, budget: int = 256):
        """Cluster mark-and-sweep: the dispatcher unions every servlet's
        TB/UB heads (plus servlet pin sets, optional extra ``pins``, and
        any caller-supplied ``extra_roots``/``extra_hooks`` — e.g. an
        external ForkBase sharing a routing store) into one global root
        set, marks through the routing store — reads fan out to owning
        nodes via the master index, one batch per node per BFS level —
        then sweeps each node's *own* chunk store and the master index.
        ``incremental=True`` runs the same collection as an epoch of
        ``budget``-bounded slices (see ``incremental_gc``).
        The sweep deliberately bypasses the per-servlet routing-store
        stats: those count what each servlet wrote, and a chunk's writer
        is not recorded, so debiting any one servlet would skew its
        counters; physical reclamation shows up in the node stores'
        stats and the per-node placement counters."""
        from ..gc import GCReport, GarbageCollector
        if incremental:
            return self.incremental_gc(
                pins=pins, extra_roots=extra_roots,
                extra_hooks=extra_hooks).collect(budget)
        roots, hooks = self._gc_roots_hooks(pins, extra_roots, extra_hooks)
        # epoch fence: heads committed by attestations still in their
        # grace window survive STW collections too
        self.gc_fence.begin_epoch()
        roots |= self.gc_fence.grace_roots()
        gc = GarbageCollector(self.nodes[0].servlet.store,
                              extra_roots=roots, ref_hooks=hooks)
        live, rounds, missing = gc.mark()
        by_node: dict[int, list[bytes]] = {}
        for cid, node in self.index.items():
            if cid not in live:
                by_node.setdefault(node, []).append(cid)
        swept = reclaimed = compacted = 0
        for ni, cs in by_node.items():
            n, freed = _delete_on_node(self, ni, sorted(cs))
            swept += n
            reclaimed += freed
            nst = self.nodes[ni].store.stats
            c0 = nst.compacted_bytes
            self.nodes[ni].store.flush()  # durable tombstones if logged;
            #   on a durable store this flush feeds the segment compactor
            compacted += nst.compacted_bytes - c0
        self._rebase_build_work()
        report = GCReport(roots=len(roots), live_chunks=len(live),
                          swept_chunks=swept, reclaimed_bytes=reclaimed,
                          mark_rounds=rounds, missing_roots=missing,
                          compacted_bytes=compacted)
        obs.record_gc_report(report)
        obs.emit("gc.done", mode="stw", scope="cluster",
                 swept=swept, reclaimed_bytes=reclaimed)
        return report

    def incremental_gc(self, pins=None, extra_roots=(), extra_hooks=()):
        """Begin a cluster-wide incremental collection epoch and return
        its ``gc.IncrementalCollector`` (already in MARK).  The root set
        is an epoch-numbered snapshot — one branch-table copy per
        servlet taken here — so servlets keep committing during the
        distributed mark; write barriers are installed on EVERY
        servlet's routing store, and the sweep fans out per owning node
        in budget-bounded slices via the master index."""
        from ..gc import IncrementalCollector
        roots, hooks = self._gc_roots_hooks(pins, extra_roots, extra_hooks)
        col = IncrementalCollector(
            self.nodes[0].servlet.store, extra_roots=roots,
            ref_hooks=hooks,
            barrier_stores=[n.servlet.store for n in self.nodes],
            inventory_fn=lambda: list(self.index),
            sweep_fn=self._sweep_slice,
            flush_fn=self._flush_nodes,
            on_done=lambda report: self._rebase_build_work(),
            fence=self.gc_fence)
        col.begin()
        for node in self.nodes:      # fork-from-uid / pin root barriers
            node.servlet._track_collector(col)
        return col

    def _sweep_slice(self, cids) -> tuple[int, int]:
        """One bounded sweep slice, fanned out per owning node."""
        by_node: dict[int, list[bytes]] = {}
        for cid in cids:
            ni = self.index.get(cid)
            if ni is not None:
                by_node.setdefault(ni, []).append(cid)
        swept = freed = 0
        for ni, cs in by_node.items():
            n, f = _delete_on_node(self, ni, sorted(cs))
            swept += n
            freed += f
        return swept, freed

    def _flush_nodes(self) -> None:
        for node in self.nodes:
            node.store.flush()       # durable tombstones if logged

    def _rebase_build_work(self) -> None:
        """GC-aware rebalancing (ROADMAP): after a collection, re-anchor
        the construction-pressure counters on the post-GC LIVE byte
        distribution instead of gross bytes ever written — a node whose
        data was mostly collected must stop repelling new construction
        work, and a node dense with live chunks must keep delegating."""
        for n in self.nodes:
            n.stats.build_work = max(0, n.stats.chunk_bytes)

    # ---- audit RPC verbs (proof subsystem) ----
    def attest(self, context: bytes = b"", secret: bytes | None = None):
        """Dispatcher attestation: one Merkle commitment per servlet's
        branch table plus a cluster root over the servlet roots — a
        light client pins the cluster root and drills into any node.
        Returns (cluster Attestation, per-servlet attestations)."""
        from ..proof.attest import (Attestation, leaf_hash, merkle_root,
                                    sign)
        from ..proof.delta import pack_epoch
        atts = [nd.servlet.attest(
                    context=bytes(context) + b"|node%d" % i, secret=secret)
                for i, nd in enumerate(self.nodes)]
        cluster_att = Attestation(
            merkle_root([leaf_hash(a.root) for a in atts]),
            len(atts), pack_epoch(self.gc_fence.epoch, bytes(context)))
        return ((sign(cluster_att, secret) if secret is not None
                 else cluster_att), atts)

    def audit(self, sample: int = 64, seed: int = 0,
              secret: bytes | None = None):
        """Cluster-wide audit: master-index placement spot checks,
        per-servlet head/membership/lineage proof verification, and
        key-routing divergence — reported per offending node."""
        from ..proof.audit import Auditor
        return Auditor(sample=sample, seed=seed).audit_cluster(
            self, secret=secret)

    def audit_daemon(self, *, sample: int = 32, seed: int = 0,
                     secret: bytes | None = None, base_interval: int = 1,
                     max_interval: int = 64):
        """The persistent continuous-audit daemon for this cluster
        (proof.AuditDaemon): call ``tick(budget)`` from the serving
        loop.  One daemon per cluster — repeated calls return it (pass
        different knobs by constructing proof.AuditDaemon directly)."""
        from ..proof.audit import AuditDaemon
        if self._audit_daemon is None:
            self._audit_daemon = AuditDaemon(
                self, sample=sample, seed=seed, secret=secret,
                base_interval=base_interval, max_interval=max_interval)
        return self._audit_daemon

    def audit_tick(self, budget: int = 1):
        """One continuous-audit tick (see ``audit_daemon``): audits at
        most ``budget`` due targets and returns the tick's AuditReport."""
        return self.audit_daemon().tick(budget)

    # ---- §4.6.1 construction rebalancing ----
    def _build_servlet_for(self, key, value) -> ForkBase:
        """POS-Tree construction is CPU-heavy; an overloaded servlet locks
        the branch table and delegates chunking to the least-loaded peer,
        embedding the returned root cid itself.  We model load with the
        build_work counter; the branch-table update always happens on the
        key's home servlet (returned here)."""
        owner = self.nodes[self._home_index(key)]
        owner.stats.requests += 1             # one dispatch, counted once
        size = _value_size(value)
        hi = max(self.nodes, key=lambda n: n.stats.build_work)
        lo = min(self.nodes, key=lambda n: n.stats.build_work)
        if (owner is hi and owner.stats.build_work >
                2 * max(1, lo.stats.build_work) and size > 0):
            lo.stats.build_work += size        # delegated construction
        else:
            owner.stats.build_work += size
        return owner.servlet

    # ---- stats ----
    def observe(self) -> dict:
        """Cluster-wide observability snapshot: the global registry /
        event journal / GC history plus every node store's StoreStats
        (and their cluster-wide rollup under ``stores.cluster``),
        per-node placement counters, and the quarantine set.  Pulled at
        snapshot time — node stats are read, never re-counted into
        registry counters.  JSON-safe."""
        from ..storage.backend import StoreStats
        rollup = StoreStats()
        stores = {}
        for i, nd in enumerate(self.nodes):
            rollup.merge(nd.store.stats)
            stores[f"node{i}"] = nd.store.stats
        stores["cluster"] = rollup
        quarantined = (sorted(self._audit_daemon.quarantined)
                       if self._audit_daemon is not None else [])
        extra = {"cluster": {
            "mode": self.mode,
            "nodes": [{"chunks": n.stats.chunks,
                       "chunk_bytes": n.stats.chunk_bytes,
                       "requests": n.stats.requests,
                       "build_work": n.stats.build_work}
                      for n in self.nodes],
            "index_size": len(self.index),
            "gc_epoch": self.gc_fence.epoch,
            "quarantined": quarantined,
        }}
        return obs.snapshot(stores=stores, extra=extra)

    def storage_distribution(self) -> list[int]:
        return [n.stats.chunk_bytes for n in self.nodes]

    def build_distribution(self) -> list[int]:
        return [n.stats.build_work for n in self.nodes]


def _value_size(value) -> int:
    if hasattr(value, "read"):
        try:
            return len(value)
        except Exception:
            return 0
    if hasattr(value, "encode") and not isinstance(value, str):
        return len(value.encode())
    if isinstance(value, (bytes, bytearray, str)):
        return len(value)
    return 0
