"""Distributed deployment (paper §4.1 Fig. 5, §4.6): master + request
dispatcher + servlets + chunk-storage pool, with hash-based two-layer
partitioning:

  1. dispatcher -> servlet : request-key hash;
  2. servlet   -> storage  : chunk cid hash (meta chunks stay local).

Because cids are cryptographic hashes, layer 2 spreads chunks uniformly
even under severely skewed key workloads (Fig. 15).  ``mode='1LP'``
reproduces the paper's one-layer baseline (all of a key's chunks stored on
its servlet's node).  Runs in-process; per-node byte/op counters stand in
for real placement, and POS-Tree construction rebalancing (§4.6.1) is a
work-queue transfer between servlets.
"""
from __future__ import annotations

import hashlib
import threading
from collections import deque
from dataclasses import dataclass, field

from . import chunk as ck
from .chunker import ChunkParams, DEFAULT_PARAMS
from .chunkstore import ChunkStore
from .db import ForkBase
from .locking import make_lock
from .. import obs
from ..errors import ConfigError, RoutingIndexMiss
from ..storage import BackendBase, resolve_cids
from ..storage.backend import group_by, put_via

__all__ = ["Cluster", "Node", "NodeStats", "RoutingIndexMiss"]


def _h(data: bytes) -> int:
    return int.from_bytes(hashlib.sha256(data).digest()[:8], "little")


@dataclass
class NodeStats:
    chunk_bytes: int = 0
    chunks: int = 0
    requests: int = 0
    build_work: int = 0      # POS-Tree construction work units (bytes)


def _delete_on_node(cluster: "Cluster", ni: int, cids,
                    stats=None) -> tuple[int, int]:
    """One node's share of a sweep: delete the chunks, debit the node's
    placement counters, drop master-index entries.  ``stats`` (optional,
    a routing store's) absorbs the delete/reclaim counters but is NEVER
    debited physical bytes: routing stats count what that servlet wrote,
    and the deleted chunk's writer is unknown, so a debit would skew the
    caller negative (physical truth lives in the node stores).  Returns
    (removed chunks, freed bytes)."""
    nd = cluster.nodes[ni]
    with nd.store_lock:
        d0 = nd.store.stats.deletes
        r0 = nd.store.stats.reclaimed_bytes
        nd.store.delete_many(cids)
        removed = nd.store.stats.deletes - d0
        freed = nd.store.stats.reclaimed_bytes - r0
    if stats is not None:
        stats.deletes += removed
        stats.reclaimed_bytes += freed
    nd.stats.chunks -= removed
    nd.stats.chunk_bytes -= freed
    with cluster._index_lock:
        for cid in cids:        # absent on the owner either way now
            cluster.index.pop(cid, None)
    return removed, freed


class _RoutingStore(BackendBase):
    """StorageBackend a servlet writes through: meta chunks pinned locally,
    data chunks placed by cid hash across the pool (2LP) or locally (1LP).
    Batched puts group chunks per target node — one put_many per node per
    batch, the cluster analogue of the §4.6.1 pipeline.  Reads go straight
    to the owning node (dispatcher fast path, §4.6)."""

    OBS_NAME = "routing"

    def __init__(self, cluster: "Cluster", home: int):
        super().__init__()
        self.cluster = cluster
        self.home = home

    def _owner(self, cid: bytes) -> int:
        """Hash placement (2LP) / home placement (1LP), walked past
        quarantined ring members: new chunks never land on a node the
        audit daemon has quarantined (enforcement, not advice)."""
        if self.cluster.mode == "1LP":
            return self.cluster._healthy_from(self.home)
        return self.cluster._healthy_from(
            _h(cid) % len(self.cluster.nodes))

    def _placement(self, raws):
        """owner_of for put batches: meta chunks pin to the home servlet
        (§4.6) — or its healthy ring successor while it is quarantined —
        and data chunks place by cid hash."""
        def owner(i, cid):
            if ck.chunk_type(raws[i]) == ck.META:
                return self.cluster._healthy_from(self.home)
            return self._owner(cid)
        return owner

    def _location(self, i, cid):
        """owner_of for read batches: master index only.  A missing
        entry is a typed ``RoutingIndexMiss`` — the old fallback to
        ``_owner(cid)`` sent the read to the hash owner, which holds no
        copy (meta chunks pin to their home servlet), so the failure
        surfaced as a generic miss from the WRONG node."""
        node = self.cluster.index.get(cid)
        if node is None:
            raise RoutingIndexMiss(bytes(cid))
        return node

    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        out = resolve_cids(raws, cids)
        st = self.stats
        st.put_batches += 1
        st.puts += len(raws)
        st.logical_bytes += sum(len(r) for r in raws)
        cluster = self.cluster
        for node, (_, cs, rs) in group_by(self._placement(raws),
                                          out, raws).items():
            n = cluster.nodes[node]
            with n.store_lock:
                _, new_chunks, new_bytes = put_via(st, n.store, rs, cs)
            n.stats.chunks += new_chunks
            n.stats.chunk_bytes += new_bytes
            with cluster._index_lock:
                for cid in cs:
                    cluster.index[cid] = node
        # listeners (GC write barrier) fire with NO routing locks held:
        # the collector lock nests inside servlet locks, never inside
        # index/store locks (canonical order: core.locking.LOCK_ORDER)
        self._notify_put(out)
        return out

    def _get_many_impl(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        st.gets += len(cids)
        out: list[bytes | None] = [None] * len(cids)
        for node, (idx, cs, _) in group_by(self._location, cids).items():
            n = self.cluster.nodes[node]
            n.stats.requests += len(cs)
            with n.store_lock:
                raws = n.store.get_many(cs)
            for i, raw in zip(idx, raws):
                out[i] = raw
        return out  # type: ignore[return-value]

    def has_many(self, cids) -> list[bool]:
        out = [False] * len(cids)
        index = self.cluster.index
        located = [(i, cid, index.get(cid)) for i, cid in enumerate(cids)]
        groups: dict[int, list[tuple[int, bytes]]] = {}
        for i, cid, node in located:     # unindexed cids stay False
            if node is not None:
                groups.setdefault(node, []).append((i, cid))
        for node, pairs in groups.items():
            n = self.cluster.nodes[node]
            with n.store_lock:
                present = n.store.has_many([cid for _, cid in pairs])
            for (i, _), p in zip(pairs, present):
                out[i] = p
        return out

    def _delete_many_impl(self, cids) -> int:
        """Sweep fan-out by owning node; the master index and per-node
        placement counters shrink with the deleted chunks.  Unindexed
        cids are already gone — deleting them is a no-op, not a miss."""
        index = self.cluster.index
        groups: dict[int, list[bytes]] = {}
        for cid in cids:
            node = index.get(cid)
            if node is not None:
                groups.setdefault(node, []).append(cid)
        n = 0
        for node, cs in groups.items():
            n += _delete_on_node(self.cluster, node, cs, self.stats)[0]
        return n

    def iter_cids(self):
        """THIS servlet's share of the sweep/audit inventory: the chunks
        resident on its home node, streamed lazily from the node store
        (no cluster-wide list copy).  Per-servlet inventories are
        disjoint and union to the master index — a cluster-wide walk
        visits every chunk exactly once instead of N times.  ``len()``
        stays cluster-wide (the index size): the routing store is the
        servlet's window onto ONE shared pool, and dedup/put accounting
        (``put_via``) must see pool-wide existence."""
        return self.cluster.nodes[self.home].store.iter_cids()

    def __len__(self) -> int:
        return len(self.cluster.index)

    def flush(self) -> None:
        for n in self.cluster.nodes:
            with n.store_lock:
                n.store.flush()


@dataclass
class Node:
    store: ChunkStore
    stats: NodeStats
    servlet: ForkBase | None = None
    # Per-servlet mutual exclusion: held by the runtime's dispatcher
    # workers and by Cluster's public verbs around any touch of this
    # node's ForkBase (branch table, live tables, pins).  RLock so a
    # verb that is already inside the servlet lock (e.g. commit_epoch
    # folding into put) can re-enter.  Rank "servlet" — THE outermost
    # lock; the canonical order lives in core.locking.LOCK_ORDER.
    lock: threading.RLock = field(
        default_factory=lambda: make_lock("servlet"))
    # Cross-thread access to the node's chunk store (durable segment
    # stores mutate shared hot-tier/segment state on every op).  Rank
    # "store": innermost alongside "index" (see core.locking).
    store_lock: threading.RLock = field(
        default_factory=lambda: make_lock("store"))


class Cluster:
    """In-process ForkBase cluster."""

    def __init__(self, n_nodes: int = 4, mode: str = "2LP",
                 params: ChunkParams = DEFAULT_PARAMS,
                 verify: bool = False, *,
                 durable_root: str | None = None,
                 hot_bytes: int = 64 << 20,
                 segment_bytes: int = 4 << 20):
        if mode not in ("1LP", "2LP"):
            raise ConfigError(f"unknown placement mode {mode!r} "
                              "(expected '1LP' or '2LP')")
        self.mode = mode
        self.params = params
        self.durable_root = durable_root
        self.index: dict[bytes, int] = {}   # master's chunk location map
        # guards the master index and the quarantine/re-replication
        # state below; rank "index" — innermost alongside Node.store_lock
        # (canonical order in core.locking.LOCK_ORDER) — never held
        # across a store op or a listener callback
        self._index_lock = make_lock("index")
        # audit-enforced quarantine: node ids placement must route
        # around.  Populated via quarantine_node() (called by the audit
        # daemon at audit.quarantine time — enforcement works even with
        # REPRO_OBS=0 because it is a direct call, not an event tap).
        self.quarantined: set[int] = set()
        # re-replication backlog: (cid, source node) pairs snapshotted
        # when a node is quarantined, drained in budgeted slices by
        # rereplicate_step() (the MaintenanceDaemon's job)
        self._rerep_queue: deque[tuple[bytes, int]] = deque()
        self.rerep_done = 0      # chunks copied off quarantined nodes
        self.rerep_lost = 0      # chunks found corrupt/missing at rerep
        # one attestation/GC epoch fence for the whole cluster:
        # collections are cluster-wide, so servlet attestations pin into
        # (and collections consume from) the dispatcher's fence
        from ..gc.incremental import EpochFence
        self.gc_fence = EpochFence()
        self._audit_daemon = None
        if durable_root is None:
            stores = [ChunkStore(verify=verify) for _ in range(n_nodes)]
        else:
            # durable pool: each node's chunks live in a tiered segment
            # store under ``durable_root/node-XX``; reopening the same
            # root resumes the cluster (see ``sync``/``_restore_durable``)
            from ..storage.durable import open_durable
            stores = [open_durable(self._node_root(i), hot_bytes=hot_bytes,
                                   segment_bytes=segment_bytes,
                                   verify=verify)
                      for i in range(n_nodes)]
        self.nodes = [Node(store, NodeStats()) for store in stores]
        for i, node in enumerate(self.nodes):
            node.servlet = ForkBase(_RoutingStore(self, i), params)
        if durable_root is not None:
            self._restore_durable()
        # bloom spill path of the shared fence recovers cap-overflowed
        # pins by filtering the cluster-wide current heads
        self.gc_fence.heads_fn = self._all_heads

    # ---- durable pool (storage.durable) ----
    def _node_root(self, i: int) -> str:
        import os
        return os.path.join(self.durable_root, f"node-{i:02d}")

    def _heads_path(self, i: int) -> str:
        import os
        return os.path.join(self._node_root(i), "heads.json")

    def _restore_durable(self) -> None:
        """Resume a durable cluster: reload each servlet's branch heads
        from its last ``sync()`` snapshot and rebuild the master chunk
        location map by streaming every node store's cids (meta chunks
        are pinned to their home servlet, so the hash-placement fallback
        of ``_location`` alone would misroute them after a restart)."""
        import os
        for i, node in enumerate(self.nodes):
            path = self._heads_path(i)
            if os.path.exists(path):
                with open(path, "rb") as f:
                    node.servlet.branches.restore(f.read())
            for cid in node.store.iter_cids():
                self.index[cid] = i
            node.stats.chunks = len(node.store)
            node.stats.chunk_bytes = node.store.stats.physical_bytes
            node.stats.build_work = node.stats.chunk_bytes

    def sync(self) -> None:
        """Cluster durability point: flush every node store (hot-tier
        write-back + segment fsync + GC-fed compaction) and atomically
        snapshot every servlet's branch heads.  After ``sync()``, a new
        ``Cluster(durable_root=...)`` over the same root resumes with
        bit-identical heads.  A plain flush when not durable."""
        for i, node in enumerate(self.nodes):
            node.store.flush()
            if self.durable_root is not None:
                from ..storage.durable import write_durably
                write_durably(self._heads_path(i),
                              node.servlet.branches.snapshot())

    def _all_heads(self) -> set[bytes]:
        """Cluster-wide current heads.  Takes each servlet lock one at
        a time (never two at once — no deadlock window with verbs that
        hold one servlet lock).  Callers (fence grace roots, collector
        begin) hold NO collector/fence lock here, per the lock order
        servlet ≺ collector ≺ {index, store}."""
        out: set[bytes] = set()
        for node in self.nodes:
            with node.lock:
                out |= node.servlet.branches.all_heads()
        return out

    # ---- dispatcher (layer 1) ----
    def _home_index(self, key) -> int:
        """Key-hash routing (hashed exactly once per dispatch)."""
        key = key.encode() if isinstance(key, str) else bytes(key)
        return _h(key) % len(self.nodes)

    def servlet_of(self, key: bytes) -> ForkBase:
        return self._node_of(key).servlet

    def _node_of(self, key) -> Node:
        i = self._home_index(key)
        self.nodes[i].stats.requests += 1
        return self.nodes[i]

    # ---- quarantine enforcement + re-replication ----
    def _healthy_from(self, start: int) -> int:
        """First non-quarantined ring member at or after ``start``
        (clockwise walk).  If EVERY node is quarantined the walk gives
        up and returns ``start`` — refusing all writes would wedge the
        cluster, and the audit findings already flag the whole pool."""
        q = self.quarantined
        if not q:                       # fast path: healthy cluster
            return start
        n = len(self.nodes)
        for j in range(n):
            ni = (start + j) % n
            if ni not in q:
                return ni
        return start

    def quarantine_node(self, ni: int, *, reason: str = "") -> int:
        """ENFORCE a quarantine (not just record it): placement stops
        routing new chunks to node ``ni`` (``_healthy_from`` walks past
        it) and its current chunk inventory — per the master index — is
        snapshotted into the re-replication backlog for budgeted
        draining by ``rereplicate_step``.  Idempotent.  Called by the
        audit daemon at the ``audit.quarantine`` emit point as a DIRECT
        call, so enforcement holds with REPRO_OBS=0.  Returns the
        number of chunks queued."""
        with self._index_lock:
            if ni in self.quarantined:
                return 0
            self.quarantined.add(ni)
            queued = [cid for cid, node in self.index.items()
                      if node == ni]
            self._rerep_queue.extend((cid, ni) for cid in queued)
        obs.emit("cluster.quarantine_enforced", node=f"node{ni}",
                 reason=reason, backlog=len(queued))
        return len(queued)

    def release_node(self, ni: int) -> None:
        """Lift a quarantine: ``ni`` rejoins placement.  Chunks already
        re-replicated stay where they landed (the index is truth);
        entries still queued for this node are dropped unprocessed."""
        with self._index_lock:
            if ni not in self.quarantined:
                return
            self.quarantined.discard(ni)
            self._rerep_queue = deque(
                e for e in self._rerep_queue if e[1] != ni)
        obs.emit("cluster.release_enforced", node=f"node{ni}")

    def rerep_backlog(self) -> int:
        with self._index_lock:
            return len(self._rerep_queue)

    def rereplicate_step(self, budget: int = 64) -> int:
        """Drain up to ``budget`` re-replication entries: copy each
        chunk off its quarantined source to the healthy hash-ring
        owner, redirect the master index, then drop the source copy
        (store delete only — no index pop, the entry now points at the
        destination).  A source copy that is missing or fails its
        content-hash check is instead *dropped from the index*:
        subsequent reads get the typed ``RoutingIndexMiss``, which is
        honest, rather than being routed to a node known to serve bad
        bytes.  Returns entries processed (0 ⇒ backlog empty)."""
        done = 0
        while done < budget:
            with self._index_lock:
                if not self._rerep_queue:
                    break
                cid, src = self._rerep_queue.popleft()
                cur = self.index.get(cid)
            done += 1
            if cur != src:
                continue            # swept or already moved
            sn = self.nodes[src]
            with sn.store_lock:
                raw = (sn.store.get_many([cid])[0]
                       if sn.store.has(cid) else None)
            if raw is None or resolve_cids([raw], None)[0] != cid:
                with self._index_lock:
                    if self.index.get(cid) == src:
                        self.index.pop(cid, None)
                self.rerep_lost += 1
                obs.emit("cluster.rerep_lost", node=f"node{src}",
                         cid=cid)
                continue
            dst = self._healthy_from(_h(cid) % len(self.nodes))
            if dst == src:          # whole pool quarantined: leave it
                continue
            dn = self.nodes[dst]
            with dn.store_lock:
                c0 = len(dn.store)
                p0 = dn.store.stats.physical_bytes
                dn.store.put_many([raw], [cid])
                dn.stats.chunks += len(dn.store) - c0
                dn.stats.chunk_bytes += dn.store.stats.physical_bytes - p0
            with self._index_lock:
                if self.index.get(cid) == src:
                    self.index[cid] = dst
            with sn.store_lock:
                d0 = sn.store.stats.deletes
                r0 = sn.store.stats.reclaimed_bytes
                sn.store.delete_many([cid])
                sn.stats.chunks -= sn.store.stats.deletes - d0
                sn.stats.chunk_bytes -= (sn.store.stats.reclaimed_bytes
                                         - r0)
            self.rerep_done += 1
        if done:
            obs.emit("cluster.rerep_step", processed=done,
                     backlog=self.rerep_backlog())
        return done

    def rereplicate(self, slice_budget: int = 256) -> int:
        """Drain the whole re-replication backlog (loops
        ``rereplicate_step``).  Returns total entries processed."""
        total = 0
        while True:
            n = self.rereplicate_step(slice_budget)
            if not n:
                return total
            total += n

    # public API mirrors ForkBase, routed per key.  Each verb holds the
    # key's home-servlet lock for its duration: ForkBase branch tables,
    # live tables, and pin sets are not internally synchronized, and the
    # async runtime (core.runtime) calls these from dispatcher workers.
    def put(self, key, value, branch=None, **kw):
        with obs.trace("cluster.put", key=key if isinstance(key, (bytes,
                       str)) else str(key)):
            nd = self._build_node_for(key, value)
            with nd.lock:
                return nd.servlet.put(key, value, branch, **kw)

    def get(self, key, branch=None, **kw):
        nd = self._node_of(key)
        with nd.lock:
            return nd.servlet.get(key, branch, **kw)

    def fork(self, key, ref, new_branch):
        nd = self._node_of(key)
        with nd.lock:
            return nd.servlet.fork(key, ref, new_branch)

    def merge(self, key, target, *refs, **kw):
        nd = self._node_of(key)
        with nd.lock:
            return nd.servlet.merge(key, target, *refs, **kw)

    def track(self, key, ref, dist_rng=(0, 1 << 30)):
        nd = self._node_of(key)
        with nd.lock:
            return nd.servlet.track(key, ref, dist_rng)

    def remove(self, key, branch):
        nd = self._node_of(key)
        with nd.lock:
            return nd.servlet.remove(key, branch)

    # ---- batched verbs (async runtime's coalesced dispatch) ----
    def put_batch(self, requests):
        """Coalesced multi-client put: ``requests`` is a sequence of
        (key, value, branch, kwargs) tuples.  Requests group by home
        servlet; each group commits through ONE shared WriteBuffer —
        one routing ``put_many`` fan-out per storage node per group
        instead of one per request (the §4.6.1 WriteBuffer idea lifted
        to the RPC layer).  Returns uids in request order."""
        groups: dict[int, list[tuple[int, tuple]]] = {}
        for i, req in enumerate(requests):
            groups.setdefault(self._home_index(req[0]), []).append(
                (i, req))
        out: list[bytes | None] = [None] * len(requests)
        for ni, batch in groups.items():
            nd = self.nodes[ni]
            nd.stats.requests += len(batch)
            with nd.lock:
                uids = nd.servlet.put_batch([r for _, r in batch])
            for (i, _), uid in zip(batch, uids):
                out[i] = uid
        return out

    def get_batch(self, requests):
        """Coalesced multi-client get: ``requests`` is a sequence of
        (key, branch, kwargs) tuples; per-servlet groups resolve heads
        then issue ONE batched chunk read.  Returns values in request
        order."""
        groups: dict[int, list[tuple[int, tuple]]] = {}
        for i, req in enumerate(requests):
            groups.setdefault(self._home_index(req[0]), []).append(
                (i, req))
        out: list = [None] * len(requests)
        for ni, batch in groups.items():
            nd = self.nodes[ni]
            nd.stats.requests += len(batch)
            with nd.lock:
                vals = nd.servlet.get_batch([r for _, r in batch])
            for (i, _), v in zip(batch, vals):
                out[i] = v
        return out

    # ---- live fast path (repro.live), routed per key ----
    def live(self, key, branch=None, *, policy=None):
        """The key's home servlet's LiveTable — hot traffic is served
        off the flat path while the POS-Tree archive (and its 2LP chunk
        placement) is only touched at epoch folds."""
        nd = self._node_of(key)
        with nd.lock:
            return nd.servlet.live(key, branch, policy=policy)

    def commit_epoch(self, context: bytes = b"", *, attest: bool = False,
                     secret: bytes | None = None):
        """Cluster epoch boundary: fold every servlet's dirty live
        tables (each fold is one batched Put on its home servlet) and
        optionally attest per servlet.  Returns the per-servlet
        live.EpochReports.  Locks are taken one servlet at a time, so
        foreground verbs on other servlets proceed during the fold."""
        out = []
        for node in self.nodes:
            with node.lock:
                out.append(node.servlet.commit_epoch(
                    context, attest=attest, secret=secret))
        return out

    def commit_epoch_on(self, ni: int, context: bytes = b"", *,
                        attest: bool = False,
                        secret: bytes | None = None):
        """One servlet's epoch fold (the MaintenanceDaemon staggers
        folds across ticks so no single tick stalls every servlet)."""
        node = self.nodes[ni]
        with node.lock:
            return node.servlet.commit_epoch(context, attest=attest,
                                             secret=secret)

    # ---- garbage collection (cluster-wide) ----
    def _gc_roots_hooks(self, pins, extra_roots, extra_hooks):
        """Global root-set snapshot: union every servlet's TB/UB heads
        (branch-table copy per servlet) plus servlet pin sets, optional
        extra ``pins``, and caller-supplied roots/hooks — e.g. an
        external ForkBase sharing a routing store."""
        roots: set[bytes] = set(extra_roots)
        hooks: list = list(extra_hooks)
        for node in self.nodes:
            with node.lock:
                roots |= node.servlet.branches.all_heads()
                roots |= node.servlet.pins.uids()
                hooks.extend(h for h in node.servlet.gc_hooks
                             if h not in hooks)
        if pins is not None:
            roots |= pins.uids()
        return roots, hooks

    def gc(self, pins=None, extra_roots=(), extra_hooks=(), *,
           incremental: bool = False, budget: int = 256):
        """Cluster mark-and-sweep: the dispatcher unions every servlet's
        TB/UB heads (plus servlet pin sets, optional extra ``pins``, and
        any caller-supplied ``extra_roots``/``extra_hooks`` — e.g. an
        external ForkBase sharing a routing store) into one global root
        set, marks through the routing store — reads fan out to owning
        nodes via the master index, one batch per node per BFS level —
        then sweeps each node's *own* chunk store and the master index.
        ``incremental=True`` runs the same collection as an epoch of
        ``budget``-bounded slices (see ``incremental_gc``).
        The sweep deliberately bypasses the per-servlet routing-store
        stats: those count what each servlet wrote, and a chunk's writer
        is not recorded, so debiting any one servlet would skew its
        counters; physical reclamation shows up in the node stores'
        stats and the per-node placement counters."""
        from ..gc import GCReport, GarbageCollector
        if incremental:
            return self.incremental_gc(
                pins=pins, extra_roots=extra_roots,
                extra_hooks=extra_hooks).collect(budget)
        roots, hooks = self._gc_roots_hooks(pins, extra_roots, extra_hooks)
        # epoch fence: heads committed by attestations still in their
        # grace window survive STW collections too
        self.gc_fence.begin_epoch()
        roots |= self.gc_fence.grace_roots()
        gc = GarbageCollector(self.nodes[0].servlet.store,
                              extra_roots=roots, ref_hooks=hooks)
        live, rounds, missing = gc.mark()
        with self._index_lock:
            placed = list(self.index.items())
        by_node: dict[int, list[bytes]] = {}
        for cid, node in placed:
            if cid not in live:
                by_node.setdefault(node, []).append(cid)
        swept = reclaimed = compacted = 0
        for ni, cs in by_node.items():
            n, freed = _delete_on_node(self, ni, sorted(cs))
            swept += n
            reclaimed += freed
            nst = self.nodes[ni].store.stats
            c0 = nst.compacted_bytes
            self.nodes[ni].store.flush()  # durable tombstones if logged;
            #   on a durable store this flush feeds the segment compactor
            compacted += nst.compacted_bytes - c0
        self._rebase_build_work()
        report = GCReport(roots=len(roots), live_chunks=len(live),
                          swept_chunks=swept, reclaimed_bytes=reclaimed,
                          mark_rounds=rounds, missing_roots=missing,
                          compacted_bytes=compacted)
        obs.record_gc_report(report)
        obs.emit("gc.done", mode="stw", scope="cluster",
                 swept=swept, reclaimed_bytes=reclaimed)
        return report

    def incremental_gc(self, pins=None, extra_roots=(), extra_hooks=()):
        """Begin a cluster-wide incremental collection epoch and return
        its ``gc.IncrementalCollector`` (already in MARK).  The root set
        is an epoch-numbered snapshot — one branch-table copy per
        servlet taken here — so servlets keep committing during the
        distributed mark; write barriers are installed on EVERY
        servlet's routing store, and the sweep fans out per owning node
        in budget-bounded slices via the master index."""
        from contextlib import ExitStack
        from ..gc import IncrementalCollector
        # The root snapshot and the barrier installation must be ONE
        # atomic event w.r.t. committers: a put landing between the
        # branch-table copy and ``add_put_listener`` would move a head
        # whose chunks are neither rooted nor barriered — white to the
        # mark, condemned by the freeze, swept while fully live.  Every
        # servlet lock is held (ascending order; all other verbs take at
        # most one at a time, so the ordered sweep cannot deadlock) for
        # the duration of ``begin()`` — a bounded pause (root copy plus
        # one ``has_many``), not the mark itself.
        with ExitStack() as stack:
            for node in self.nodes:
                stack.enter_context(node.lock)
            roots, hooks = self._gc_roots_hooks(pins, extra_roots,
                                                extra_hooks)
            col = IncrementalCollector(
                self.nodes[0].servlet.store, extra_roots=roots,
                ref_hooks=hooks,
                barrier_stores=[n.servlet.store for n in self.nodes],
                inventory_fn=self._index_snapshot,
                sweep_fn=self._sweep_slice,
                flush_fn=self._flush_nodes,
                on_done=lambda report: self._rebase_build_work(),
                fence=self.gc_fence)
            col.begin()
            for node in self.nodes:  # fork-from-uid / pin root barriers
                node.servlet._track_collector(col)
        return col

    def _index_snapshot(self) -> list[bytes]:
        with self._index_lock:
            return list(self.index)

    def _sweep_slice(self, cids) -> tuple[int, int]:
        """One bounded sweep slice, fanned out per owning node."""
        with self._index_lock:
            located = [(cid, self.index.get(cid)) for cid in cids]
        by_node: dict[int, list[bytes]] = {}
        for cid, ni in located:
            if ni is not None:
                by_node.setdefault(ni, []).append(cid)
        swept = freed = 0
        for ni, cs in by_node.items():
            n, f = _delete_on_node(self, ni, sorted(cs))
            swept += n
            freed += f
        return swept, freed

    def _flush_nodes(self) -> None:
        for node in self.nodes:
            node.store.flush()       # durable tombstones if logged

    def _rebase_build_work(self) -> None:
        """GC-aware rebalancing (ROADMAP): after a collection, re-anchor
        the construction-pressure counters on the post-GC LIVE byte
        distribution instead of gross bytes ever written — a node whose
        data was mostly collected must stop repelling new construction
        work, and a node dense with live chunks must keep delegating."""
        for n in self.nodes:
            n.stats.build_work = max(0, n.stats.chunk_bytes)

    # ---- audit RPC verbs (proof subsystem) ----
    def attest(self, context: bytes = b"", secret: bytes | None = None):
        """Dispatcher attestation: one Merkle commitment per servlet's
        branch table plus a cluster root over the servlet roots — a
        light client pins the cluster root and drills into any node.
        Returns (cluster Attestation, per-servlet attestations)."""
        from ..proof.attest import (Attestation, leaf_hash, merkle_root,
                                    sign)
        from ..proof.delta import pack_epoch
        atts = []
        for i, nd in enumerate(self.nodes):
            with nd.lock:
                atts.append(nd.servlet.attest(
                    context=bytes(context) + b"|node%d" % i,
                    secret=secret))
        cluster_att = Attestation(
            merkle_root([leaf_hash(a.root) for a in atts]),
            len(atts), pack_epoch(self.gc_fence.epoch, bytes(context)))
        return ((sign(cluster_att, secret) if secret is not None
                 else cluster_att), atts)

    def audit(self, sample: int = 64, seed: int = 0,
              secret: bytes | None = None):
        """Cluster-wide audit: master-index placement spot checks,
        per-servlet head/membership/lineage proof verification, and
        key-routing divergence — reported per offending node."""
        from ..proof.audit import Auditor
        return Auditor(sample=sample, seed=seed).audit_cluster(
            self, secret=secret)

    def audit_daemon(self, *, sample: int = 32, seed: int = 0,
                     secret: bytes | None = None, base_interval: int = 1,
                     max_interval: int = 64):
        """The persistent continuous-audit daemon for this cluster
        (proof.AuditDaemon): call ``tick(budget)`` from the serving
        loop.  One daemon per cluster — repeated calls return it (pass
        different knobs by constructing proof.AuditDaemon directly)."""
        from ..proof.audit import AuditDaemon
        if self._audit_daemon is None:
            self._audit_daemon = AuditDaemon(
                self, sample=sample, seed=seed, secret=secret,
                base_interval=base_interval, max_interval=max_interval)
        return self._audit_daemon

    def audit_tick(self, budget: int = 1):
        """One continuous-audit tick (see ``audit_daemon``): audits at
        most ``budget`` due targets and returns the tick's AuditReport."""
        return self.audit_daemon().tick(budget)

    # ---- async runtime (core.runtime) ----
    def runtime(self, config=None) -> "object":
        """An event-driven ClusterRuntime over this cluster: bounded
        per-servlet queues with obs-driven admission control, coalesced
        cross-client dispatch, and a time-paced MaintenanceDaemon (see
        core.runtime).  A new runtime per call — callers own start/stop."""
        from .runtime import ClusterRuntime
        return ClusterRuntime(self, config)

    # ---- §4.6.1 construction rebalancing ----
    def _build_node_for(self, key, value) -> Node:
        """POS-Tree construction is CPU-heavy; an overloaded servlet locks
        the branch table and delegates chunking to the least-loaded peer,
        embedding the returned root cid itself.  We model load with the
        build_work counter; the branch-table update always happens on the
        key's home servlet (whose Node is returned here)."""
        owner = self.nodes[self._home_index(key)]
        owner.stats.requests += 1             # one dispatch, counted once
        size = _value_size(value)
        hi = max(self.nodes, key=lambda n: n.stats.build_work)
        lo = min(self.nodes, key=lambda n: n.stats.build_work)
        if (owner is hi and owner.stats.build_work >
                2 * max(1, lo.stats.build_work) and size > 0):
            lo.stats.build_work += size        # delegated construction
        else:
            owner.stats.build_work += size
        return owner

    # ---- stats ----
    def observe(self) -> dict:
        """Cluster-wide observability snapshot: the global registry /
        event journal / GC history plus every node store's StoreStats
        (and their cluster-wide rollup under ``stores.cluster``),
        per-node placement counters, and the quarantine set.  Pulled at
        snapshot time — node stats are read, never re-counted into
        registry counters.  JSON-safe."""
        from ..storage.backend import StoreStats
        rollup = StoreStats()
        stores = {}
        for i, nd in enumerate(self.nodes):
            rollup.merge(nd.store.stats)
            stores[f"node{i}"] = nd.store.stats
        stores["cluster"] = rollup
        quarantined = (sorted(self._audit_daemon.quarantined)
                       if self._audit_daemon is not None else [])
        extra = {"cluster": {
            "mode": self.mode,
            "nodes": [{"chunks": n.stats.chunks,
                       "chunk_bytes": n.stats.chunk_bytes,
                       "requests": n.stats.requests,
                       "build_work": n.stats.build_work}
                      for n in self.nodes],
            "index_size": len(self.index),
            "gc_epoch": self.gc_fence.epoch,
            "quarantined": quarantined,
            # enforcement view (routing layer), distinct from the audit
            # daemon's finding view above
            "quarantined_enforced": sorted(self.quarantined),
            "rerep_backlog": self.rerep_backlog(),
            "rerep_done": self.rerep_done,
            "rerep_lost": self.rerep_lost,
        }}
        return obs.snapshot(stores=stores, extra=extra)

    def storage_distribution(self) -> list[int]:
        return [n.stats.chunk_bytes for n in self.nodes]

    def build_distribution(self) -> list[int]:
        return [n.stats.build_work for n in self.nodes]


def _value_size(value) -> int:
    if hasattr(value, "read"):
        try:
            return len(value)
        except Exception:
            return 0
    if hasattr(value, "encode") and not isinstance(value, str):
        return len(value.encode())
    if isinstance(value, (bytes, bytearray, str)):
        return len(value)
    return 0
