"""ForkBase connector — the public API (paper Table 1, M1–M17 + guarded
Put §4.5.1 + Diff §3.2).

Both fork semantics are first-class:
  * Fork-on-Demand  (FoD): named (tagged) branches, explicit Fork/Merge;
  * Fork-on-Conflict (FoC): ``Put(key, base_uid, value)`` against an already
    derived base implicitly forks; the UB-table tracks the resulting
    untagged heads and ``Merge(key, uid1, uid2, ...)`` reconciles them.
"""
from __future__ import annotations

import os
from typing import Iterable

from . import chunk as ck
from . import merge as mg
from .branch import (DEFAULT_BRANCH, BranchTable, GuardFailed,
                     NoSuchRef)
from .chunker import ChunkParams, DEFAULT_PARAMS
from .chunkstore import ChunkStore
from .. import obs
from ..storage import StorageBackend, WriteBuffer
from .fobject import (CHUNKABLE_TYPES, FObject, load_fobject, make_fobject)
from .postree import POSTree
from .types import (CHUNKABLE_CLASSES, FBlob, FInt, FList, FMap, FSet,
                    FString, FTuple, PRIMITIVE_CLASSES)


class TypeNotMatch(Exception):
    pass


class ValueHandle:
    """Typed view over a Get result (paper Fig. 4: value.Blob() etc.)."""

    def __init__(self, db: "ForkBase", obj: FObject):
        self.db = db
        self.obj = obj

    @property
    def type(self) -> int:
        return self.obj.type

    @property
    def uid(self) -> bytes:
        return self.obj.uid

    def _chunkable(self, kind: int):
        if self.obj.type != kind:
            raise TypeNotMatch(self.obj.type_name())
        tree = POSTree.from_root(self.db.store, kind, self.obj.data,
                                 self.db.params)
        return CHUNKABLE_CLASSES[kind].from_tree(tree)

    def blob(self) -> FBlob:
        return self._chunkable(ck.BLOB)

    def list(self) -> FList:
        return self._chunkable(ck.LIST)

    def map(self) -> FMap:
        return self._chunkable(ck.MAP)

    def set(self) -> FSet:
        return self._chunkable(ck.SET)

    def primitive(self):
        if self.obj.type not in PRIMITIVE_CLASSES:
            raise TypeNotMatch(self.obj.type_name())
        return PRIMITIVE_CLASSES[self.obj.type].decode(self.obj.data)

    def string(self) -> FString:
        if self.obj.type != FString.TYPE:
            raise TypeNotMatch(self.obj.type_name())
        return FString.decode(self.obj.data)

    def tuple(self) -> FTuple:
        if self.obj.type != FTuple.TYPE:
            raise TypeNotMatch(self.obj.type_name())
        return FTuple.decode(self.obj.data)

    def integer(self) -> FInt:
        if self.obj.type != FInt.TYPE:
            raise TypeNotMatch(self.obj.type_name())
        return FInt.decode(self.obj.data)


class ForkBase:
    """Embedded single-servlet engine (one servlet + one chunk storage,
    §4.1).  cluster.Cluster wires several of these behind a dispatcher."""

    def __init__(self, store: StorageBackend | None = None,
                 params: ChunkParams = DEFAULT_PARAMS, *,
                 verify_get: bool = False,
                 durable_root: str | None = None,
                 hot_bytes: int = 64 << 20,
                 segment_bytes: int = 4 << 20):
        # durable mode: chunks live in the tiered segment store under
        # ``durable_root`` and branch heads are reloaded from the last
        # ``sync()`` snapshot — reopening the same root resumes the
        # engine with bit-identical heads
        if store is None and durable_root is not None:
            from ..storage.durable import open_durable
            store = open_durable(durable_root, hot_bytes=hot_bytes,
                                 segment_bytes=segment_bytes,
                                 verify=verify_get)
        self._durable_root = durable_root
        self.store = store if store is not None else ChunkStore()
        self.params = params
        self._obs_get_tick = 7       # 1-in-8 get timing; first sampled
        # verify-on-get: every Get re-hashes the meta chunk against its
        # uid (per-call ``verify=`` overrides; checks count in StoreStats)
        self.verify_get = verify_get
        self.branches = BranchTable()
        if durable_root is not None:
            head_path = _heads_path(durable_root)
            if os.path.exists(head_path):
                with open(head_path, "rb") as f:
                    self.branches.restore(f.read())
        # explicit GC roots: in-flight readers / retention holds pin the
        # uids they need across a concurrent collect(); pinning mid-
        # collection fires the incremental root barrier
        from ..gc.incremental import EpochFence
        from ..gc.pins import PinSet
        self.pins = PinSet(on_pin=self._gc_root_barrier)
        # attestation/GC epoch handshake: attest() pins the heads it
        # commits to; collections root pins still in the grace window
        # (heads_fn backs the fence's bloom spill path: pins past the
        # memory cap are recovered by filtering current heads)
        self.gc_fence = EpochFence()
        self.gc_fence.heads_fn = self.branches.all_heads
        # live tables (flat-state fast path, repro.live): one per
        # (key, branch) head, folded into the archive at epoch
        # boundaries — see live() / commit_epoch()
        self._live: dict = {}
        # attest pin delta: keys whose heads moved since the last
        # attest; the first attest of a fence epoch pins the full head
        # baseline, subsequent ones pin only these keys' heads — O(k)
        self._attest_dirty: set[bytes] = set()
        self._attest_pin_epoch: int | None = None
        self.branches.add_listener(self._on_head_mutation)
        # incremental attestation state (proof.delta), built lazily on
        # the first attest()/prove_head()
        self._delta_attestor = None
        # per-root audit-path cache for prove_member/prove_absence
        from ..proof.membership import ProofCache
        self.proof_cache = ProofCache()
        # application-level link extractors (gc.mark ref_hooks): layers
        # that embed cids inside opaque values (ckpt manifests) register
        # here so gc() can trace through them
        self.gc_hooks: list = []
        # in-flight incremental collections this engine must barrier for
        # (store-level put barriers are installed by the collector; this
        # registry carries the *root* barrier: fork-from-uid, new pins)
        self.gc_collectors: list = []

    # ------------------------------------------------------------- put
    def _commit_value(self, value, store=None) -> tuple[int, bytes]:
        """Returns (object type, data field bytes)."""
        if store is None:
            store = self.store
        if hasattr(value, "commit"):          # chunkable handle
            root = value.commit(store)
            return value.TYPE, root
        if hasattr(value, "encode"):          # primitive
            return value.TYPE, value.encode()
        if isinstance(value, (bytes, bytearray, str)):
            v = value.encode() if isinstance(value, str) else bytes(value)
            return FString.TYPE, v
        raise TypeError(f"unsupported value: {type(value)}")

    def put(self, key: bytes, value, branch: str | None = None, *,
            base_uid: bytes | None = None, context: bytes = b"",
            guard_uid: bytes | None = None) -> bytes:
        """M3 (branch put), M4 (FoC put on a base version), guarded put."""
        with obs.trace("engine.put", key=key):
            return self._put_inner(key, value, branch, base_uid=base_uid,
                                   context=context, guard_uid=guard_uid)

    def _put_inner(self, key, value, branch, *, base_uid, context,
                   guard_uid) -> bytes:
        key = _k(key)
        if base_uid is not None:              # M4: fork-on-conflict path
            bases: tuple[bytes, ...] = (base_uid,)
            base_depth = load_fobject(self.store, base_uid).depth
        else:
            branch = branch or DEFAULT_BRANCH
            head = self.branches.head(key, branch)
            if guard_uid is not None and head != guard_uid:
                raise GuardFailed(branch)
            bases = (head,) if head else ()
            base_depth = (load_fobject(self.store, head).depth
                          if head else -1)
        # batched chunk pipeline (§4.6.1): every chunk of this value —
        # POS-Tree leaves, index nodes, the meta chunk — accumulates in
        # one WriteBuffer and hits the store as a single put_many.
        batch = WriteBuffer(self.store)
        t, data = self._commit_value(value, batch)
        obj = make_fobject(batch, t, key, data, bases, context,
                           base_depth)
        batch.flush()
        self.branches.on_new_version(key, obj.uid, bases,
                                     foc=base_uid is not None)
        if base_uid is None:
            self.branches.set_head(key, branch, obj.uid)
        return obj.uid

    # ------------------------------------------------------------- get
    def get(self, key: bytes, branch: str | None = None, *,
            uid: bytes | None = None,
            verify: bool | None = None) -> ValueHandle | None:
        """M1 (branch get) / M2 (version get).  ``verify`` (default: the
        engine's ``verify_get``) re-hashes the meta chunk against the uid
        and raises TamperedChunk on mismatch.

        Reads are histogram-only (``engine_get_us``), timed at a 1-in-8
        sample: a span (or even an unconditional timer) per get would
        tax the O(10µs) hot path the obs-overhead gate protects, so
        only the write verbs carry full span trees."""
        if not obs.REGISTRY.enabled:
            return self._get_inner(key, branch, uid=uid, verify=verify)
        self._obs_get_tick = tick = (self._obs_get_tick + 1) & 7
        if tick:
            return self._get_inner(key, branch, uid=uid, verify=verify)
        t0 = obs.monotonic()
        out = self._get_inner(key, branch, uid=uid, verify=verify)
        obs.REGISTRY.histogram("engine_get_us").observe(obs.monotonic() - t0)
        return out

    def _get_inner(self, key, branch, *, uid, verify):
        key = _k(key)
        if uid is None:
            uid = self.branches.head(key, branch or DEFAULT_BRANCH)
            if uid is None:
                return None
        verify = self.verify_get if verify is None else verify
        return ValueHandle(self, load_fobject(self.store, uid,
                                              verify=verify))

    # -------------------------------------------------- batched verbs
    def put_batch(self, requests) -> list[bytes]:
        """Coalesced multi-request put (the async runtime's dispatch
        unit): ``requests`` are ``(key, value)``, ``(key, value,
        branch)`` or ``(key, value, branch, kwargs)`` tuples.  Plain
        branch puts commit through ONE shared WriteBuffer — every
        value's tree chunks and meta chunk across the whole batch hit
        the store as a single put_many (the §4.6.1 chunk pipeline
        lifted to the request layer) — and same-key-same-branch
        requests chain within the batch exactly as sequential puts
        would (the buffer's overlay serves the base version's meta
        chunk before flush).  Head updates publish only after the
        flush, so a reader never sees a head whose chunks are still
        buffered.  Guarded / fork-on-conflict requests (``guard_uid``,
        ``base_uid``) need the real branch table: the batch flushes
        around them and they take the single-put path, order
        preserved.  Returns uids in request order."""
        out: list[bytes] = []
        with obs.trace("engine.put_batch", requests=len(requests)):
            batch: WriteBuffer | None = None
            heads: dict[tuple[bytes, str], bytes] = {}
            pending: list[tuple[bytes, str, bytes, tuple]] = []

            def _flush() -> None:
                nonlocal batch
                if batch is None:
                    return
                batch.flush()
                for key, branch, uid, bases in pending:
                    self.branches.on_new_version(key, uid, bases)
                    self.branches.set_head(key, branch, uid)
                pending.clear()
                heads.clear()
                batch = None

            for req in requests:
                key, value = req[0], req[1]
                branch = (req[2] if len(req) > 2 and req[2] is not None
                          else DEFAULT_BRANCH)
                kw = dict(req[3]) if len(req) > 3 and req[3] else {}
                if (kw.get("base_uid") is not None
                        or kw.get("guard_uid") is not None):
                    _flush()
                    out.append(self._put_inner(
                        key, value, branch,
                        base_uid=kw.get("base_uid"),
                        context=kw.get("context", b""),
                        guard_uid=kw.get("guard_uid")))
                    continue
                key = _k(key)
                if batch is None:
                    batch = WriteBuffer(self.store)
                head = heads.get((key, branch))
                if head is None:
                    head = self.branches.head(key, branch)
                bases = (head,) if head else ()
                base_depth = (load_fobject(batch, head).depth
                              if head else -1)
                t, data = self._commit_value(value, batch)
                obj = make_fobject(batch, t, key, data, bases,
                                   kw.get("context", b""), base_depth)
                heads[(key, branch)] = obj.uid
                pending.append((key, branch, obj.uid, bases))
                out.append(obj.uid)
            _flush()
        return out

    def get_batch(self, requests) -> list:
        """Coalesced multi-request get: ``requests`` are ``(key,)``,
        ``(key, branch)`` or ``(key, branch, kwargs)`` tuples.  Heads
        resolve first, then every requested meta chunk loads in ONE
        ``store.get_many`` (one routing fan-out per storage node
        instead of one per request).  Requests needing verify-on-get
        take the single-get path.  Returns ValueHandle-or-None in
        request order."""
        parsed = []
        for req in requests:
            key = req[0]
            branch = req[1] if len(req) > 1 else None
            kw = req[2] if len(req) > 2 and req[2] else {}
            parsed.append((key, branch, kw))
        out: list = [None] * len(parsed)
        fetch: list[tuple[int, bytes]] = []
        for i, (key, branch, kw) in enumerate(parsed):
            verify = kw.get("verify")
            verify = self.verify_get if verify is None else verify
            if verify:                     # verify re-hashes per chunk
                out[i] = self._get_inner(key, branch,
                                         uid=kw.get("uid"), verify=True)
                continue
            uid = kw.get("uid")
            if uid is None:
                uid = self.branches.head(_k(key),
                                         branch or DEFAULT_BRANCH)
                if uid is None:
                    continue
            fetch.append((i, bytes(uid)))
        if fetch:
            raws = self.store.get_many([uid for _, uid in fetch])
            for (i, uid), raw in zip(fetch, raws):
                out[i] = ValueHandle(self, FObject.deserialize(raw, uid))
        return out

    # ------------------------------------------------- live fast path
    def _on_head_mutation(self, key: bytes) -> None:
        """Branch-table listener: feeds the attest pin delta and marks
        this key's live tables stale (an external put / merge / fork
        moved a head under them)."""
        key = bytes(key)
        self._attest_dirty.add(key)
        if self._live:
            for (k, _b), t in self._live.items():
                if k == key:
                    t._mark_stale()

    def live(self, key: bytes, branch: str | None = None, *, policy=None):
        """Flat-state fast path (repro.live): a per-(key, branch)
        ``LiveTable`` absorbing puts and serving gets in O(1), folded
        into the POS-Tree archive at epoch boundaries (``fold()`` /
        ``commit_epoch()`` / the table's EpochPolicy thresholds).
        Repeated calls return the same table.  Direct ``put``s on the
        same (key, branch) stay legal: the table revalidates against
        the moved head and its dirty overlay reapplies on top at the
        next fold (last-writer-wins, as two successive puts would)."""
        from ..live.table import LiveTable
        key = _k(key)
        branch = branch or DEFAULT_BRANCH
        t = self._live.get((key, branch))
        if t is None:
            t = (LiveTable(self, key, branch, policy=policy)
                 if policy is not None else LiveTable(self, key, branch))
            self._live[(key, branch)] = t
        return t

    def commit_epoch(self, context: bytes = b"", *, attest: bool = False,
                     secret: bytes | None = None):
        """Epoch boundary: fold every dirty live table into the archive
        (one batched Put per table) and publish the folded roots under
        the EpochFence handshake — each new head is pinned at the
        current collection epoch and forwarded to in-flight collections
        exactly like an attested head, so no sweep can touch a chunk a
        fold just referenced before the fold's proofs are servable.
        With ``attest=True`` the epoch closes with a delta attestation
        committing to the folded heads.  Returns a live.EpochReport."""
        from ..live.table import EpochReport
        rep = EpochReport()
        for t in list(self._live.values()):
            if t.dirty_count:
                rep.folds.append(t.fold(context=context))
        folded = rep.folded_uids
        if folded:
            cluster = getattr(self.store, "cluster", None)
            fence = (cluster.gc_fence if cluster is not None
                     else self.gc_fence)
            fence.pin(folded)
            self._gc_attest_fence(folded)
        if attest:
            rep.attestation = self.attest(context=context, secret=secret)
        return rep

    def _live_fold_key(self, key: bytes) -> None:
        """Fork/merge of a dirty head folds first: the archive must hold
        the state the new branch (or the merge input) is derived from."""
        if self._live:
            for (k, _b), t in list(self._live.items()):
                if k == key and t.dirty_count:
                    t.fold()

    # ----------------------------------------------------------- views
    def list_keys(self) -> list[bytes]:                      # M8
        return self.branches.keys()

    def list_tagged_branches(self, key: bytes) -> dict[str, bytes]:  # M9
        return self.branches.tagged(_k(key))

    def list_untagged_branches(self, key: bytes) -> list[bytes]:     # M10
        return self.branches.untagged(_k(key))

    # ----------------------------------------------------------- forks
    def fork(self, key: bytes, ref: str | bytes, new_branch: str) -> None:
        """M11 (from branch) / M12 (from uid)."""
        key = _k(key)
        self._live_fold_key(key)      # fork of a dirty head folds first
        uid = (self.branches.head(key, ref) if isinstance(ref, str)
               else bytes(ref))
        if uid is None or (not isinstance(ref, str)
                           and not self.store.has(uid)):
            raise NoSuchRef(ref)   # a dangling tag would poison GC roots
        # root barrier: tagging an arbitrary uid mid-collection re-roots
        # its subgraph — it must be shaded (mark) or rescued (sweep)
        self._gc_root_barrier(uid)
        self.branches.fork(key, new_branch, uid)

    def rename(self, key: bytes, old: str, new: str) -> None:   # M13
        key = _k(key)
        self.branches.rename(key, old, new)
        t = self._live.pop((key, old), None)
        if t is not None:             # live table follows its branch name
            t.branch = new
            self._live[(key, new)] = t

    def remove(self, key: bytes, branch: str) -> None:          # M14
        key = _k(key)
        self.branches.remove(key, branch)
        # the branch's unfolded live delta dies with the branch, exactly
        # like its unswept archive chunks
        self._live.pop((key, branch), None)

    # ------------------------------------------------------- durability
    def sync(self) -> None:
        """Durability point for a durable-root engine: flush the store
        (demote the hot tier, fsync segments, run GC-fed compaction)
        and atomically snapshot the branch heads — after ``sync()``
        returns, reopening the same root resumes with bit-identical
        heads and every chunk reachable from them.  A no-op flush on a
        non-durable engine."""
        self.store.flush()
        if self._durable_root is not None:
            from ..storage.durable import write_durably
            write_durably(_heads_path(self._durable_root),
                          self.branches.snapshot())

    # ---------------------------------------------------- observability
    def observe(self) -> dict:
        """Engine observability snapshot: the global registry / event
        journal / GC history plus this engine's StoreStats (pulled at
        snapshot time, never re-counted) and live-table aggregates.
        JSON-safe — ``json.dumps(db.observe())`` round-trips."""
        live = {"tables": len(self._live), "dirty_keys": 0, "folds": 0,
                "fold_seconds": 0.0}
        for t in self._live.values():
            live["dirty_keys"] += t.dirty_count
            live["folds"] += t.stats.folds
            live["fold_seconds"] += t.stats.fold_seconds
        extra = {"engine": {
            "keys": len(self.branches.keys()),
            "pins": len(self.pins.uids()),
            "gc_epoch": self.gc_fence.epoch,
            "live": live,
        }}
        return obs.snapshot(stores={"store": self.store.stats},
                            extra=extra)

    # ---------------------------------------------------- space reclaim
    def gc(self, *, extra_roots: Iterable[bytes] = (),
           incremental: bool = False, budget: int = 256):
        """Mark-and-sweep: everything reachable from the TB/UB heads of
        every key (plus ``self.pins`` and ``extra_roots``) survives; the
        rest is removed via the backend's ``delete_many``.  Returns a
        ``gc.GCReport``.

        ``incremental=True`` runs the same collection as a tri-color
        epoch in ``budget``-bounded slices (``gc.IncrementalCollector``)
        — every pause is O(budget) chunks instead of O(DAG); use
        ``incremental_gc()`` to interleave the slices with your own
        traffic.

        When the store is a cluster routing store, its sweep inventory
        spans the WHOLE cluster — so the collection must be the
        cluster's: this delegates to ``Cluster.gc`` (contributing this
        engine's own heads, pins and hooks), which unions every
        servlet's roots and sweeps each node's store directly.  A
        single-servlet ``gc()`` is therefore exactly as safe as
        ``Cluster.gc()``, and no servlet's write-side routing counters
        are skewed by deleting chunks another servlet wrote."""
        from ..gc import GarbageCollector
        cluster = getattr(self.store, "cluster", None)
        if cluster is not None:
            roots = (set(extra_roots) | self.branches.all_heads()
                     | self.pins.uids())
            return cluster.gc(extra_roots=roots, extra_hooks=self.gc_hooks,
                              incremental=incremental, budget=budget)
        if incremental:
            return self.incremental_gc(extra_roots=extra_roots).collect(
                budget)
        # STW collections honor the attestation epoch fence too: heads
        # committed by a recent attestation stay provable for one more
        # epoch regardless of how the collection is driven
        self.gc_fence.begin_epoch()
        roots = set(extra_roots) | self.gc_fence.grace_roots()
        report = GarbageCollector(self.store, branches=self.branches,
                                  pins=self.pins, extra_roots=roots,
                                  ref_hooks=self.gc_hooks).collect()
        obs.record_gc_report(report)
        obs.emit("gc.done", mode="stw", scope="engine",
                 swept=report.swept_chunks,
                 reclaimed_bytes=report.reclaimed_bytes)
        return report

    def incremental_gc(self, *, extra_roots: Iterable[bytes] = ()):
        """Begin an incremental collection epoch and return its
        ``gc.IncrementalCollector`` (already in MARK, barriers
        installed): interleave ``step(budget)`` with your own commits;
        every put/merge/fork/pin in between is barriered, so no chunk
        reachable from any head or pin is ever swept.  On a cluster
        routing store this is the cluster's collection (see ``gc``)."""
        from ..gc import IncrementalCollector
        cluster = getattr(self.store, "cluster", None)
        if cluster is not None:
            roots = (set(extra_roots) | self.branches.all_heads()
                     | self.pins.uids())
            col = cluster.incremental_gc(extra_roots=roots,
                                         extra_hooks=self.gc_hooks)
            # an external engine sharing a routing store is a committer
            # too: its fork-from-uid / pin root barriers must reach the
            # cluster's collection (servlets are registered by Cluster)
            self._track_collector(col)
            return col
        col = IncrementalCollector(self.store, branches=self.branches,
                                   pins=self.pins, extra_roots=extra_roots,
                                   ref_hooks=self.gc_hooks,
                                   fence=self.gc_fence)
        col.begin()
        self._track_collector(col)
        return col

    def _track_collector(self, col) -> None:
        """Register an in-flight collection for root barriers, dropping
        finished epochs so back-to-back collections don't accumulate."""
        self.gc_collectors = [c for c in self.gc_collectors
                              if c.active and c is not col]
        self.gc_collectors.append(col)

    def _gc_root_barrier(self, uid: bytes) -> None:
        """Forward a re-rooting event (fork-from-uid, new pin) to every
        in-flight incremental collection; finished ones drop out."""
        if not self.gc_collectors:
            return
        self.gc_collectors = [c for c in self.gc_collectors if c.active]
        for c in self.gc_collectors:
            c.root_barrier(uid)

    def truncate_history(self, key: bytes, branch: str,
                         keep_uids: "list[bytes]",
                         base_uid: bytes | None = None
                         ) -> dict[bytes, bytes]:
        """Destructive retention primitive: rewrite ``branch``'s version
        chain to exactly ``keep_uids`` (newest first, as returned by
        ``track``), relinking each kept version's ``bases`` to the
        previous kept one; the oldest links to ``base_uid`` if given
        (the anchor: an untouched ancestor, e.g. history shared with
        another branch) and otherwise becomes a root.  Kept versions get
        new uids (the meta chunk changes; hash-chain tamper evidence is
        preserved over the *retained* chain); retired versions become
        unreachable, so the next ``gc()`` sweeps them.  The rewritten
        chain is linear — merge second-parents above the anchor are
        dropped, which is what makes their subtrees collectable.
        Returns {old uid: new uid}."""
        key = _k(key)
        if not keep_uids:
            raise NoSuchRef(branch)
        old_head = self.branches.head(key, branch)
        if old_head is None:
            raise NoSuchRef(branch)
        mapping: dict[bytes, bytes] = {}
        prev = base_uid
        base_depth = (load_fobject(self.store, base_uid).depth
                      if base_uid is not None else -1)
        batch = WriteBuffer(self.store)
        for uid in reversed(keep_uids):
            obj = load_fobject(self.store, uid)
            bases = (prev,) if prev is not None else ()
            new = make_fobject(batch, obj.type, obj.key, obj.data, bases,
                               obj.context, base_depth)
            mapping[uid] = new.uid
            prev = new.uid
            base_depth += 1
        batch.flush()
        self.branches.on_new_version(key, prev, (old_head,))
        self.branches.set_head(key, branch, prev)
        return mapping

    # ----------------------------------------------------------- track
    def track(self, key: bytes, ref: str | bytes,
              dist_rng: tuple[int, int] = (0, 1 << 30)) -> list[FObject]:
        """M15/M16: versions along the primary-parent chain whose distance
        from the given head lies in dist_rng."""
        key = _k(key)
        uid = (self.branches.head(key, ref) if isinstance(ref, str)
               else ref)
        out: list[FObject] = []
        d = 0
        while uid is not None and d < dist_rng[1]:
            obj = load_fobject(self.store, uid)
            if d >= dist_rng[0]:
                out.append(obj)
            uid = obj.bases[0] if obj.bases else None
            d += 1
        return out

    def lca(self, key: bytes, uid1: bytes, uid2: bytes):        # M17
        return mg.lca(self.store, uid1, uid2)

    # ------------------------------------------------------------ diff
    def diff(self, uid1: bytes, uid2: bytes):
        """Type-aware Diff of two versions (same type, any keys, §3.2)."""
        o1 = load_fobject(self.store, uid1)
        o2 = load_fobject(self.store, uid2)
        if o1.type != o2.type:
            raise TypeNotMatch(f"{o1.type_name()} vs {o2.type_name()}")
        if o1.type in (ck.MAP, ck.SET):
            t1 = POSTree.from_root(self.store, o1.type, o1.data, self.params)
            t2 = POSTree.from_root(self.store, o2.type, o2.data, self.params)
            return t1.diff_keys(t2)
        if o1.type in (ck.BLOB, ck.LIST):
            t1 = POSTree.from_root(self.store, o1.type, o1.data, self.params)
            t2 = POSTree.from_root(self.store, o2.type, o2.data, self.params)
            return [op for op in t1.diff_leaf_blocks(t2) if op[0] != "equal"]
        return None if o1.data == o2.data else (o1.data, o2.data)

    # ----------------------------------------------------------- merge
    def merge(self, key: bytes, target, *refs, resolver=None,
              context: bytes = b"") -> bytes:
        """M5 Merge(key, tgt_branch, ref_branch); M6 Merge(key, tgt_branch,
        ref_uid); M7 Merge(key, uid1, uid2, ...) for untagged heads."""
        key = _k(key)
        self._live_fold_key(key)      # merge inputs come from the archive
        if isinstance(target, str):          # M5 / M6
            tgt_uid = self.branches.head(key, target)
            if tgt_uid is None:
                raise NoSuchRef(target)
            ref = refs[0]
            ref_uid = (self.branches.head(key, ref) if isinstance(ref, str)
                       else ref)
            if ref_uid is None:
                raise NoSuchRef(ref)
            merged_uid = self._merge_versions(key, tgt_uid, ref_uid,
                                              resolver, context)
            self.branches.set_head(key, target, merged_uid)
            return merged_uid
        # M7: merge a collection of untagged heads pairwise; the result
        # is itself an untagged (FoC) head until something tags it
        uids = [target, *refs]
        acc = uids[0]
        for u in uids[1:]:
            acc = self._merge_versions(key, acc, u, resolver, context,
                                       foc=True)
        return acc

    def _merge_versions(self, key: bytes, uid1: bytes, uid2: bytes,
                        resolver, context: bytes, *,
                        foc: bool = False) -> bytes:
        o1 = load_fobject(self.store, uid1)
        o2 = load_fobject(self.store, uid2)
        if o1.type != o2.type:
            raise TypeNotMatch(f"{o1.type_name()} vs {o2.type_name()}")
        base_uid = mg.lca(self.store, uid1, uid2)
        base = (load_fobject(self.store, base_uid)
                if base_uid is not None else None)
        t = o1.type
        if t == ck.MAP:
            bm = (FMap.from_tree(POSTree.from_root(self.store, t, base.data,
                                                   self.params))
                  if base is not None and base.type == t else None)
            m1 = FMap.from_tree(POSTree.from_root(self.store, t, o1.data,
                                                  self.params))
            m2 = FMap.from_tree(POSTree.from_root(self.store, t, o2.data,
                                                  self.params))
            merged = mg.merge_map(self.store, bm, m1, m2, resolver)
            data = merged.tree.root_cid
        elif t == ck.SET:
            bs = (FSet.from_tree(POSTree.from_root(self.store, t, base.data,
                                                   self.params))
                  if base is not None and base.type == t else None)
            s1 = FSet.from_tree(POSTree.from_root(self.store, t, o1.data,
                                                  self.params))
            s2 = FSet.from_tree(POSTree.from_root(self.store, t, o2.data,
                                                  self.params))
            merged = mg.merge_set(self.store, bs, s1, s2, resolver)
            data = merged.tree.root_cid
        elif t in (ck.BLOB, ck.LIST):
            bt = (POSTree.from_root(self.store, t, base.data, self.params)
                  if base is not None and base.type == t else None)
            t1 = POSTree.from_root(self.store, t, o1.data, self.params)
            t2 = POSTree.from_root(self.store, t, o2.data, self.params)
            merged_tree = mg.merge_linear(self.store, t, bt, t1, t2,
                                          resolver, self.params)
            data = merged_tree.root_cid
        else:
            data = mg.merge_primitive(t, base.data if base else None,
                                      o1.data, o2.data, resolver)
        depth = max(o1.depth, o2.depth)
        obj = make_fobject(self.store, t, key, data, (uid1, uid2), context,
                           depth)
        self.branches.on_new_version(key, obj.uid, (uid1, uid2), foc=foc)
        return obj.uid

    # ----------------------------------------------------- verification
    def verify_lineage(self, uid: bytes, ancestor: bytes,
                       max_depth: int = 1 << 30) -> bool:
        """Tamper-evidence check (§3.2): is `ancestor` in uid's history?
        Walking hashes re-verifies integrity chunk by chunk when the store
        runs with verify=True."""
        from ..proof.lineage import lineage_path
        return lineage_path(self.store, uid, ancestor,
                            max_depth=max_depth) is not None

    # --------------------------------------------------- proof subsystem
    # Prover-side verbs: each emits a self-contained proof an external
    # verifier checks with repro.proof's stateless verify_* functions,
    # holding only a trusted root cid / head uid / attestation.

    def prove_lineage(self, uid: bytes, ancestor: bytes):
        """Meta-chunk hash chain showing ``ancestor`` in uid's history
        (verify with ``proof.verify_lineage(uid, ancestor, proof)``)."""
        from ..proof.lineage import prove_lineage
        return prove_lineage(self.store, uid, ancestor)

    def prove_version(self, uid: bytes) -> bytes:
        """The raw meta chunk binding ``uid`` to its version record —
        the bridge from a trusted uid to the value's tree root cid
        (verify with ``proof.verify_version(uid, raw)``)."""
        return self.store.get(uid)

    def _tree_of(self, obj: FObject) -> POSTree:
        if obj.type not in CHUNKABLE_TYPES:
            raise TypeNotMatch(obj.type_name())
        return POSTree.from_root(self.store, obj.type, obj.data,
                                 self.params)

    def prove_member(self, key: bytes, branch: str | None = None, *,
                     uid: bytes | None = None, pos: int | None = None,
                     item_key: bytes | None = None):
        """Membership proof for one element of a chunkable value —
        by position (any kind) or by key (Set/Map).  Anchored on the
        value's tree root cid = the ``data`` field of its (provable)
        meta chunk; verify with ``proof.verify_member(root, proof)``.
        Hot paths are served from the per-root proof cache: roots are
        content-addressed, so a cached audit path can never go stale —
        a mutated value has a new root and misses."""
        from ..proof.membership import prove_member
        h = self.get(key, branch, uid=uid)
        if h is None:
            raise NoSuchRef(branch)
        req = ("pos", pos) if pos is not None else ("key", item_key)
        return self._cached_proof(
            h.obj, req,
            lambda: prove_member(self._tree_of(h.obj), pos=pos,
                                 key=item_key))

    def prove_absence(self, key: bytes, branch: str | None = None, *,
                      uid: bytes | None = None,
                      item_key: bytes = b""):
        """Negative membership proof (sorted kinds), cached per root
        like ``prove_member``."""
        from ..proof.membership import prove_absence
        h = self.get(key, branch, uid=uid)
        if h is None:
            raise NoSuchRef(branch)
        return self._cached_proof(
            h.obj, ("absent", item_key),
            lambda: prove_absence(self._tree_of(h.obj), item_key))

    def _cached_proof(self, obj, req, build):
        """Per-root proof-cache plumbing shared by prove_member and
        prove_absence (the root is the value's content-addressed tree
        root, so cached paths can never go stale)."""
        root = bytes(obj.data)
        cached = self.proof_cache.lookup(root, req)
        if cached is not None:
            return cached
        proof = build()
        self.proof_cache.store(root, req, proof)
        return proof

    def _delta(self):
        from ..proof.delta import DeltaAttestor
        if self._delta_attestor is None:
            self._delta_attestor = DeltaAttestor(self.branches)
        return self._delta_attestor

    def attest(self, context: bytes = b"",
               secret: bytes | None = None):
        """Head attestation: a Merkle commitment (optionally HMAC-signed)
        to every branch head this engine serves — the light client's
        trust anchor.  Pair with ``prove_head`` / ``proof.verify_head``.

        Incremental: a persistent Merkle tree over the head entries is
        maintained through branch-table mutation hooks, so an attest
        after k head updates re-hashes O(k log heads) leaves instead of
        rebuilding all of them (proof.delta; first use falls back to one
        full build).  The attestation context carries the GC collector
        epoch, and the committed heads are pinned with the epoch fence:
        proofs against this attestation stay servable until the second
        collection after now begins (gc.EpochFence handshake).

        The pin path is O(k log n) too: the FIRST attest of each fence
        epoch pins the full head baseline; every later attest in the
        same epoch pins only the heads of keys mutated since (the
        baseline pins already cover the unchanged ones at this epoch).
        A collection advancing the fence epoch resets the baseline."""
        from ..proof.delta import pack_epoch
        cluster = getattr(self.store, "cluster", None)
        fence = cluster.gc_fence if cluster is not None else self.gc_fence
        if self._attest_pin_epoch != fence.epoch:
            heads = self.branches.all_heads()     # epoch baseline
            self._attest_pin_epoch = fence.epoch
        else:                                     # delta: O(dirty keys)
            heads = set()
            for k in self._attest_dirty:
                heads |= self.branches.heads_of(k)
        self._attest_dirty.clear()
        epoch = fence.pin(heads)
        self._gc_attest_fence(heads)
        return self._delta().attest(context=pack_epoch(epoch, context),
                                    secret=secret)

    def _gc_attest_fence(self, uids) -> None:
        """Forward freshly attested heads to every in-flight incremental
        collection: a sweep slice must not delete chunks beneath a head
        committed by an attestation issued this epoch."""
        if not self.gc_collectors:
            return
        self.gc_collectors = [c for c in self.gc_collectors if c.active]
        for c in self.gc_collectors:
            c.attest_fence(uids)

    def prove_head(self, key: bytes, branch: str | None = None, *,
                   uid: bytes | None = None):
        """Audit path showing one head is committed by ``attest()``.
        ``branch`` defaults to master (like get); pass ``uid`` for an
        untagged fork-on-conflict head.  Served off the resident delta
        attestation tree: O(log heads) per proof, no re-hashing."""
        from ..proof.attest import UB_TAG, encode_entry
        key = _k(key)
        if branch is None and uid is None:
            branch = DEFAULT_BRANCH
        if branch is None:
            entry = encode_entry(key, UB_TAG, uid)
        else:
            head = self.branches.head(key, branch)
            if head is None:
                raise KeyError(branch)
            entry = encode_entry(key, branch, head)
        return self._delta().prove(entry)

    def audit(self, sample: int = 64, seed: int = 0,
              secret: bytes | None = None):
        """Self-audit through the stateless verifiers (proof.Auditor)."""
        from ..proof.audit import Auditor
        return Auditor(sample=sample, seed=seed).audit_engine(
            self, secret=secret)


def _k(key) -> bytes:
    return key.encode() if isinstance(key, str) else bytes(key)


def _heads_path(root: str) -> str:
    return os.path.join(root, "heads.json")
