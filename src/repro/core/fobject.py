"""FObject — the versioned object record (paper Fig. 2, §3.1–3.2).

uid = cid of the serialized meta chunk, so a uid commits to the value *and*
to the full derivation history via the ``bases`` hash chain: the storage
cannot present a version v' outside the history without breaking the hash
chain (tamper evidence, §3.2).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

from . import chunk as ck
from ..errors import TamperedChunk

# object type tags: chunkable types reuse chunk kinds; primitives below.
TSTRING = 7
TTUPLE = 8
TINT = 9

CHUNKABLE_TYPES = (ck.BLOB, ck.LIST, ck.SET, ck.MAP)
PRIMITIVE_TYPES = (TSTRING, TTUPLE, TINT)

TYPE_NAMES = {ck.BLOB: "Blob", ck.LIST: "List", ck.SET: "Set", ck.MAP: "Map",
              TSTRING: "String", TTUPLE: "Tuple", TINT: "Integer"}

_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")


@dataclass(frozen=True)
class FObject:
    type: int
    key: bytes
    data: bytes            # primitives: inline value; chunkables: root cid
    depth: int             # distance to the first version
    bases: tuple[bytes, ...]  # uids this version derives from
    context: bytes = b""   # reserved for the application (commit msg, nonce)
    uid: bytes = b""       # filled after serialization

    def serialize(self) -> bytes:
        parts = [bytes([self.type]),
                 _U32.pack(len(self.key)), self.key,
                 _U32.pack(len(self.data)), self.data,
                 _U64.pack(self.depth),
                 _U16.pack(len(self.bases))]
        parts.extend(self.bases)
        parts.append(_U32.pack(len(self.context)))
        parts.append(self.context)
        return ck.encode_chunk(ck.META, b"".join(parts))

    @classmethod
    def deserialize(cls, raw: bytes, uid: bytes) -> "FObject":
        if ck.chunk_type(raw) != ck.META:
            raise TamperedChunk(uid, "fobject meta chunk has wrong type tag")
        p = ck.chunk_payload(raw)
        t = p[0]
        i = 1
        (kl,) = _U32.unpack_from(p, i); i += 4
        key = p[i:i + kl]; i += kl
        (dl,) = _U32.unpack_from(p, i); i += 4
        data = p[i:i + dl]; i += dl
        (depth,) = _U64.unpack_from(p, i); i += 8
        (nb,) = _U16.unpack_from(p, i); i += 2
        bases = tuple(p[i + 32 * j: i + 32 * (j + 1)] for j in range(nb))
        i += 32 * nb
        (cl,) = _U32.unpack_from(p, i); i += 4
        ctx = p[i:i + cl]
        return cls(t, key, data, depth, bases, ctx, uid)

    @property
    def is_chunkable(self) -> bool:
        return self.type in CHUNKABLE_TYPES

    def type_name(self) -> str:
        return TYPE_NAMES[self.type]


def make_fobject(store, type_: int, key: bytes, data: bytes,
                 bases: tuple[bytes, ...], context: bytes = b"",
                 base_depth: int = -1) -> FObject:
    """Construct, persist and uid-stamp a new FObject meta chunk.

    ``store`` is any StorageBackend; when it is the value's WriteBuffer
    (db.put), the meta chunk rides the same put_many batch as the value's
    tree chunks, so a whole version commits in one store round-trip."""
    obj = FObject(type_, key, data, base_depth + 1, bases, context)
    raw = obj.serialize()
    uid = store.put(raw)
    return FObject(type_, key, data, base_depth + 1, bases, context, uid)


def load_fobject(store, uid: bytes, verify: bool = False) -> FObject:
    """Load a version record; with ``verify`` the meta chunk is re-hashed
    against the uid (the verify-on-get option, counted in StoreStats),
    so a corrupted or substituted version can never deserialize."""
    raw = store.get(uid)
    if verify:
        from .chunk import cid_of
        st = getattr(store, "stats", None)
        ok = cid_of(raw) == bytes(uid)
        if st is not None:
            st.verifies += 1
            st.verify_failures += 0 if ok else 1
        if not ok:
            from ..storage import TamperedChunk
            raise TamperedChunk(bytes(uid), "Get-Meta")
    return FObject.deserialize(raw, uid)
