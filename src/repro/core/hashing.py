"""Content hashing for cids/uids (paper §4.2.1).

The paper uses SHA-256 by default and explicitly allows faster alternatives
("e.g., BLAKE2"). We keep SHA-256 as the host default for externally
verifiable tamper evidence, and expose a pluggable interface so the TPU
dedup path can use the Pallas ``fphash`` kernel (see kernels/fphash.py and
DESIGN.md §3 hardware-adaptation table).
"""
from __future__ import annotations

import hashlib
from typing import Callable

# A cid is the raw 32-byte digest of chunk bytes.  We keep bytes (not hex)
# internally; hex only at display boundaries.
CID_LEN = 32

HashFn = Callable[[bytes], bytes]


def sha256(data: bytes) -> bytes:
    return hashlib.sha256(data).digest()


def blake2b_256(data: bytes) -> bytes:
    return hashlib.blake2b(data, digest_size=32).digest()


_DEFAULT: HashFn = sha256


def set_default_hash(fn: HashFn) -> None:
    global _DEFAULT
    _DEFAULT = fn


def content_hash(data: bytes) -> bytes:
    """chunk.cid = H(chunk.bytes)  (paper §4.2.1)."""
    return _DEFAULT(data)


def hex(cid: bytes) -> str:
    return cid.hex()[:16]  # short display form
