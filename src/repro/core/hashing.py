"""Content hashing for cids/uids (paper §4.2.1).

The paper uses SHA-256 by default and explicitly allows faster alternatives
("e.g., BLAKE2"). We keep SHA-256 as the host default for externally
verifiable tamper evidence, and expose a pluggable interface so the TPU
dedup path can use the Pallas ``fphash`` kernel (see kernels/fphash.py and
DESIGN.md §3 hardware-adaptation table).
"""
from __future__ import annotations

import hashlib
from typing import Callable, Sequence

# A cid is the raw 32-byte digest of chunk bytes.  We keep bytes (not hex)
# internally; hex only at display boundaries.
CID_LEN = 32

HashFn = Callable[[bytes], bytes]
BatchHashFn = Callable[[Sequence[bytes]], "list[bytes]"]


def sha256(data: bytes) -> bytes:
    return hashlib.sha256(data).digest()


def sha256_many(blobs: Sequence[bytes]) -> list[bytes]:
    return [hashlib.sha256(b).digest() for b in blobs]


def blake2b_256(data: bytes) -> bytes:
    return hashlib.blake2b(data, digest_size=32).digest()


_DEFAULT: HashFn = sha256
_DEFAULT_MANY: BatchHashFn = sha256_many


def set_default_hash(fn: HashFn, many: BatchHashFn | None = None) -> None:
    """Swap the cid hash.  ``many`` is the vectorized entry point used by
    the batched store pipeline; without one, the singular fn is mapped."""
    global _DEFAULT, _DEFAULT_MANY
    _DEFAULT = fn
    _DEFAULT_MANY = many if many is not None else (
        lambda blobs: [fn(b) for b in blobs])


def use_fphash() -> None:
    """Route cid computation through the Pallas ``fphash`` kernel: the
    batched entry point hashes all chunks of a value in ONE kernel launch
    (kernels/fphash.fphash_many).  sha256 stays the verifiable default."""
    from ..kernels.fphash import fphash, fphash_many
    set_default_hash(fphash, fphash_many)


def use_sha256() -> None:
    set_default_hash(sha256, sha256_many)


def current_hash() -> HashFn:
    """Identity of the active cid hash — callers that memoize digests
    (delta attestations, verify memos) compare this across calls and
    rebuild wholesale when the algorithm was swapped."""
    return _DEFAULT


def content_hash(data: bytes) -> bytes:
    """chunk.cid = H(chunk.bytes)  (paper §4.2.1)."""
    return _DEFAULT(data)


def content_hash_many(blobs: Sequence[bytes]) -> list[bytes]:
    """Vectorized cid computation for a batch of chunks — one dispatch for
    the whole batch (one Pallas launch per value on the fphash path)."""
    return _DEFAULT_MANY(list(blobs))


def hex(cid: bytes) -> str:
    return cid.hex()[:16]  # short display form
