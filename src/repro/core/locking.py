"""Canonical lock order + runtime lock witness.

This module is the single source of truth for the cluster's locking
contract (established in the async-runtime PR, documented there in
docstrings, machine-readable here):

    servlet  ≺  collector  ≺  {index, store}  ≺  fence

* **servlet** (``Node.lock``) — per-servlet mutual exclusion around any
  touch of that node's ForkBase (branch table, live tables, pins).
  Servlet locks of *different* nodes may nest only in ascending node
  order, and only by ``Cluster.incremental_gc`` (every other verb takes
  at most one at a time).
* **collector** (``IncrementalCollector._collector_lock``, parked on
  stores as ``_barrier_lock`` while a collection is in flight) —
  serializes barrier/gray/condemned state between mutators and GC
  slices.
* **index** (``Cluster._index_lock``) — the master chunk-location map
  and quarantine/re-replication state.  Innermost alongside **store**;
  the two are *incomparable*: neither may be acquired while the other
  is held.
* **store** (``Node.store_lock``) — cross-thread access to one node's
  chunk store.  Never held across a listener callback.
* **fence** (``EpochFence._fence_lock``) — pin bookkeeping; a true
  leaf, never held across ``heads_fn`` (which takes servlet locks).

``LOCK_ORDER`` maps rank name -> numeric rank (lower = acquired
first/outermost); ``LOCK_ATTRS`` maps the attribute name each ranked
lock lives under -> its rank name.  The static analyzer
(``repro.analysis`` rule LOCK001) consumes both tables; keep attribute
names unique repo-wide so a ``with obj.<attr>:`` acquisition resolves
without type inference.

The **runtime lock witness** (``REPRO_LOCK_WITNESS=1``, or
:func:`enable_witness` before constructing the cluster) swaps every
ranked lock for an instrumented wrapper that records the
acquired-before graph across threads, flags rank inversions and graph
cycles the moment the offending acquisition happens, and accounts
held-lock wall time per rank.  The scheduled runtime-race CI job runs
the threaded harness under it, turning the stress suite into a
race/deadlock detector.
"""
from __future__ import annotations

import itertools
import os
import threading
from dataclasses import dataclass, field
from time import perf_counter as _perf

from ..errors import ConfigError, InvariantViolation

__all__ = [
    "LOCK_ORDER", "LOCK_ATTRS", "make_lock", "WitnessLock",
    "LockWitness", "WITNESS", "enable_witness", "disable_witness",
    "witness_enabled",
]

#: Rank name -> numeric rank.  Lower rank = outermost (acquired first).
#: Equal ranks are incomparable: such locks must never nest (the
#: witness catches AB/BA cycles among them; LOCK001 flags lexical
#: nesting statically).
LOCK_ORDER: dict[str, int] = {
    "servlet": 10,
    "collector": 20,
    "index": 30,
    "store": 30,
    "fence": 40,
}

#: Attribute name -> rank name, for every ranked lock in the tree.
#: LOCK001 resolves a ``with <expr>.<attr>:`` acquisition through this
#: table, so these names are deliberately unique: unranked utility
#: locks (queue mutexes, admission, metrics) use other names.
LOCK_ATTRS: dict[str, str] = {
    "lock": "servlet",               # core.cluster.Node.lock
    "store_lock": "store",           # core.cluster.Node.store_lock
    "_index_lock": "index",          # core.cluster.Cluster._index_lock
    "_collector_lock": "collector",  # gc.incremental.IncrementalCollector
    "_barrier_lock": "collector",    # the collector lock parked on stores
    "_fence_lock": "fence",          # gc.incremental.EpochFence
}


_ENV_FLAG = os.environ.get("REPRO_LOCK_WITNESS", "")
_enabled = _ENV_FLAG not in ("", "0", "false", "no")


def witness_enabled() -> bool:
    return _enabled


def enable_witness() -> None:
    """Turn the witness on for locks created *after* this call (tests
    call it before constructing the cluster; CI sets the env var)."""
    global _enabled
    _enabled = True


def disable_witness() -> None:
    global _enabled
    _enabled = False


@dataclass
class LockViolation:
    """One detected ordering violation, recorded at acquisition time."""
    kind: str          # "rank-inversion" | "cycle"
    thread: str
    acquiring: str     # display name of the lock being acquired
    held: tuple        # display names of locks already held (outer first)
    detail: str = ""

    def __str__(self) -> str:
        return (f"{self.kind}: thread {self.thread!r} acquired "
                f"{self.acquiring} while holding {list(self.held)}"
                + (f" ({self.detail})" if self.detail else ""))


@dataclass
class HoldStats:
    acquisitions: int = 0
    held_total_s: float = 0.0
    held_max_s: float = 0.0


class LockWitness:
    """Acquired-before recorder shared by a set of :class:`WitnessLock`
    instances.  Detection happens inline at acquisition:

    * **rank inversion** — acquiring a lock of strictly LOWER rank than
      one already held by this thread (store -> servlet, collector ->
      servlet, ...) violates the documented order outright.
    * **cycle** — the acquisition adds held->new edges to the global
      acquired-before graph; if the new lock can already reach a held
      lock, two threads have (at some point) acquired the pair in
      opposite orders — a latent deadlock, even if this run got lucky.
      This is what catches same-rank pairs ({index, store}, two servlet
      locks out of ascending order), which rank comparison alone cannot.
      Graph nodes are per-lock monotonic tokens, NOT ``id()`` — CPython
      reuses freed addresses, so id-keyed edges from a dead lock would
      alias a newly created one and report false cycles.

    Violations are recorded, not raised (raising mid-critical-section in
    an arbitrary worker thread would wedge the harness); the test
    fixture asserts :meth:`assert_clean` after each test.  Held-lock
    wall time is accounted per display name on release."""

    def __init__(self):
        self._mu = threading.Lock()
        self._tl = threading.local()
        self._edges: dict[int, set[int]] = {}
        self._names: dict[int, str] = {}
        self.violations: list[LockViolation] = []
        self.holds: dict[str, HoldStats] = {}

    # ------------------------------------------------------------ state
    def reset(self) -> None:
        with self._mu:
            self._edges.clear()
            self._names.clear()
            self.violations = []
            self.holds = {}

    def _held(self) -> list:
        held = getattr(self._tl, "held", None)
        if held is None:
            held = self._tl.held = []
        return held

    def _reaches(self, src: int, targets: set[int]) -> bool:
        """DFS over the acquired-before graph (caller holds _mu)."""
        seen = {src}
        stack = [src]
        while stack:
            for nxt in self._edges.get(stack.pop(), ()):
                if nxt in targets:
                    return True
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return False

    # ------------------------------------------------------ lock events
    def on_acquire(self, lock: "WitnessLock") -> None:
        held = self._held()
        if held:
            tname = threading.current_thread().name
            held_names = tuple(lk.display for lk in held)
            for outer in held:
                if lock.rank < outer.rank:
                    with self._mu:
                        self.violations.append(LockViolation(
                            "rank-inversion", tname, lock.display,
                            held_names,
                            f"{lock.rank_name}(rank {lock.rank}) under "
                            f"{outer.rank_name}(rank {outer.rank})"))
                    break
            with self._mu:
                self._names[lock.token] = lock.display
                targets = set()
                for outer in held:
                    if outer is lock:
                        continue
                    self._names[outer.token] = outer.display
                    targets.add(outer.token)
                if targets and self._reaches(lock.token, targets):
                    self.violations.append(LockViolation(
                        "cycle", tname, lock.display, held_names,
                        "acquired-before graph closed a cycle"))
                for t in targets:
                    self._edges.setdefault(t, set()).add(lock.token)
        held.append(lock)

    def on_release(self, lock: "WitnessLock", held_s: float) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is lock:
                del held[i]
                break
        with self._mu:
            st = self.holds.setdefault(lock.display, HoldStats())
            st.acquisitions += 1
            st.held_total_s += held_s
            st.held_max_s = max(st.held_max_s, held_s)

    # ---------------------------------------------------------- reports
    def report(self) -> dict:
        """JSON-safe summary: violations + held-lock wall time."""
        with self._mu:
            return {
                "violations": [str(v) for v in self.violations],
                "locks": {name: {"acquisitions": st.acquisitions,
                                 "held_total_s": st.held_total_s,
                                 "held_max_s": st.held_max_s}
                          for name, st in sorted(self.holds.items())},
            }

    def assert_clean(self) -> None:
        if self.violations:
            raise InvariantViolation(
                "lock witness recorded ordering violations:\n  "
                + "\n  ".join(str(v) for v in self.violations))


#: Process-wide witness every ``make_lock`` wrapper reports into.
WITNESS = LockWitness()


#: Graph-node tokens: unique for the process lifetime (never reused,
#: unlike ``id()``), so edges recorded for a dead lock can never alias a
#: new one.
_TOKENS = itertools.count(1)


class WitnessLock:
    """Instrumented re-entrant lock: a ``threading.RLock`` whose FIRST
    acquisition/final release per thread reports to a
    :class:`LockWitness`.  Context-manager and acquire/release
    compatible with RLock (nested re-entry is depth-counted and not
    re-reported)."""

    def __init__(self, rank_name: str, *, label: str = "",
                 witness: LockWitness | None = None):
        if rank_name not in LOCK_ORDER:
            raise ConfigError(
                f"unranked lock name {rank_name!r}; add it to "
                f"core.locking.LOCK_ORDER first")
        self.rank_name = rank_name
        self.rank = LOCK_ORDER[rank_name]
        self.label = label
        self.token = next(_TOKENS)
        self.display = (f"{rank_name}[{label}]" if label
                        else f"{rank_name}#{self.token}")
        self.witness = witness if witness is not None else WITNESS
        self._inner = threading.RLock()
        self._tl = threading.local()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            depth = getattr(self._tl, "depth", 0)
            if depth == 0:
                self._tl.t0 = _perf()
                self.witness.on_acquire(self)
            self._tl.depth = depth + 1
        return got

    def release(self) -> None:
        depth = getattr(self._tl, "depth", 0)
        if depth == 1:
            self.witness.on_release(self, _perf() - self._tl.t0)
        self._tl.depth = depth - 1
        self._inner.release()

    def __enter__(self) -> "WitnessLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debug sugar
        return f"<WitnessLock {self.display}>"


def make_lock(rank_name: str, *, label: str = ""):
    """The one factory ranked locks are created through.  Plain
    ``threading.RLock`` when the witness is off (zero overhead — the
    default), a :class:`WitnessLock` reporting into the global
    :data:`WITNESS` when on."""
    if _enabled:
        return WitnessLock(rank_name, label=label)
    if rank_name not in LOCK_ORDER:
        raise ConfigError(
            f"unranked lock name {rank_name!r}; add it to "
            f"core.locking.LOCK_ORDER first")
    return threading.RLock()
