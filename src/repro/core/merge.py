"""Three-way merge + conflict resolution (paper §3.3.3, §4.5.2).

Merge(v1, v2) feeds (v1, v2, LCA(v1, v2)) into a type-specific merge
function.  On conflicts it returns a conflict list; built-in resolvers
(append, aggregate, choose_one) or a user hook may resolve them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from . import chunk as ck
from ..errors import MergeConflict
from .fobject import TINT, load_fobject
from .postree import POSTree
from .types import (FInt, FMap, FSet)

__all__ = ["Conflict", "MergeConflict", "merge"]


@dataclass(frozen=True)
class Conflict:
    where: object          # key (Map/Set), (start,end) range, or None
    base: object
    ours: object
    theirs: object


# ------------------------------------------------------------- resolvers

def choose_one(side: int = 0) -> Callable:
    def fn(c: Conflict):
        return c.ours if side == 0 else c.theirs
    return fn


def append_resolver(c: Conflict):
    ours = c.ours if c.ours is not None else b""
    theirs = c.theirs if c.theirs is not None else b""
    return ours + theirs


def aggregate_resolver(c: Conflict):
    """Numeric: base + (ours-base) + (theirs-base)."""
    return c.ours + c.theirs - c.base


BUILTIN_RESOLVERS = {"choose_ours": choose_one(0),
                     "choose_theirs": choose_one(1),
                     "append": append_resolver,
                     "aggregate": aggregate_resolver}


# ----------------------------------------------------------- LCA (M17)

def lca(store, uid1: bytes, uid2: bytes) -> bytes | None:
    """Least common ancestor on the derivation DAG (M17): pop frontier nodes
    in decreasing depth (versions carry depth, Fig. 2), propagating which
    side(s) reach each node; the first node popped that both sides reach is
    a deepest common ancestor."""
    import heapq

    if uid1 == uid2:
        return uid1
    seen = {uid1: 1, uid2: 2}
    heap = [(-load_fobject(store, uid1).depth, uid1),
            (-load_fobject(store, uid2).depth, uid2)]
    heapq.heapify(heap)
    while heap:
        _, u = heapq.heappop(heap)
        mask = seen[u]
        if mask == 3:
            return u
        for b in load_fobject(store, u).bases:
            old = seen.get(b, 0)
            if old | mask != old:
                seen[b] = old | mask
                heapq.heappush(heap, (-load_fobject(store, b).depth, b))
    return None


# ----------------------------------------------------- type-specific merges

def merge_map(store, base: FMap | None, ours: FMap, theirs: FMap,
              resolver=None) -> FMap:
    bt = base.tree if base is not None else None
    conflicts, edits = [], {}
    if bt is None:
        ochg = {k: v for k, v in ours.items()}
        tchg = {k: v for k, v in theirs.items()}
        allk = set(ochg) | set(tchg)
        for k in allk:
            ov, tv = ochg.get(k), tchg.get(k)
            if ov == tv:
                edits[k] = ov
            elif ov is None:
                edits[k] = tv
            elif tv is None:
                edits[k] = ov
            else:
                conflicts.append(Conflict(k, None, ov, tv))
    else:
        oa, orm, och = ours.tree.diff_keys(bt)
        ta, trm, tch = theirs.tree.diff_keys(bt)
        ochange = {k: ("add", ours.get(k)) for k in oa}
        ochange.update({k: ("del", None) for k in orm})
        ochange.update({k: ("chg", ours.get(k)) for k in och})
        tchange = {k: ("add", theirs.get(k)) for k in ta}
        tchange.update({k: ("del", None) for k in trm})
        tchange.update({k: ("chg", theirs.get(k)) for k in tch})
        for k in set(ochange) | set(tchange):
            oc, tc = ochange.get(k), tchange.get(k)
            if oc is not None and tc is not None and oc != tc:
                conflicts.append(Conflict(k, base.get(k),
                                          oc[1], tc[1]))
            else:
                op, val = oc or tc
                edits[k] = None if op == "del" else val
    if conflicts:
        if resolver is None:
            raise MergeConflict(conflicts)
        for c in conflicts:
            edits[c.where] = resolver(c)
    # materialize merged = ours + theirs' (resolved) changes
    merged = FMap.from_tree(ours.tree) if ours.tree is not None else FMap()
    for k, v in edits.items():
        if v is None:
            merged.delete(k)
        else:
            merged.set(k, v)
    merged.commit(store)
    return merged


def merge_set(store, base: FSet | None, ours: FSet, theirs: FSet,
              resolver=None) -> FSet:
    bt = base.tree if base is not None else None
    bkeys = set(bt.iter_elements()) if bt is not None else set()
    okeys, tkeys = set(iter(ours)), set(iter(theirs))
    merged_keys = (okeys & tkeys) | (okeys - bkeys) | (tkeys - bkeys)
    # removed by either side stays removed unless re-added by the other
    out = FSet(sorted(merged_keys))
    out.commit(store)
    return out


def _changed_ranges(base: POSTree, side: POSTree):
    """Base item-ranges altered by `side`, with replacement items.
    Leaf-cid SequenceMatcher opcodes locate the changed chunk runs in
    O(difference); each run is then refined to item granularity by trimming
    the common prefix/suffix, so merge conflicts are per-item, not
    per-chunk."""
    bcum = np.concatenate([[0], np.cumsum([e.count for e in base.levels[0]])])
    scum = np.concatenate([[0], np.cumsum([e.count for e in side.levels[0]])])
    out = []
    for tag, i1, i2, j1, j2 in base.diff_leaf_blocks(side):
        if tag == "equal":
            continue
        bs, be = int(bcum[i1]), int(bcum[i2])
        js, je = int(scum[j1]), int(scum[j2])
        bi = _items_range(base, bs, be)
        si = _items_range(side, js, je)
        pre = 0
        while pre < len(bi) and pre < len(si) and bi[pre] == si[pre]:
            pre += 1
        suf = 0
        while (suf < len(bi) - pre and suf < len(si) - pre
               and bi[len(bi) - 1 - suf] == si[len(si) - 1 - suf]):
            suf += 1
        if pre == len(bi) == len(si):
            continue
        out.append((bs + pre, be - suf, js + pre, je - suf))
    return out


def _items_range(tree: POSTree, s: int, e: int):
    if tree.kind == ck.BLOB:
        return tree.read_bytes(s, e - s)
    return [tree.get_item(i) for i in range(s, e)]


def merge_linear(store, kind: int, base: POSTree | None, ours: POSTree,
                 theirs: POSTree, resolver=None, params=None):
    """Blob/List 3-way region merge: disjoint edited base-ranges compose;
    overlapping ranges conflict."""
    if base is None:
        raise MergeConflict([Conflict(None, None, ours.root_cid,
                                      theirs.root_cid)])
    ro = _changed_ranges(base, ours)
    rt = _changed_ranges(base, theirs)
    conflicts = []
    for (bs, be, *_ ) in ro:
        for (cs, ce, *_ ) in rt:
            if bs < ce and cs < be:   # overlap in base coords
                conflicts.append(Conflict(
                    (max(bs, cs), min(be, ce)),
                    _items_range(base, max(bs, cs), min(be, ce)),
                    None, None))
    if conflicts and resolver is None:
        raise MergeConflict(conflicts)
    # rebuild: walk base, applying both sides' replacements
    edits = ([(bs, be, ("o", js, je)) for bs, be, js, je in ro] +
             [(bs, be, ("t", js, je)) for bs, be, js, je in rt])
    edits.sort()
    pieces = []
    cursor = 0
    skip_until = -1
    for bs, be, (side, js, je) in edits:
        if bs < skip_until:       # overlapped & resolved: ours wins region
            continue
        pieces.append(_items_range(base, cursor, bs))
        src = ours if side == "o" else theirs
        pieces.append(_items_range(src, js, je))
        cursor = be
        skip_until = be
    pieces.append(_items_range(base, cursor, base.total_count))
    if kind == ck.BLOB:
        data = b"".join(bytes(p) for p in pieces)
        return POSTree.build_bytes(store, data,
                                   params or base.params)
    els = [ck.pack_lv(x) for p in pieces for x in p]
    return POSTree.build_elements(store, ck.LIST, els,
                                  params=params or base.params)


def merge_primitive(type_: int, base_data: bytes | None, ours: bytes,
                    theirs: bytes, resolver=None) -> bytes:
    if ours == theirs:
        return ours
    if base_data is not None:
        if ours == base_data:
            return theirs
        if theirs == base_data:
            return ours
    c = Conflict(None, base_data, ours, theirs)
    if resolver is None:
        raise MergeConflict([c])
    if resolver is aggregate_resolver and type_ == TINT:
        b = FInt.decode(base_data or FInt(0).encode()).value
        o, t = FInt.decode(ours).value, FInt.decode(theirs).value
        return FInt(o + t - b).encode()
    return resolver(c)
