"""Piece table for client-side edit buffering (paper §3.5, Fig. 4: "Changes
are buffered in client"; "When multiple updates of the same object are
batched, ForkBase only retains the final version").

Buffers an arbitrary sequence of virtual-coordinate splices against a base
of known length and, at commit time, emits the minimal list of
*base-coordinate* splices — exactly what POSTree.splice_bytes /
splice_elements consume in one incremental pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from ..errors import InvariantViolation


@dataclass
class _Piece:
    base_start: int   # -1 for NEW pieces
    length: int
    new: Any = None   # NEW payload: list (elements) or bytes


class PieceTable:
    def __init__(self, base_len: int):
        self.base_len = base_len
        self.pieces: list[_Piece] = (
            [_Piece(0, base_len)] if base_len > 0 else [])

    def __len__(self) -> int:
        return sum(p.length for p in self.pieces)

    def splice(self, vstart: int, vend: int, new: Any, new_len: int) -> None:
        if not (0 <= vstart <= vend <= len(self)):
            raise InvariantViolation(
                f"splice range out of bounds: {(vstart, vend, len(self))}")
        out: list[_Piece] = []
        pos = 0
        inserted = False

        def emit_new():
            nonlocal inserted
            if not inserted:
                if new_len > 0:
                    out.append(_Piece(-1, new_len, new))
                inserted = True

        for p in self.pieces:
            pend = pos + p.length
            if pend <= vstart or pos >= vend:
                if pos >= vend:
                    emit_new()
                out.append(p)
            else:
                # head fragment
                if pos < vstart:
                    head = vstart - pos
                    if p.base_start >= 0:
                        out.append(_Piece(p.base_start, head))
                    else:
                        out.append(_Piece(-1, head, p.new[:head]))
                emit_new()
                # tail fragment
                if pend > vend:
                    tail = pend - vend
                    off = p.length - tail
                    if p.base_start >= 0:
                        out.append(_Piece(p.base_start + off, tail))
                    else:
                        out.append(_Piece(-1, tail, p.new[off:]))
            pos = pend
        emit_new()
        self.pieces = [p for p in out if p.length > 0]

    def read(self, vstart: int, vend: int, base_read: Callable[[int, int], Any],
             joiner: Callable[[list], Any]) -> Any:
        """Materialize virtual range [vstart, vend)."""
        parts = []
        pos = 0
        for p in self.pieces:
            pend = pos + p.length
            lo, hi = max(pos, vstart), min(pend, vend)
            if lo < hi:
                off = lo - pos
                if p.base_start >= 0:
                    parts.append(base_read(p.base_start + off,
                                           p.base_start + off + (hi - lo)))
                else:
                    parts.append(p.new[off:off + (hi - lo)])
            pos = pend
            if pos >= vend:
                break
        return joiner(parts)

    @property
    def dirty(self) -> bool:
        if len(self.pieces) != (1 if self.base_len else 0):
            return True
        return bool(self.pieces) and (self.pieces[0].base_start != 0 or
                                      self.pieces[0].length != self.base_len)

    def base_edits(self, joiner: Callable[[list], Any]):
        """Emit [(base_start, base_end, replacement)] splices, sorted and
        non-overlapping.  BASE pieces stay in increasing order because
        splices never reorder retained content."""
        edits = []
        cursor = 0  # position in base coords
        pending: list[Any] = []
        for p in self.pieces:
            if p.base_start >= 0:
                if p.base_start != cursor or pending:
                    edits.append((cursor, p.base_start, joiner(pending)))
                    pending = []
                cursor = p.base_start + p.length
            else:
                pending.append(p.new)
        if cursor != self.base_len or pending:
            edits.append((cursor, self.base_len, joiner(pending)))
        return edits
