"""Pattern-Oriented-Split Tree (paper §4.3, Fig. 6, Algorithm 1).

A Merkle-hashed B+-tree whose node boundaries are *content patterns*:
  * leaf level — rolling-hash patterns over the serialized element stream
    (element-aligned, §4.3.2);
  * index levels — cid-bit patterns over child entries (P', §4.3.3).

Node boundaries are a deterministic function of content alone, independent
of edit order.  Consequences (all property-tested):
  * equal content  <=> identical root cid (dedup + tamper evidence);
  * updates are copy-on-write and touch O(changed chunks) nodes;
  * Diff of two trees skips identical-cid subtrees.

The tree object keeps materialized per-level entry lists (levels[0] = leaf
entries ... levels[-1] = [root]); chunks are the persistent representation.
Incremental commits re-chunk only from the first affected leaf until the new
cut sequence re-aligns with the old one (guaranteed once the rolling window
has slid past the edit), then splice.  Index levels are recomputed from the
leaf entries — unchanged nodes re-serialize to identical bytes, so the store
dedups them and only the O(log n) changed path is newly written.
"""
from __future__ import annotations

import bisect
from difflib import SequenceMatcher

import numpy as np

from . import chunk as ck
from .chunk import Entry
from .chunker import (ChunkParams, DEFAULT_PARAMS, boundary_bitmap,
                      cut_bytes, cut_elements, index_cuts)
from ..errors import InvariantViolation
from ..storage import WriteBuffer

SORTED_KINDS = (ck.SET, ck.MAP)


# ---------------------------------------------------------------- navigation
# Deterministic child selection over decoded index entries.  Shared by the
# tree walks here and by the *stateless* proof verifier (repro.proof):
# both sides must pick the same child for the same (entries, pos/key), or
# a genuine proof would fail to verify.

def child_by_pos(entries: list[Entry], pos: int) -> tuple[int, int]:
    """(child index, items preceding it) for global item position ``pos``
    within a node whose subtree counts sum over ``pos``; raises IndexError
    when pos is outside the node (a forged position in a proof)."""
    base = 0
    for i, e in enumerate(entries):
        if pos < base + e.count:
            return i, base
        base += e.count
    raise IndexError(pos)


def child_by_key(entries: list[Entry], key: bytes) -> int:
    """First child whose max key covers ``key`` (clamped to the last child
    so past-the-end keys resolve to the rightmost leaf, as in find_key)."""
    ks = [e.key for e in entries]
    return min(bisect.bisect_left(ks, key), len(entries) - 1)


class POSTree:
    def __init__(self, store, kind: int, levels: list[list[Entry]],
                 params: ChunkParams = DEFAULT_PARAMS):
        self.store = store
        self.kind = kind
        self.levels = levels
        self.params = params
        self._buf: WriteBuffer | None = None      # active commit batch
        self._leaf_cache: dict[int, list] = {}
        self._cum: np.ndarray | None = None       # leaf cumulative counts
        self._keycache: list[bytes] | None = None  # leaf max keys (sorted)

    # ------------------------------------------------- batched chunk I/O
    # All chunks written during one build/splice commit accumulate in a
    # WriteBuffer and reach the store as a single put_many (§4.6.1); reads
    # during the commit see pending chunks through the buffer.
    def _open_batch(self, sink=None) -> None:
        """``sink`` lets a caller-owned batch (db.put's per-value
        WriteBuffer) absorb this commit's chunks, so incremental splices
        ride the same single put_many as the value's meta chunk."""
        if self._buf is None:
            self._buf = WriteBuffer(sink if sink is not None else self.store)

    def _commit_batch(self) -> None:
        if self._buf is not None:
            self._buf.flush()
            self._buf = None

    def _put_chunks(self, raws: list[bytes]) -> list[bytes]:
        tgt = self._buf if self._buf is not None else self.store
        return tgt.put_many(raws)

    def _get_raw(self, cid: bytes) -> bytes:
        src = self._buf if self._buf is not None else self.store
        return src.get(cid)

    # ------------------------------------------------------------ build
    @classmethod
    def build_bytes(cls, store, data: np.ndarray | bytes,
                    params: ChunkParams = DEFAULT_PARAMS) -> "POSTree":
        data = np.frombuffer(bytes(data), dtype=np.uint8) if isinstance(
            data, (bytes, bytearray)) else np.asarray(data, dtype=np.uint8)
        if data.size == 0:
            return cls._empty(store, ck.BLOB, params)
        cuts = cut_bytes(data, params)
        buf = WriteBuffer(store)
        raws, counts = [], []
        start = 0
        for c in cuts:
            raws.append(ck.encode_chunk(ck.BLOB, data[start:c].tobytes()))
            counts.append(c - start)
            start = c
        leaves = [Entry(cid, cnt)
                  for cid, cnt in zip(buf.put_many(raws), counts)]
        return cls._from_leaves(store, ck.BLOB, leaves, params, buf=buf)

    @classmethod
    def build_elements(cls, store, kind: int, elements: list[bytes],
                       keys: list[bytes] | None = None,
                       params: ChunkParams = DEFAULT_PARAMS) -> "POSTree":
        """elements: already-serialized, self-delimiting elements
        (pack_lv for List/Set, pack_kv for Map); keys: per-element sort key
        for sorted kinds."""
        if not elements:
            return cls._empty(store, kind, params)
        stream = np.frombuffer(b"".join(elements), dtype=np.uint8)
        bitmap = boundary_bitmap(stream, params)
        lengths = [len(e) for e in elements]
        cuts = cut_elements(lengths, bitmap, params)
        buf = WriteBuffer(store)
        raws, counts, ekeys = [], [], []
        start = 0
        is_sorted = kind in SORTED_KINDS
        for c in cuts:
            raws.append(ck.encode_chunk(kind, b"".join(elements[start:c])))
            counts.append(c - start)
            ekeys.append(keys[c - 1] if (is_sorted and keys is not None)
                         else None)
            start = c
        leaves = [Entry(cid, cnt, key) for cid, cnt, key
                  in zip(buf.put_many(raws), counts, ekeys)]
        return cls._from_leaves(store, kind, leaves, params, buf=buf)

    @classmethod
    def _empty(cls, store, kind: int, params: ChunkParams) -> "POSTree":
        raw = ck.encode_chunk(kind, b"")
        key = b"" if kind in SORTED_KINDS else None
        return cls(store, kind, [[Entry(store.put(raw), 0, key)]], params)

    @classmethod
    def _from_leaves(cls, store, kind, leaves, params,
                     buf: WriteBuffer | None = None) -> "POSTree":
        tree = cls(store, kind, [leaves], params)
        tree._buf = buf if buf is not None else WriteBuffer(store)
        tree._rebuild_index()
        tree._commit_batch()
        return tree

    @classmethod
    def from_root(cls, store, kind: int, root_cid: bytes,
                  params: ChunkParams = DEFAULT_PARAMS) -> "POSTree":
        """Materialize the index (not the leaves) from a stored root."""
        root_raw = store.get(root_cid)
        raw = ck.chunk_payload(root_raw)
        rtype = ck.chunk_type(root_raw)
        if rtype in (ck.UINDEX, ck.SINDEX):
            # walk down, collecting each level's entries; each level is
            # fetched with ONE batched get_many, not a get per node
            levels_desc = []
            entries = (ck.decode_sindex if rtype == ck.SINDEX
                       else ck.decode_uindex)(raw)
            cur = entries
            while True:
                levels_desc.append(cur)
                child = store.get(cur[0].cid)
                ctype = ck.chunk_type(child)
                if ctype not in (ck.UINDEX, ck.SINDEX):
                    break
                dec = ck.decode_sindex if ctype == ck.SINDEX else ck.decode_uindex
                nxt = []
                for raw_c in store.get_many([e.cid for e in cur]):
                    nxt.extend(dec(ck.chunk_payload(raw_c)))
                cur = nxt
            root_count = sum(e.count for e in levels_desc[0])
            root_key = levels_desc[0][-1].key
            levels = list(reversed(levels_desc))
            levels.append([Entry(root_cid, root_count, root_key)])
            return cls(store, kind, levels, params)
        # root is a single leaf
        count, key = cls._leaf_stats(kind, raw)
        return cls(store, kind, [[Entry(root_cid, count, key)]], params)

    @staticmethod
    def _leaf_stats(kind: int, payload: bytes) -> tuple[int, bytes | None]:
        if kind == ck.BLOB:
            return len(payload), None
        if kind == ck.MAP:
            els = ck.unpack_kv_stream(payload)
            return len(els), (els[-1][0] if els else b"")
        els = ck.unpack_lv_stream(payload)
        key = (els[-1] if els else b"") if kind == ck.SET else None
        return len(els), key

    # ------------------------------------------------------------ props
    @property
    def root_cid(self) -> bytes:
        return self.levels[-1][0].cid

    @property
    def total_count(self) -> int:
        return self.levels[-1][0].count

    @property
    def height(self) -> int:
        return len(self.levels)

    def node_cids(self) -> set[bytes]:
        """All chunk cids reachable from this tree (for GC / stats)."""
        out = set()
        for lvl in self.levels:
            out.update(e.cid for e in lvl)
        return out

    # ------------------------------------------------------------ reads
    def _cum_counts(self) -> np.ndarray:
        if self._cum is None:
            self._cum = np.cumsum(
                np.fromiter((e.count for e in self.levels[0]), dtype=np.int64,
                            count=len(self.levels[0])))
        return self._cum

    def _leaf_payload(self, i: int) -> bytes:
        return ck.chunk_payload(self._get_raw(self.levels[0][i].cid))

    def _parse_leaf(self, payload: bytes):
        if self.kind == ck.BLOB:
            return np.frombuffer(payload, dtype=np.uint8)
        if self.kind == ck.MAP:
            return ck.unpack_kv_stream(payload)
        return ck.unpack_lv_stream(payload)

    def leaf_elements(self, i: int) -> list:
        """Parsed elements of leaf i (bytes-array for Blob, kv tuples for
        Map, bytes for List/Set)."""
        if i in self._leaf_cache:
            return self._leaf_cache[i]
        els = self._parse_leaf(self._leaf_payload(i))
        if len(self._leaf_cache) > 256:
            self._leaf_cache.clear()
        self._leaf_cache[i] = els
        return els

    def prefetch_leaves(self, j0: int, j1: int) -> None:
        """Pull leaves [j0, j1) into the parse cache with ONE batched
        ``get_many`` over the uncached cids — the read-side analogue of
        the WriteBuffer's batched flush.  Range reads and scans that
        touch k leaves cost one store round-trip instead of k."""
        need = [j for j in range(j0, j1) if j not in self._leaf_cache]
        if len(need) < 2:
            return                       # 0/1 leaves: plain path is fine
        src = self._buf if self._buf is not None else self.store
        raws = src.get_many([self.levels[0][j].cid for j in need])
        if len(self._leaf_cache) + len(need) > 256:
            self._leaf_cache.clear()
        for j, raw in zip(need, raws):
            self._leaf_cache[j] = self._parse_leaf(ck.chunk_payload(raw))

    def leaf_of_item(self, pos: int) -> tuple[int, int]:
        """(leaf index, local offset) of global item position pos."""
        cum = self._cum_counts()
        j = int(np.searchsorted(cum, pos, side="right"))
        j = min(j, len(cum) - 1)
        base = int(cum[j - 1]) if j > 0 else 0
        return j, pos - base

    def get_item(self, pos: int):
        if not (0 <= pos < self.total_count):
            raise IndexError(pos)
        j, off = self.leaf_of_item(pos)
        return self.leaf_elements(j)[off]

    def read_bytes(self, start: int, length: int) -> bytes:
        if self.kind != ck.BLOB:
            raise InvariantViolation(f"read_bytes on non-blob kind {self.kind}")
        end = min(start + length, self.total_count)
        if end <= start:
            return b""
        j0, off0 = self.leaf_of_item(start)
        self.prefetch_leaves(j0, self.leaf_of_item(end - 1)[0] + 1)
        out = []
        pos = start
        j = j0
        while pos < end:
            els = self.leaf_elements(j)
            lo = off0 if j == j0 else 0
            hi = min(len(els), lo + (end - pos))
            out.append(els[lo:hi].tobytes())
            pos += hi - lo
            j += 1
        return b"".join(out)

    def _leaf_keys(self) -> list[bytes]:
        if self._keycache is None:
            self._keycache = [e.key for e in self.levels[0]]
        return self._keycache

    def find_key(self, key: bytes):
        """Sorted kinds: (found, leaf_idx, local_idx, global_idx)."""
        if self.kind not in SORTED_KINDS:
            raise InvariantViolation(f"find_key on unsorted kind {self.kind}")
        lk = self._leaf_keys()
        j = bisect.bisect_left(lk, key)
        if j >= len(lk):
            j = len(lk) - 1
        els = self.leaf_elements(j)
        keys = [e[0] for e in els] if self.kind == ck.MAP else els
        li = bisect.bisect_left(keys, key)
        cum = self._cum_counts()
        base = int(cum[j - 1]) if j > 0 else 0
        found = li < len(keys) and keys[li] == key
        return found, j, li, base + li

    def iter_elements(self):
        n = len(self.levels[0])
        for blk in range(0, n, 128):
            hi = min(blk + 128, n)
            self.prefetch_leaves(blk, hi)
            for i in range(blk, hi):
                yield from self.leaf_elements(i)

    # ------------------------------------------------------ lookup via tree
    def descend_key(self, key: bytes):
        """Pure tree-walk lookup (no materialized leaf keys) — exercises the
        on-disk SIndex path the way a remote client would (paper §3.4)."""
        if self.kind not in SORTED_KINDS:
            raise InvariantViolation(f"descend_key on unsorted kind {self.kind}")
        node = self.levels[-1][0]
        raw = self.store.get(node.cid)
        while ck.chunk_type(raw) in (ck.UINDEX, ck.SINDEX):
            entries = ck.decode_sindex(ck.chunk_payload(raw))
            ks = [e.key for e in entries]
            i = min(bisect.bisect_left(ks, key), len(entries) - 1)
            raw = self.store.get(entries[i].cid)
        if self.kind == ck.MAP:
            for k, v in ck.unpack_kv_stream(ck.chunk_payload(raw)):
                if k == key:
                    return v
            return None
        return key if key in ck.unpack_lv_stream(ck.chunk_payload(raw)) else None

    # ------------------------------------------------------- audit paths
    def audit_path(self, *, pos: int | None = None,
                   key: bytes | None = None) -> tuple[list[bytes], bytes]:
        """Membership-proof extraction hook (proof subsystem): the raw
        chunk chain from the root down to the leaf holding item ``pos``
        (any kind) or sorted-kind ``key`` — exactly the nodes a stateless
        verifier needs to recompute the root cid.  Returns
        (index node raws root-down, leaf raw)."""
        if (pos is None) == (key is None):
            raise InvariantViolation("audit_path needs exactly one of pos/key")
        if key is not None and self.kind not in SORTED_KINDS:
            raise InvariantViolation(f"audit_path by key on unsorted kind {self.kind}")
        raw = self._get_raw(self.root_cid)
        index_raws: list[bytes] = []
        while ck.chunk_type(raw) in (ck.UINDEX, ck.SINDEX):
            dec = (ck.decode_sindex if ck.chunk_type(raw) == ck.SINDEX
                   else ck.decode_uindex)
            entries = dec(ck.chunk_payload(raw))
            if pos is not None:
                child, base = child_by_pos(entries, pos)
                pos -= base
            else:
                child = child_by_key(entries, key)
            index_raws.append(raw)
            raw = self._get_raw(entries[child].cid)
        return index_raws, raw

    # ------------------------------------------------------------ commit
    def _rebuild_index(self) -> None:
        """Recompute index levels from levels[0] (P' cid patterns, §4.3.3).
        Unchanged nodes hash to their old cids and dedup in the store."""
        self.levels = [self.levels[0]]
        self._cum = None
        self._keycache = None
        self._leaf_cache.clear()
        entries = self.levels[0]
        is_sorted = self.kind in SORTED_KINDS
        while len(entries) > 1:
            cuts = index_cuts([e.cid for e in entries], self.params)
            raws, counts, keys = [], [], []
            start = 0
            for c in cuts:
                group = entries[start:c]
                raws.append(ck.encode_sindex(group) if is_sorted
                            else ck.encode_uindex(group))
                counts.append(sum(e.count for e in group))
                keys.append(group[-1].key if is_sorted else None)
                start = c
            nxt = [Entry(cid, cnt, key) for cid, cnt, key
                   in zip(self._put_chunks(raws), counts, keys)]
            self.levels.append(nxt)
            entries = nxt

    def _warmup_bytes(self, j0: int) -> bytes:
        """Last window-1 bytes of the stream before leaf j0."""
        need = self.params.window - 1
        parts: list[bytes] = []
        got = 0
        j = j0 - 1
        while j >= 0 and got < need:
            p = self._leaf_payload(j)
            take = p[-(need - got):]
            parts.append(take)
            got += len(take)
            j -= 1
        return b"".join(reversed(parts))

    def splice_bytes(self, edits: list[tuple[int, int, bytes]],
                     sink=None) -> None:
        """Blob: apply [(start, end, replacement)] byte splices (sorted,
        non-overlapping) and incrementally re-chunk."""
        if self.kind != ck.BLOB:
            raise InvariantViolation(f"splice_bytes on non-blob kind {self.kind}")
        if not edits:
            return
        self._open_batch(sink)
        leaves = self.levels[0]
        cum = self._cum_counts()
        total = int(cum[-1]) if len(cum) else 0
        first = min(e[0] for e in edits)
        j0 = min(int(np.searchsorted(cum, first, side="right")), len(leaves) - 1)
        base = int(cum[j0 - 1]) if j0 > 0 else 0
        last_end = max(e[1] for e in edits)
        jE = min(int(np.searchsorted(cum, max(last_end - 1, first),
                                     side="right")), len(leaves) - 1)
        warm = self._warmup_bytes(j0)
        grow = max(2, jE - j0 + 1)
        while True:
            jx = min(jE + grow, len(leaves) - 1)
            old = np.concatenate([np.frombuffer(self._leaf_payload(j),
                                                dtype=np.uint8)
                                  for j in range(j0, jx + 1)])
            # apply edits in local coordinates, back to front
            buf = old
            for s, e, rep in sorted(edits, reverse=True):
                ls, le = s - base, e - base
                buf = np.concatenate([buf[:ls],
                                      np.frombuffer(rep, dtype=np.uint8),
                                      buf[le:]])
            delta = len(buf) - len(old)
            covered_end = int(cum[jx])            # old coords
            at_stream_end = jx == len(leaves) - 1
            wb = np.frombuffer(warm, dtype=np.uint8)
            bitmap = boundary_bitmap(np.concatenate([wb, buf]), self.params)[len(wb):]
            cuts = cut_bytes(buf, self.params, bitmap=bitmap)
            # resync: new cut -> old offset must hit an old leaf boundary
            stable_from = (last_end - base) + delta + self.params.window
            splice_at = None   # (cut_idx, old_leaf_index)
            cumset = {int(c): i + 1 for i, c in enumerate(cum)}
            for ci, c in enumerate(cuts[:-1] if not at_stream_end else cuts):
                if c < stable_from:
                    continue
                old_off = c - delta + base
                if old_off in cumset and old_off >= last_end:
                    splice_at = (ci, cumset[old_off])
                    break
            if splice_at is None and not at_stream_end:
                grow *= 2
                continue
            raws, counts = [], []
            start = 0
            upto = len(cuts) if splice_at is None else splice_at[0] + 1
            for c in cuts[:upto]:
                raws.append(ck.encode_chunk(ck.BLOB, buf[start:c].tobytes()))
                counts.append(c - start)
                start = c
            new_leaves = [Entry(cid, cnt) for cid, cnt
                          in zip(self._put_chunks(raws), counts)]
            tail = leaves[splice_at[1]:] if splice_at else []
            if len(buf) == 0 and not new_leaves and not tail and j0 == 0:
                self.levels[0] = self._empty(self.store, ck.BLOB,
                                             self.params).levels[0]
            else:
                self.levels[0] = leaves[:j0] + new_leaves + tail
                if not self.levels[0]:
                    self.levels[0] = self._empty(self.store, ck.BLOB,
                                                 self.params).levels[0]
            self._rebuild_index()
            self._commit_batch()
            return

    def splice_elements(self, edits: list[tuple[int, int, list[bytes],
                                                list[bytes] | None]],
                        sink=None) -> None:
        """List/Set/Map: [(start, end, new_serialized_elems, new_keys)]
        element-space splices (sorted, non-overlapping).

        Scattered edits are partitioned into locality clusters and applied
        as independent spans in DESCENDING order (later spans never shift
        earlier indices), so a 100-key update on a 5M-row map re-chunks
        ~100 leaves, not the whole range between the first and last key.
        The index levels are recomputed once at the end."""
        if self.kind == ck.BLOB:
            raise InvariantViolation("splice_elements on blob tree")
        if not edits:
            return
        self._open_batch(sink)
        # cluster by element distance (~2 leaves apart -> same span)
        avg_leaf = max(1, self.total_count // max(1, len(self.levels[0])))
        gap = 2 * avg_leaf
        clusters: list[list] = [[edits[0]]]
        for e in edits[1:]:
            if e[0] - clusters[-1][-1][1] <= gap:
                clusters[-1].append(e)
            else:
                clusters.append([e])
        for cl in reversed(clusters):
            self._splice_span_elements(cl)
        self._rebuild_index()
        self._commit_batch()
        return

    def _splice_span_elements(self, edits) -> None:
        leaves = self.levels[0]
        cum = self._cum_counts()
        is_sorted = self.kind in SORTED_KINDS
        first = min(e[0] for e in edits)
        j0 = min(int(np.searchsorted(cum, first, side="right")), len(leaves) - 1)
        base = int(cum[j0 - 1]) if j0 > 0 else 0
        last_end = max(e[1] for e in edits)
        jE = min(int(np.searchsorted(cum, max(last_end - 1, first),
                                     side="right")), len(leaves) - 1)
        warm = self._warmup_bytes(j0)
        grow = max(2, jE - j0 + 1)
        while True:
            jx = min(jE + grow, len(leaves) - 1)
            old_els: list[bytes] = []
            old_keys: list[bytes] = []
            for j in range(j0, jx + 1):
                els = self.leaf_elements(j)
                if self.kind == ck.MAP:
                    old_els.extend(ck.pack_kv(k, v) for k, v in els)
                    old_keys.extend(k for k, _ in els)
                elif self.kind == ck.SET:
                    old_els.extend(ck.pack_lv(e) for e in els)
                    old_keys.extend(els)
                else:
                    old_els.extend(ck.pack_lv(e) for e in els)
            els_new = list(old_els)
            keys_new = list(old_keys)
            for s, e, reps, rkeys in sorted(edits, key=lambda t: t[0],
                                            reverse=True):
                ls, le = s - base, e - base
                els_new[ls:le] = reps
                if is_sorted:
                    keys_new[ls:le] = rkeys or []
            delta = len(els_new) - len(old_els)
            at_stream_end = jx == len(leaves) - 1
            stream = np.frombuffer(b"".join(els_new), dtype=np.uint8)
            wb = np.frombuffer(warm, dtype=np.uint8)
            bitmap = boundary_bitmap(np.concatenate([wb, stream]),
                                     self.params)[len(wb):]
            lengths = [len(e) for e in els_new]
            cuts = cut_elements(lengths, bitmap, self.params)
            bytecum = np.cumsum([0] + lengths)
            # stability guard in byte space
            stable_el = (last_end - base) + delta
            stable_byte = (int(bytecum[stable_el]) + self.params.window
                           if 0 <= stable_el <= len(lengths) else 1 << 62)
            cumset = {int(c): i + 1 for i, c in enumerate(cum)}
            splice_at = None
            for ci, c in enumerate(cuts[:-1] if not at_stream_end else cuts):
                if c < stable_el or int(bytecum[c]) < stable_byte:
                    continue
                old_idx = c - delta + base
                if old_idx in cumset and old_idx >= last_end:
                    splice_at = (ci, cumset[old_idx])
                    break
            if splice_at is None and not at_stream_end:
                grow *= 2
                continue
            raws, counts, lkeys = [], [], []
            start = 0
            upto = len(cuts) if splice_at is None else splice_at[0] + 1
            for c in cuts[:upto]:
                raws.append(ck.encode_chunk(self.kind,
                                            b"".join(els_new[start:c])))
                counts.append(c - start)
                lkeys.append(keys_new[c - 1] if is_sorted else None)
                start = c
            new_leaves = [Entry(cid, cnt, key) for cid, cnt, key
                          in zip(self._put_chunks(raws), counts, lkeys)]
            tail = leaves[splice_at[1]:] if splice_at else []
            self.levels[0] = leaves[:j0] + new_leaves + tail
            if not self.levels[0]:
                self.levels[0] = self._empty(self.store, self.kind,
                                             self.params).levels[0]
            # invalidate caches; caller rebuilds the index once at the end
            self._cum = None
            self._keycache = None
            self._leaf_cache.clear()
            return

    # ------------------------------------------------------------ diff
    def diff_leaf_blocks(self, other: "POSTree"):
        """Matched/unmatched leaf runs via cid comparison.  Returns
        SequenceMatcher opcodes over leaf-cid sequences — identical-cid
        subtree skipping is what makes Diff O(difference) (paper §4.3)."""
        a = [e.cid for e in self.levels[0]]
        b = [e.cid for e in other.levels[0]]
        sm = SequenceMatcher(a=a, b=b, autojunk=False)
        return sm.get_opcodes()

    def diff_keys(self, other: "POSTree"):
        """Sorted kinds: (added, removed, changed) keys vs `other`
        (self = new, other = old), parsing only differing leaves."""
        if self.kind not in SORTED_KINDS or other.kind != self.kind:
            raise InvariantViolation(
                f"diff_keys needs matching sorted kinds, got {self.kind}/{other.kind}")
        acids = {e.cid for e in self.levels[0]}
        bcids = {e.cid for e in other.levels[0]}
        da = [i for i, e in enumerate(self.levels[0]) if e.cid not in bcids]
        db = [i for i, e in enumerate(other.levels[0]) if e.cid not in acids]
        if self.kind == ck.MAP:
            dicta = {k: v for i in da for k, v in self.leaf_elements(i)}
            dictb = {k: v for i in db for k, v in other.leaf_elements(i)}
        else:
            dicta = {k: b"" for i in da for k in self.leaf_elements(i)}
            dictb = {k: b"" for i in db for k in other.leaf_elements(i)}
        added = sorted(k for k in dicta if k not in dictb)
        removed = sorted(k for k in dictb if k not in dicta)
        changed = sorted(k for k in dicta
                         if k in dictb and dicta[k] != dictb[k])
        return added, removed, changed
