"""Cyclic-polynomial rolling hash for content-defined chunking (paper §4.3.2).

    P(b_1..b_k) = s^{k-1}(h(b_1)) ^ s^{k-2}(h(b_2)) ^ ... ^ s^0(h(b_k))

where ``h`` maps a byte to a pseudo-random word and ``s`` is a 1-bit barrel
rotation.  A *pattern* occurs at stream position i when the low ``q`` bits of
P over the window ending at i are all zero; the expected distance between
patterns is 2^q bytes (the paper's default chunk size 4 KB -> q = 12).

The paper defines the rotation within q bits; we rotate within a 32-bit word
(classic buzhash) which has strictly better mixing and the identical boundary
statistics — the pattern predicate only inspects the low q bits.  This is the
numpy *reference*; kernels/chunker.py is the Pallas/TPU version and
kernels/ref.py cross-checks both.

The boundary bitmap is a pure function of the byte stream (the scan window
slides continuously and never resets at cuts), which is the invariant that
makes chunk boundaries stable under local edits and lets incremental commits
splice back into the old chunk sequence (postree.py).
"""
from __future__ import annotations

import numpy as np

WORD_BITS = 32
_MASK32 = np.uint32(0xFFFFFFFF)


def mix32(x: np.ndarray) -> np.ndarray:
    """murmur3 finalizer — a bijective 32-bit mixer computable with pure
    vector-ALU ops, so the Pallas kernel evaluates h(byte) arithmetically
    instead of gathering from a table (TPU adaptation, DESIGN.md §3)."""
    x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint32(16)
        x *= np.uint32(0x85EBCA6B)
        x ^= x >> np.uint32(13)
        x *= np.uint32(0xC2B2AE35)
        x ^= x >> np.uint32(16)
    return x


def byte_table(seed: int = 0xF0B) -> np.ndarray:
    """Deterministic h: byte -> u32 table shared by reference and kernels
    (table[b] = mix32(b + seed*GOLDEN))."""
    base = np.arange(256, dtype=np.uint64) + np.uint64(seed) * np.uint64(0x9E3779B9)
    return mix32((base & np.uint64(0xFFFFFFFF)).astype(np.uint32))


_TABLE = byte_table()


def _rotl(x: np.ndarray, r: int) -> np.ndarray:
    r %= WORD_BITS
    if r == 0:
        return x
    return ((x << np.uint32(r)) | (x >> np.uint32(WORD_BITS - r))) & _MASK32


def rolling_hash(data: np.ndarray, window: int) -> np.ndarray:
    """P_i over the window ending at i, for all i >= window-1 (else 0).

    data: uint8[n].  Returns uint32[n]; positions < window-1 are 0 and never
    treated as boundaries (no full window yet).
    """
    data = np.ascontiguousarray(data, dtype=np.uint8)
    n = data.shape[0]
    h = _TABLE[data]  # u32[n]
    acc = np.zeros(n, dtype=np.uint32)
    # P_i = XOR_{j=0..k-1} rotl(h[i-j], j): k vectorized passes (k ~ 48).
    for j in range(window):
        if j == 0:
            acc ^= h
        else:
            acc[j:] ^= _rotl(h[:-j] if j else h, j)
    if window > 1:
        acc[: window - 1] = 0
    return acc


def boundary_bitmap(data: np.ndarray, window: int, q: int) -> np.ndarray:
    """bool[n]: True at i iff a pattern ends at byte i (paper's predicate
    ``P & (2^q - 1) == 0``).  Positions without a full window are False."""
    p = rolling_hash(data, window)
    mask = np.uint32((1 << q) - 1)
    hits = (p & mask) == 0
    if window > 1:
        hits[: window - 1] = False
    return hits


def rolling_hash_serial(data: bytes, window: int) -> np.ndarray:
    """O(n) serial recursive form (paper's amortized update rule):
        P_i = s(P_{i-1}) ^ s^k(h(b_{i-k})) ^ h(b_i)
    Used by tests to validate the vectorized form."""
    n = len(data)
    out = np.zeros(n, dtype=np.uint32)
    h = _TABLE[np.frombuffer(data, dtype=np.uint8)] if n else np.zeros(0, np.uint32)
    p = np.uint32(0)
    for i in range(n):
        p = _rotl(np.uint32(p), 1) ^ np.uint32(h[i])
        if i >= window:
            p ^= _rotl(np.uint32(h[i - window]), window % WORD_BITS)
        if i >= window - 1:
            out[i] = p
    return out
