"""Event-driven cluster runtime (paper §4.1's dispatcher, made real).

The synchronous ``Cluster`` verbs serve one request per call: every
client put is its own WriteBuffer flush, its own per-node ``put_many``
fan-out, its own branch-table update.  This module adds the runtime
the deployment section describes:

* **Coalesced dispatch** — concurrent client requests queue per home
  servlet and drain in cross-client batches through
  ``Cluster.put_batch`` / ``get_batch``: one WriteBuffer flush (one
  routing ``put_many`` per storage node) covers every request in the
  batch — the §4.6.1 WriteBuffer idea lifted from the chunk layer to
  the RPC layer.

* **Bounded queues with obs-driven admission** — each servlet queue is
  bounded; a full queue raises :class:`Backpressure` to the submitting
  client instead of buffering without limit.  Admission reads the same
  instruments ``obs.snapshot()`` exports: a windowed p99 over the
  routing store's ``store_put_us`` histogram (bucket-array diffs — no
  per-sample storage) plus the recent span tree (any fresh slow
  ``store.put``/``cluster.put`` root), and halves the effective queue
  bound and dispatch batch while the store is slow, shedding load
  early rather than at the deep end of the queue.

* **MaintenanceDaemon** — ONE time-paced loop sharing one per-tick
  budget across every background duty: re-replication of quarantined
  nodes' chunks, incremental-GC slices, continuous-audit ticks, epoch
  folds (staggered one servlet per fold tick, so no tick stalls every
  servlet), and store flush/compaction (also staggered).  The daemon
  backs off — quarters its budget — when the foreground is busy, as
  judged by the queue-depth gauges and put-rate counters that
  ``obs.snapshot()`` exposes.

Everything works in two modes: synchronous ``drain()`` on the caller's
thread (deterministic — what the tests use) and threaded
``start()``/``stop()`` with one dispatcher worker per servlet plus the
daemon thread.  Thread safety leans on the cluster's documented lock
order: servlet lock ≺ collector lock ≺ {index lock, store lock}
(canonical, machine-readable table: ``core.locking.LOCK_ORDER``;
the LOCK001 static rule and the runtime lock witness both enforce
it from that single source).
"""
from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass

from .. import obs
from ..errors import Backpressure

__all__ = ["Backpressure", "RuntimeConfig", "ClusterRuntime",
           "MaintenanceDaemon"]


@dataclass
class RuntimeConfig:
    # admission / dispatch
    queue_depth: int = 256       # per-servlet bound (requests)
    max_batch: int = 64          # requests coalesced per dispatch
    admission_p99_us: float = 20_000.0   # windowed store-put p99 above
    #   which admission halves the queue bound and dispatch batch
    slow_span_us: float = 50_000.0       # a fresh root span this slow
    #   counts as a latency signal too (span-tree admission input)
    # maintenance daemon
    tick_interval_s: float = 0.005       # time pacing between ticks
    tick_budget: int = 128       # work units (chunks/targets) per tick
    backoff_queued: int = 32     # queued foreground requests ⇒ back off
    backoff_put_rate: int = 256  # foreground puts since last tick ⇒ idem
    fold_every: int = 4          # ticks between staggered epoch folds
    audit_every: int = 2         # ticks between audit ticks
    compact_every: int = 8       # ticks between staggered store flushes
    gc_cycle_ticks: int = 0      # >0: begin an incremental GC epoch
    #   every N ticks (0 = caller manages collections)


class _AdmissionController:
    """Windowed latency signal from instruments ``obs.snapshot()``
    exports.  ``store_put_us{backend=routing}`` is cumulative, so the
    window is the *diff* of its bucket array since the last decision;
    the span input uses the monotonic ``start_us`` stamp (same clock as
    event ``mono_us``) to consider only spans that finished since then.
    With observability disabled there are no samples and admission
    falls back to the static queue bound."""

    def __init__(self, cfg: RuntimeConfig):
        self.cfg = cfg
        # repro: allow(OBS001): once-per-runtime construction, not a hot
        # path — the histogram handle is cached and must exist even if
        # obs is enabled later mid-run
        self._hist = obs.REGISTRY.histogram("store_put_us",
                                            {"backend": "routing"})
        self._last_buckets = list(self._hist.buckets)
        self._last_mono_us = obs.monotonic() * 1e6
        self._lock = threading.Lock()
        self.congested = False

    def _window_p99(self) -> float:
        cur = list(self._hist.buckets)
        delta = [c - p for c, p in zip(cur, self._last_buckets)]
        self._last_buckets = cur
        n = sum(delta)
        if n <= 0:
            return 0.0
        want = 0.99 * n
        seen = 0
        for i, c in enumerate(delta):
            seen += c
            if seen >= want:
                return float(1 << i)
        return float(1 << (len(delta) - 1))

    def _fresh_slow_span(self, since_us: float) -> bool:
        for root in obs.recent_spans():
            for sp in root.walk():
                if (sp.start_s * 1e6 > since_us
                        and sp.name in ("store.put", "cluster.put",
                                        "engine.put_batch")
                        and sp.duration_s * 1e6 > self.cfg.slow_span_us):
                    return True
        return False

    def update(self) -> bool:
        """Refresh the congestion verdict (called once per dispatch
        round, not per request).  Returns the new verdict."""
        if not obs.REGISTRY.enabled:
            self.congested = False
            return False
        with self._lock:
            since = self._last_mono_us
            self._last_mono_us = obs.monotonic() * 1e6
            p99 = self._window_p99()
        congested = (p99 > self.cfg.admission_p99_us
                     or self._fresh_slow_span(since))
        if congested and not self.congested:
            obs.emit("runtime.congested", window_p99_us=p99)
        self.congested = congested
        return congested

    def bound(self) -> int:
        return (self.cfg.queue_depth // 2 if self.congested
                else self.cfg.queue_depth)

    def batch(self) -> int:
        return (max(1, self.cfg.max_batch // 2) if self.congested
                else self.cfg.max_batch)


class _Op:
    __slots__ = ("kind", "req", "future")

    def __init__(self, kind: str, req: tuple):
        self.kind = kind           # "put" | "get"
        self.req = req
        self.future: Future = Future()


class _ServletQueue:
    """Bounded MPSC queue: many submitting clients, one dispatcher."""

    def __init__(self, ni: int):
        self.ni = ni
        self.items: deque[_Op] = deque()
        # unranked leaf mutex (never wraps a ranked acquisition);
        # deliberately NOT named *lock so LOCK001's unranked-lock check
        # stays meaningful for real lock attributes
        self._mutex = threading.Lock()
        self.ready = threading.Condition(self._mutex)

    def push(self, op: _Op, bound: int) -> None:
        with self.ready:
            if len(self.items) >= bound:
                raise Backpressure(self.ni, len(self.items), bound)
            self.items.append(op)
            self.ready.notify()

    def pop_run(self, limit: int) -> list[_Op]:
        """Pop a contiguous run of SAME-KIND ops (≤ limit).  Kind runs
        keep per-key program order: a get queued after a put never
        dispatches before it."""
        with self._mutex:
            if not self.items:
                return []
            kind = self.items[0].kind
            run = []
            while (self.items and len(run) < limit
                   and self.items[0].kind == kind):
                run.append(self.items.popleft())
            return run

    def __len__(self) -> int:
        return len(self.items)


class ClusterRuntime:
    """Event-driven front half: per-servlet bounded queues + coalesced
    batch dispatch.  ``submit_put``/``submit_get`` return Futures;
    ``put``/``get`` are their blocking forms.  ``drain()`` dispatches
    everything queued on the caller's thread (deterministic);
    ``start()`` spawns one dispatcher worker per servlet."""

    def __init__(self, cluster, config: RuntimeConfig | None = None):
        self.cluster = cluster
        self.cfg = config or RuntimeConfig()
        self.admission = _AdmissionController(self.cfg)
        self.queues = [_ServletQueue(i)
                       for i in range(len(cluster.nodes))]
        self._threads: list[threading.Thread] = []
        self._stopping = False
        self.daemon: MaintenanceDaemon | None = None

    # ------------------------------------------------------ submission
    def submit_put(self, key, value, branch=None, **kw) -> Future:
        op = _Op("put", (key, value, branch, kw))
        self._admit(key, op)
        return op.future

    def submit_get(self, key, branch=None, **kw) -> Future:
        op = _Op("get", (key, branch, kw))
        self._admit(key, op)
        return op.future

    def _admit(self, key, op: _Op) -> None:
        ni = self.cluster._home_index(key)
        try:
            self.queues[ni].push(op, self.admission.bound())
        except Backpressure:
            obs.inc("runtime_backpressure_total")
            raise
        obs.inc("runtime_submitted_total", labels={"kind": op.kind})

    def put(self, key, value, branch=None, **kw):
        """Blocking submit: queue, drain if unthreaded, await."""
        f = self.submit_put(key, value, branch, **kw)
        if not self._threads:
            self.drain()
        return f.result()

    def get(self, key, branch=None, **kw):
        f = self.submit_get(key, branch, **kw)
        if not self._threads:
            self.drain()
        return f.result()

    def queued(self) -> int:
        return sum(len(q) for q in self.queues)

    # -------------------------------------------------------- dispatch
    def _dispatch(self, run: list[_Op]) -> None:
        """Dispatch one same-kind run as a single coalesced batch."""
        if not run:
            return
        t0 = obs.monotonic()
        if run[0].kind == "put":
            # guarded / fork-on-conflict puts fail per-request (a guard
            # miss must not poison neighbours); plain puts are all-or-
            # nothing (one WriteBuffer flush covers them — on error
            # nothing was published, so the shared failure is truthful)
            plain = [op for op in run
                     if not (op.req[3].get("guard_uid")
                             or op.req[3].get("base_uid"))]
            for op in run:
                if op not in plain:
                    try:
                        k, v, b, kw = op.req
                        op.future.set_result(
                            self.cluster.put(k, v, b, **kw))
                    except BaseException as e:  # noqa: BLE001
                        op.future.set_exception(e)
            if plain:
                try:
                    uids = self.cluster.put_batch(
                        [op.req for op in plain])
                    for op, uid in zip(plain, uids):
                        op.future.set_result(uid)
                except BaseException as e:  # noqa: BLE001
                    for op in plain:
                        op.future.set_exception(e)
        else:
            try:
                vals = self.cluster.get_batch([op.req for op in run])
                for op, v in zip(run, vals):
                    op.future.set_result(v)
            except BaseException:           # isolate the offending get
                for op in run:
                    try:
                        k, b, kw = op.req
                        op.future.set_result(self.cluster.get(k, b, **kw))
                    except BaseException as e:  # noqa: BLE001
                        op.future.set_exception(e)
        if obs.REGISTRY.enabled:
            obs.REGISTRY.histogram("runtime_dispatch_us").observe(
                obs.monotonic() - t0)
            obs.REGISTRY.histogram("runtime_batch_requests").observe(
                len(run) / 1e6)        # histogram buckets are µs-shaped;
            #   feed the raw count through the same power-of-2 buckets
            obs.inc("runtime_coalesced_total", len(run))

    def drain(self) -> int:
        """Synchronously dispatch until every queue is empty.  The
        dispatcher path used by tests and unthreaded callers; worker
        threads run the same per-queue logic.  Returns ops dispatched."""
        done = 0
        while True:
            self.admission.update()
            limit = self.admission.batch()
            idle = True
            for q in self.queues:
                run = q.pop_run(limit)
                if run:
                    idle = False
                    done += len(run)
                    self._dispatch(run)
                if obs.REGISTRY.enabled:
                    obs.set_gauge("runtime_queue_depth", len(q),
                                  {"servlet": str(q.ni)})
            if idle:
                return done

    # -------------------------------------------------------- threading
    def start(self, *, daemon: bool = False,
              daemon_kwargs: dict | None = None) -> "ClusterRuntime":
        """Spawn one dispatcher worker per servlet (and optionally the
        MaintenanceDaemon).  Idempotent; returns self."""
        if self._threads:
            return self
        self._stopping = False
        for q in self.queues:
            t = threading.Thread(target=self._worker, args=(q,),
                                 name=f"repro-dispatch-{q.ni}",
                                 daemon=True)
            t.start()
            self._threads.append(t)
        if daemon:
            self.daemon = MaintenanceDaemon(self.cluster, runtime=self,
                                            config=self.cfg,
                                            **(daemon_kwargs or {}))
            self.daemon.start()
        return self

    def _worker(self, q: _ServletQueue) -> None:
        while True:
            with q.ready:
                while not q.items and not self._stopping:
                    q.ready.wait(timeout=0.05)
                if self._stopping and not q.items:
                    return
            self.admission.update()
            run = q.pop_run(self.admission.batch())
            self._dispatch(run)
            if obs.REGISTRY.enabled:
                obs.set_gauge("runtime_queue_depth", len(q),
                              {"servlet": str(q.ni)})

    def stop(self) -> None:
        """Drain in-flight queues, stop workers and the daemon."""
        self._stopping = True
        for q in self.queues:
            with q.ready:
                q.ready.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()
        if self.daemon is not None:
            self.daemon.stop()
            self.daemon = None
        self.drain()              # anything submitted during shutdown

    def __enter__(self) -> "ClusterRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class MaintenanceDaemon:
    """ONE background loop, one budget, every background duty.

    Per tick (time-paced at ``tick_interval_s``), in priority order and
    all drawing down the same ``tick_budget`` of work units:

    1. re-replication slices (data safety first — drains the backlog
       ``Cluster.quarantine_node`` snapshotted);
    2. an incremental-GC slice, if a collection is in flight (the
       daemon can also *begin* epochs on a cycle: ``gc_cycle_ticks``);
    3. a continuous-audit tick (every ``audit_every`` ticks);
    4. ONE servlet's epoch fold (every ``fold_every`` ticks, round-
       robin — staggered so a fold tick never stalls every servlet);
    5. ONE node store's flush/compaction (every ``compact_every``
       ticks, round-robin — the durable store's segment compactor is
       fed by these).

    Foreground load backs the daemon off: when the runtime's queues are
    deep or the routing store's put counter moved a lot since the last
    tick (the same signals ``obs.snapshot()`` exports as
    ``runtime_queue_depth`` gauges and ``store_put_us`` counts), the
    tick runs at a quarter budget and skips the fold/compaction duties.
    """

    def __init__(self, cluster, *, runtime: ClusterRuntime | None = None,
                 config: RuntimeConfig | None = None,
                 audit_budget: int = 1):
        self.cluster = cluster
        self.runtime = runtime
        self.cfg = config or RuntimeConfig()
        self.audit_budget = audit_budget
        self.ticks = 0
        self.collector = None          # in-flight incremental GC epoch
        self._fold_rr = 0
        self._compact_rr = 0
        self._put_seen = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.last_report: dict = {}

    # ------------------------------------------------------ load signal
    def _backoff(self) -> bool:
        queued = self.runtime.queued() if self.runtime is not None else 0
        rate = 0
        if obs.REGISTRY.enabled:
            count = obs.REGISTRY.histogram(
                "store_put_us", {"backend": "routing"}).count
            rate = count - self._put_seen
            self._put_seen = count
        return (queued > self.cfg.backoff_queued
                or rate > self.cfg.backoff_put_rate)

    # ------------------------------------------------------------ tick
    def tick(self, budget: int | None = None) -> dict:
        """One maintenance tick.  Returns {duty: work done} — also kept
        as ``last_report``."""
        cfg = self.cfg
        self.ticks += 1
        budget = cfg.tick_budget if budget is None else budget
        backoff = self._backoff()
        if backoff:
            budget = max(1, budget // 4)
            obs.inc("daemon_backoffs_total")
        rep = {"tick": self.ticks, "budget": budget, "backoff": backoff,
               "rerep": 0, "gc": 0, "audits": 0, "folds": 0,
               "compactions": 0}
        # 1. re-replication
        if budget > 0:
            n = self.cluster.rereplicate_step(budget)
            rep["rerep"] = n
            budget -= n
        # 2. incremental GC
        if (cfg.gc_cycle_ticks and self.ticks % cfg.gc_cycle_ticks == 0
                and (self.collector is None or not self.collector.active)):
            self.collector = self.cluster.incremental_gc()
        if budget > 0 and self.collector is not None \
                and self.collector.active:
            # the GC slice takes the rest of the grant MINUS one unit
            # per later duty due this very tick — a long collection
            # (many ticks of active slices) must not starve the audit /
            # fold / compaction cadences for its whole epoch
            reserve = 0
            if self.ticks % cfg.audit_every == 0:
                reserve += self.audit_budget
            if not backoff:
                if self.ticks % cfg.fold_every == 0:
                    reserve += 1
                if self.ticks % cfg.compact_every == 0:
                    reserve += 1
            grant = max(1, budget - reserve)
            self.collector.step(grant)
            rep["gc"] = grant
            budget -= grant
        # 3. continuous audit
        if budget > 0 and self.ticks % cfg.audit_every == 0:
            self.cluster.audit_tick(self.audit_budget)
            rep["audits"] = self.audit_budget
            budget -= self.audit_budget
        # folds/compactions yield entirely to a busy foreground: they
        # take servlet/store locks the foreground needs right now
        if not backoff:
            nn = len(self.cluster.nodes)
            # 4. staggered epoch fold
            if budget > 0 and self.ticks % cfg.fold_every == 0:
                self.cluster.commit_epoch_on(self._fold_rr % nn)
                self._fold_rr += 1
                rep["folds"] = 1
                budget -= 1
            # 5. staggered store flush / compaction
            if budget > 0 and self.ticks % cfg.compact_every == 0:
                ni = self._compact_rr % nn
                nd = self.cluster.nodes[ni]
                with nd.store_lock:
                    nd.store.flush()
                self._compact_rr += 1
                rep["compactions"] = 1
        self.last_report = rep
        if obs.REGISTRY.enabled:
            obs.inc("daemon_ticks_total")
            obs.set_gauge("daemon_rerep_backlog",
                          self.cluster.rerep_backlog())
        return rep

    # -------------------------------------------------------- threading
    def start(self) -> "MaintenanceDaemon":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._run,
                                        name="repro-maintenance",
                                        daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        interval = self.cfg.tick_interval_s
        while not self._stop.is_set():
            t0 = obs.monotonic()
            self.tick()
            elapsed = obs.monotonic() - t0
            # time pacing: a long tick never stacks the next one early
            self._stop.wait(max(0.0, interval - elapsed))

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
