"""Built-in data types (paper §3.4).

Primitive types (String, Tuple, Integer) are embedded in the meta chunk and
never deduplicated; chunkable types (Blob, List, Map, Set) are POS-Trees.
Handles buffer edits client-side (piece table / overlay) and flush them as a
single batched incremental commit on Put — matching Fig. 4's programming
model ("changes are buffered in client").  Get returns a handle; leaf data
is fetched lazily, chunk by chunk (§3.4).
"""
from __future__ import annotations

import struct

from . import chunk as ck
from .chunker import ChunkParams, DEFAULT_PARAMS
from .fobject import TINT, TSTRING, TTUPLE
from .pieces import PieceTable
from .postree import POSTree

_I64 = struct.Struct("<q")


# ===================================================================== blobs

class FBlob:
    """Byte-addressable blob: Read / Append / Insert / Remove (Fig. 4)."""

    TYPE = ck.BLOB

    def __init__(self, data: bytes = b"", *, _tree: POSTree | None = None,
                 params: ChunkParams = DEFAULT_PARAMS):
        self.params = params
        self._tree = _tree
        base_len = _tree.total_count if _tree is not None else 0
        self._pt = PieceTable(base_len)
        if data:
            self._pt.splice(0, 0, bytes(data), len(data))

    @classmethod
    def from_tree(cls, tree: POSTree) -> "FBlob":
        return cls(_tree=tree, params=tree.params)

    def __len__(self) -> int:
        return len(self._pt)

    def _base_read(self, s: int, e: int) -> bytes:
        return self._tree.read_bytes(s, e - s) if self._tree is not None else b""

    def read(self, start: int = 0, length: int | None = None) -> bytes:
        end = len(self) if length is None else min(start + length, len(self))
        return self._pt.read(start, end, self._base_read,
                             lambda ps: b"".join(ps))

    def append(self, data: bytes) -> None:
        self._pt.splice(len(self), len(self), bytes(data), len(data))

    def insert(self, pos: int, data: bytes) -> None:
        self._pt.splice(pos, pos, bytes(data), len(data))

    def remove(self, pos: int, length: int) -> None:
        self._pt.splice(pos, min(pos + length, len(self)), b"", 0)

    def replace(self, pos: int, length: int, data: bytes) -> None:
        self._pt.splice(pos, min(pos + length, len(self)), bytes(data),
                        len(data))

    def commit(self, store) -> bytes:
        """Flush buffered edits; returns the POS-Tree root cid."""
        if self._tree is None:
            self._tree = POSTree.build_bytes(store, self.read(), self.params)
        elif self._pt.dirty:
            edits = self._pt.base_edits(lambda ps: b"".join(ps))
            self._tree.splice_bytes(edits, sink=store)
        self._pt = PieceTable(self._tree.total_count)
        return self._tree.root_cid

    @property
    def tree(self) -> POSTree | None:
        return self._tree


# ===================================================================== lists

class FList:
    """Positional element list."""

    TYPE = ck.LIST

    def __init__(self, elements: list[bytes] | None = None, *,
                 _tree: POSTree | None = None,
                 params: ChunkParams = DEFAULT_PARAMS):
        self.params = params
        self._tree = _tree
        base_len = _tree.total_count if _tree is not None else 0
        self._pt = PieceTable(base_len)
        if elements:
            els = [bytes(e) for e in elements]
            self._pt.splice(0, 0, els, len(els))

    @classmethod
    def from_tree(cls, tree: POSTree) -> "FList":
        return cls(_tree=tree, params=tree.params)

    def __len__(self) -> int:
        return len(self._pt)

    def _base_read(self, s: int, e: int) -> list[bytes]:
        return [self._tree.get_item(i) for i in range(s, e)]

    def get(self, i: int) -> bytes:
        return self._pt.read(i, i + 1, self._base_read,
                             lambda ps: [x for p in ps for x in p])[0]

    def slice(self, s: int, e: int) -> list[bytes]:
        return self._pt.read(s, min(e, len(self)), self._base_read,
                             lambda ps: [x for p in ps for x in p])

    def set(self, i: int, v: bytes) -> None:
        self._pt.splice(i, i + 1, [bytes(v)], 1)

    def insert(self, i: int, v: bytes) -> None:
        self._pt.splice(i, i, [bytes(v)], 1)

    def append(self, v: bytes) -> None:
        self._pt.splice(len(self), len(self), [bytes(v)], 1)

    def extend(self, vs: list[bytes]) -> None:
        vs = [bytes(v) for v in vs]
        self._pt.splice(len(self), len(self), vs, len(vs))

    def delete(self, i: int, n: int = 1) -> None:
        self._pt.splice(i, min(i + n, len(self)), [], 0)

    def __iter__(self):
        return iter(self.slice(0, len(self)))

    def commit(self, store) -> bytes:
        if self._tree is None:
            els = [ck.pack_lv(e) for e in self.slice(0, len(self))]
            self._tree = POSTree.build_elements(store, ck.LIST, els,
                                                params=self.params)
        elif self._pt.dirty:
            raw_edits = self._pt.base_edits(
                lambda ps: [x for p in ps for x in p])
            edits = [(s, e, [ck.pack_lv(x) for x in rep], None)
                     for s, e, rep in raw_edits]
            self._tree.splice_elements(edits, sink=store)
        self._pt = PieceTable(self._tree.total_count)
        return self._tree.root_cid

    @property
    def tree(self) -> POSTree | None:
        return self._tree


# ================================================================== map/set

_DEL = object()


class FMap:
    """Sorted key->value map; overlay-buffered edits."""

    TYPE = ck.MAP

    def __init__(self, items: dict[bytes, bytes] | None = None, *,
                 _tree: POSTree | None = None,
                 params: ChunkParams = DEFAULT_PARAMS):
        self.params = params
        self._tree = _tree
        self._ov: dict[bytes, object] = {}
        if items:
            for k, v in items.items():
                self._ov[bytes(k)] = bytes(v)

    @classmethod
    def from_tree(cls, tree: POSTree) -> "FMap":
        return cls(_tree=tree, params=tree.params)

    def get(self, k: bytes) -> bytes | None:
        k = bytes(k)
        if k in self._ov:
            v = self._ov[k]
            return None if v is _DEL else v  # type: ignore[return-value]
        if self._tree is None:
            return None
        found, j, li, gi = self._tree.find_key(k)
        return self._tree.get_item(gi)[1] if found else None

    def set(self, k: bytes, v: bytes) -> None:
        self._ov[bytes(k)] = bytes(v)

    def update(self, items) -> None:
        for k, v in (items.items() if isinstance(items, dict) else items):
            self._ov[bytes(k)] = bytes(v)

    def delete(self, k: bytes) -> None:
        self._ov[bytes(k)] = _DEL

    def items(self):
        """Sorted merged iteration (tree + overlay)."""
        ovkeys = sorted(self._ov)
        oi = 0
        if self._tree is not None:
            for k, v in self._tree.iter_elements():
                while oi < len(ovkeys) and ovkeys[oi] < k:
                    ov = self._ov[ovkeys[oi]]
                    if ov is not _DEL:
                        yield ovkeys[oi], ov
                    oi += 1
                if oi < len(ovkeys) and ovkeys[oi] == k:
                    ov = self._ov[ovkeys[oi]]
                    if ov is not _DEL:
                        yield k, ov
                    oi += 1
                else:
                    yield k, v
        while oi < len(ovkeys):
            ov = self._ov[ovkeys[oi]]
            if ov is not _DEL:
                yield ovkeys[oi], ov
            oi += 1

    def __len__(self) -> int:
        n = self._tree.total_count if self._tree is not None else 0
        for k, v in self._ov.items():
            if self._tree is not None:
                found, *_ = self._tree.find_key(k)
            else:
                found = False
            if v is _DEL:
                n -= 1 if found else 0
            else:
                n += 0 if found else 1
        return n

    def commit(self, store) -> bytes:
        if self._tree is None:
            items = sorted((k, v) for k, v in self._ov.items()
                           if v is not _DEL)
            els = [ck.pack_kv(k, v) for k, v in items]
            keys = [k for k, _ in items]
            self._tree = POSTree.build_elements(store, ck.MAP, els, keys,
                                                self.params)
        elif len(self._ov) * 4 >= self._tree.total_count:
            # epoch-fold fast path (live tables): when the delta
            # dominates the tree, per-key find_key + clustered splice
            # costs more than streaming the sorted merge of tree and
            # overlay straight through build_elements — one put_many
            # for all leaves, one content_hash_many dispatch per index
            # level.  Node boundaries are a function of content alone,
            # so the root is bit-identical to the splice path's.
            items = list(self.items())
            els = [ck.pack_kv(k, v) for k, v in items]
            keys = [k for k, _ in items]
            self._tree = POSTree.build_elements(store, ck.MAP, els, keys,
                                                self.params)
        elif self._ov:
            edits = []
            for k in sorted(self._ov):
                v = self._ov[k]
                found, j, li, gi = self._tree.find_key(k)
                if v is _DEL:
                    if found:
                        edits.append((gi, gi + 1, [], []))
                elif found:
                    if self._tree.get_item(gi)[1] != v:
                        edits.append((gi, gi + 1, [ck.pack_kv(k, v)], [k]))
                else:
                    edits.append((gi, gi, [ck.pack_kv(k, v)], [k]))
            edits = _coalesce(edits)
            if edits:
                self._tree.splice_elements(edits, sink=store)
        self._ov = {}
        return self._tree.root_cid

    @property
    def tree(self) -> POSTree | None:
        return self._tree


class FSet:
    TYPE = ck.SET

    def __init__(self, items=None, *, _tree: POSTree | None = None,
                 params: ChunkParams = DEFAULT_PARAMS):
        self.params = params
        self._tree = _tree
        self._ov: dict[bytes, bool] = {}  # True=add, False=remove
        for it in items or []:
            self._ov[bytes(it)] = True

    @classmethod
    def from_tree(cls, tree: POSTree) -> "FSet":
        return cls(_tree=tree, params=tree.params)

    def contains(self, k: bytes) -> bool:
        k = bytes(k)
        if k in self._ov:
            return self._ov[k]
        if self._tree is None:
            return False
        found, *_ = self._tree.find_key(k)
        return found

    def add(self, k: bytes) -> None:
        self._ov[bytes(k)] = True

    def remove(self, k: bytes) -> None:
        self._ov[bytes(k)] = False

    def __iter__(self):
        ovkeys = sorted(self._ov)
        oi = 0
        if self._tree is not None:
            for k in self._tree.iter_elements():
                while oi < len(ovkeys) and ovkeys[oi] < k:
                    if self._ov[ovkeys[oi]]:
                        yield ovkeys[oi]
                    oi += 1
                if oi < len(ovkeys) and ovkeys[oi] == k:
                    if self._ov[ovkeys[oi]]:
                        yield k
                    oi += 1
                else:
                    yield k
        while oi < len(ovkeys):
            if self._ov[ovkeys[oi]]:
                yield ovkeys[oi]
            oi += 1

    def commit(self, store) -> bytes:
        if self._tree is None:
            items = sorted(k for k, add in self._ov.items() if add)
            els = [ck.pack_lv(k) for k in items]
            self._tree = POSTree.build_elements(store, ck.SET, els, items,
                                                self.params)
        elif self._ov:
            edits = []
            for k in sorted(self._ov):
                add = self._ov[k]
                found, j, li, gi = self._tree.find_key(k)
                if add and not found:
                    edits.append((gi, gi, [ck.pack_lv(k)], [k]))
                elif not add and found:
                    edits.append((gi, gi + 1, [], []))
            edits = _coalesce(edits)
            if edits:
                self._tree.splice_elements(edits, sink=store)
        self._ov = {}
        return self._tree.root_cid

    @property
    def tree(self) -> POSTree | None:
        return self._tree


def _coalesce(edits):
    """Merge adjacent/same-position element edits into non-overlapping,
    sorted splices (find_key indices may collide for consecutive inserts)."""
    if not edits:
        return edits
    edits.sort(key=lambda t: (t[0], t[1]))
    out = [list(edits[0])]
    for s, e, reps, keys in edits[1:]:
        ps, pe, preps, pkeys = out[-1]
        if s <= pe:  # adjacent or same position: merge
            out[-1] = [ps, max(pe, e), preps + reps,
                       (pkeys or []) + (keys or []) if pkeys is not None
                       or keys is not None else None]
        else:
            out.append([s, e, reps, keys])
    return [tuple(x) for x in out]


# ================================================================ primitives

class FString:
    TYPE = TSTRING

    def __init__(self, value: bytes = b""):
        self.value = bytes(value)

    def append(self, data: bytes) -> None:
        self.value += bytes(data)

    def insert(self, pos: int, data: bytes) -> None:
        self.value = self.value[:pos] + bytes(data) + self.value[pos:]

    def encode(self) -> bytes:
        return self.value

    @classmethod
    def decode(cls, data: bytes) -> "FString":
        return cls(data)


class FTuple:
    TYPE = TTUPLE

    def __init__(self, fields: list[bytes] | None = None):
        self.fields = [bytes(f) for f in (fields or [])]

    def append(self, f: bytes) -> None:
        self.fields.append(bytes(f))

    def insert(self, i: int, f: bytes) -> None:
        self.fields.insert(i, bytes(f))

    def get(self, i: int) -> bytes:
        return self.fields[i]

    def set(self, i: int, f: bytes) -> None:
        self.fields[i] = bytes(f)

    def encode(self) -> bytes:
        return b"".join(ck.pack_lv(f) for f in self.fields)

    @classmethod
    def decode(cls, data: bytes) -> "FTuple":
        return cls(ck.unpack_lv_stream(data))


class FInt:
    TYPE = TINT

    def __init__(self, value: int = 0):
        self.value = int(value)

    def add(self, x: int) -> None:
        self.value += x

    def multiply(self, x: int) -> None:
        self.value *= x

    def encode(self) -> bytes:
        return _I64.pack(self.value)

    @classmethod
    def decode(cls, data: bytes) -> "FInt":
        return cls(_I64.unpack(data)[0])


PRIMITIVE_CLASSES = {TSTRING: FString, TTUPLE: FTuple, TINT: FInt}
CHUNKABLE_CLASSES = {ck.BLOB: FBlob, ck.LIST: FList, ck.MAP: FMap,
                     ck.SET: FSet}
