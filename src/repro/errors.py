"""Unified exception hierarchy — every runtime invariant the engine can
violate raises a :class:`ReproError` subclass defined HERE.

One module, zero imports, so every layer (storage backends, the obs
layer, the analysis engine itself) can depend on it without cycles.
Each class keeps its historical builtin base (``KeyError``,
``ValueError``, ``RuntimeError``, ``AssertionError``) so call sites
that caught builtins keep working; the original defining modules
(``storage.backend``, ``core.branch``, ``proof.membership``,
``core.runtime``, ``core.cluster``, ``core.merge``) re-export their
classes from here for compatibility.

This hierarchy is the target of the CONTRACT001 static-analysis rule
(``repro.analysis``): bare ``raise Exception``/``RuntimeError`` and
``assert`` statements for runtime invariants in engine code are flagged
— an invariant that can fire in production must be typed so callers can
catch it, and must survive ``python -O``.
"""
from __future__ import annotations

__all__ = [
    "ReproError",
    "ConfigError",
    "InvariantViolation",
    "ChunkMissing",
    "TamperedChunk",
    "RoutingIndexMiss",
    "BranchExists",
    "NoSuchRef",
    "GuardFailed",
    "MergeConflict",
    "InvalidProof",
    "Backpressure",
    "CollectionInFlight",
    "CheckpointMissing",
    "TensorMissing",
]


class ReproError(Exception):
    """Base of every typed error the engine raises for a runtime
    invariant.  ``except ReproError`` catches anything ForkBase-shaped
    while letting genuine programming errors (TypeError, ...) escape."""


class ConfigError(ReproError, ValueError):
    """Invalid construction-time configuration (bad mode string, empty
    replica/shard list, nonsensical knob)."""


class InvariantViolation(ReproError, AssertionError):
    """An internal structural invariant does not hold (wrong chunk kind
    on a navigation path, inconsistent piece bounds).  Subclasses
    ``AssertionError`` because these sites were historically ``assert``
    statements — but unlike asserts they survive ``python -O``."""


class ChunkMissing(ReproError, KeyError):
    """A requested cid is not present in the backend (or any replica)."""

    def __init__(self, cid: bytes):
        super().__init__(cid)
        self.cid = cid

    def __str__(self) -> str:
        return f"chunk not found: {self.cid.hex()[:16]}"


class TamperedChunk(ReproError, ValueError):
    """Chunk bytes do not hash to their cid: on-disk or in-flight
    corruption / tampering (the content-addressing invariant is broken)."""

    def __init__(self, cid: bytes, where: str = ""):
        super().__init__(cid)
        self.cid = cid
        self.where = where

    def __str__(self) -> str:
        at = f" during {self.where}" if self.where else ""
        return f"tampered chunk{at}: {self.cid.hex()[:16]}"


class RoutingIndexMiss(ChunkMissing):
    """A read consulted the master chunk-location index and the cid has
    no entry: the chunk was never placed, or a sweep dropped it.  Typed
    (instead of a silent fallback to the hash owner, which holds no copy
    and used to fail from the WRONG node) so callers can distinguish a
    routing-layer miss from a node losing its chunk."""

    def __str__(self) -> str:
        return f"no master-index entry for chunk: {self.cid.hex()[:16]}"


class BranchExists(ReproError, ValueError):
    """Fork/rename target branch name is already taken for this key."""

    def __init__(self, branch: str):
        super().__init__(branch)
        self.branch = branch

    def __str__(self) -> str:
        return f"branch exists: {self.branch}"


class NoSuchRef(ReproError, KeyError):
    """A named branch or version uid does not resolve."""

    def __init__(self, ref):
        super().__init__(ref)
        self.ref = ref

    def __str__(self) -> str:
        return f"no such ref: {self.ref!r}"


class GuardFailed(ReproError):
    """Guarded Put failed: current head != guard_uid (paper §4.5.1)."""


class MergeConflict(ReproError):
    """Three-way merge found concurrent edits it cannot reconcile."""

    def __init__(self, conflicts):
        self.conflicts = conflicts
        super().__init__(f"{len(conflicts)} merge conflict(s)")


class InvalidProof(ReproError, ValueError):
    """The proof does not authenticate its claim against the trusted
    anchor (hash chain broken, navigation inconsistent, claim absent,
    or the bytes fail to parse)."""


class Backpressure(ReproError, RuntimeError):
    """A servlet's admission queue is full (or admission has tightened
    under observed store latency): the client must retry later."""

    def __init__(self, servlet: int, depth: int, bound: int):
        super().__init__(
            f"servlet {servlet} queue full ({depth}/{bound})")
        self.servlet = servlet
        self.depth = depth
        self.bound = bound


class CollectionInFlight(ReproError, RuntimeError):
    """``begin()`` was called while a collection epoch is still active
    (collections over one store are serialized)."""

    def __init__(self, epoch: int, phase):
        super().__init__(
            f"collection already in flight (epoch {epoch}, "
            f"phase {phase})")
        self.epoch = epoch
        self.phase = phase


class CheckpointMissing(NoSuchRef):
    """Checkpoint restore found no committed checkpoint at the ref."""


class TensorMissing(ReproError, KeyError):
    """A checkpoint manifest lacks a tensor the restore template needs
    (writer/reader model shape mismatch)."""

    def __init__(self, name: str):
        super().__init__(name)
        self.name = name

    def __str__(self) -> str:
        return f"missing tensor in checkpoint manifest: {self.name}"
