"""Chunk garbage collection & space reclamation.

  GarbageCollector  reachability mark-and-sweep over the version DAG
  GCReport          what one collection did (roots/live/swept/bytes)
  PinSet            explicit roots: in-flight readers, retention holds

Entry points: ``ForkBase.gc()`` (embedded engine), ``Cluster.gc()``
(global root set at the dispatcher, per-node sweep),
``CheckpointStore.prune`` (retention policy that drives collection),
``MemoryBackend.compact_log`` (on-disk reclamation).
"""
from .collector import GarbageCollector, GCReport, chunk_refs, mark
from .pins import PinSet

__all__ = ["GarbageCollector", "GCReport", "PinSet", "chunk_refs", "mark"]
