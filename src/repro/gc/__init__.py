"""Chunk garbage collection & space reclamation.

  GarbageCollector      stop-the-world mark-and-sweep over the version DAG
  IncrementalCollector  tri-color mark/sweep in budget-bounded slices,
                        safe beside live traffic (write barriers +
                        epoch root-set snapshot)
  GCPhase               the incremental state machine's phase enum
  GCReport              what one collection did (roots/live/swept/bytes)
  PinSet                explicit roots: in-flight readers, retention holds
  EpochFence            attestation/collection epoch handshake: heads
                        committed by a recent attest() stay provable
                        through the next collection

Entry points: ``ForkBase.gc()`` / ``ForkBase.incremental_gc()``
(embedded engine), ``Cluster.gc()`` / ``Cluster.incremental_gc()``
(global root set at the dispatcher, per-node sweep),
``CheckpointStore.prune`` (retention policy that drives collection),
``MemoryBackend.compact_log`` (on-disk reclamation).
"""
from .collector import (GarbageCollector, GCReport, chunk_refs,
                        expand_refs, filter_roots, mark)
from .incremental import EpochFence, GCPhase, IncrementalCollector
from .pins import PinSet

__all__ = ["EpochFence", "GarbageCollector", "GCPhase", "GCReport",
           "IncrementalCollector", "PinSet", "chunk_refs", "expand_refs",
           "filter_roots", "mark"]
