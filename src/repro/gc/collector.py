"""Mark-and-sweep garbage collection over the version DAG.

ForkBase dedups on write (§4.4) but, like any content-addressed engine,
needs reachability-based collection to ever *shrink* (UStore makes the
same observation): dropping a branch head only detaches a subgraph —
the chunks it pinned stay in the store until something walks the DAG
and sweeps what no surviving head reaches.

Phases, all batched through the StorageBackend protocol:

  roots  TB + UB heads of every key (BranchTable.all_heads) plus the
         PinSet (in-flight readers, checkpoint retention holds).
  mark   BFS over chunk references, frontier-by-frontier: ONE
         ``get_many`` per DAG level (the read-side twin of the batched
         write pipeline, §4.6.1).  A meta chunk contributes its
         ``bases`` uids (history stays tamper-evident: everything a
         live head derives from is live) and, for chunkable types, its
         POS-Tree root cid; index chunks contribute their child cids;
         leaf chunks are terminal.
  sweep  inventory (``iter_cids``) minus live set, removed with one
         ``delete_many`` — each backend reclaims coherently (log
         tombstones, cache invalidation, all-replica delete, shard /
         cluster fan-out).
"""
from __future__ import annotations

from dataclasses import dataclass

from .pins import PinSet


@dataclass
class GCReport:
    roots: int = 0                # root uids the mark started from
    live_chunks: int = 0          # chunks reachable from the roots
    swept_chunks: int = 0         # chunks removed
    reclaimed_bytes: int = 0      # physical bytes freed by the sweep
    mark_rounds: int = 0          # store round-trips (= DAG depth levels)
    missing_roots: int = 0        # dangling tags/pins skipped by the mark
    epoch: int = 0                # incremental collection epoch (0 = STW)
    slices: int = 0               # step() calls an incremental run took
    barriered: int = 0            # chunks shaded/rescued by write barriers
    floating_garbage: int = 0     # swept chunks the PREVIOUS epoch kept
    #   alive only because they were orphaned mid-collection (snapshot-
    #   at-the-beginning trade); incremental epochs only — an STW
    #   collection has no preceding live-set handoff to count against
    compacted_bytes: int = 0      # segment-file bytes reclaimed by the
    #   compaction this sweep's flush fed (durable backends only)

    def __str__(self) -> str:
        dangling = (f", {self.missing_roots} dangling roots"
                    if self.missing_roots else "")
        floating = (f", {self.floating_garbage} floating"
                    if self.floating_garbage else "")
        compacted = (f", {self.compacted_bytes / 1e6:.2f} MB compacted"
                     if self.compacted_bytes else "")
        inc = (f" [epoch {self.epoch}: {self.slices} slices, "
               f"{self.barriered} barriered{floating}]"
               if self.epoch else "")
        return (f"GC: {self.roots} roots, {self.live_chunks} live, "
                f"{self.swept_chunks} swept "
                f"({self.reclaimed_bytes / 1e6:.2f} MB{compacted}) "
                f"in {self.mark_rounds} mark rounds{dangling}{inc}")


def chunk_refs(raw: bytes) -> list[bytes]:
    """Outgoing cid references of one serialized chunk (the edge
    function of the mark BFS)."""
    from ..core import chunk as ck
    from ..core.fobject import CHUNKABLE_TYPES, FObject

    t = ck.chunk_type(raw)
    if t == ck.META:
        obj = FObject.deserialize(raw, b"")
        refs = list(obj.bases)
        if obj.type in CHUNKABLE_TYPES:
            refs.append(obj.data)        # POS-Tree root cid
        return refs
    if t == ck.UINDEX:
        return [e.cid for e in ck.decode_uindex(ck.chunk_payload(raw))]
    if t == ck.SINDEX:
        return [e.cid for e in ck.decode_sindex(ck.chunk_payload(raw))]
    return []                            # leaf chunk: terminal


def expand_refs(store, cids, ref_hooks, live) -> list[bytes]:
    """One mark slice: read ``cids`` (one batched ``get_many``) and
    return their not-yet-seen references, adding them to ``live``.

    This is the shared inner loop of both collectors: ``mark`` feeds it
    whole BFS frontiers, the incremental collector feeds it
    budget-bounded slices of the gray queue.  Structural refs
    (``chunk_refs``) are strict — a missing one is corruption and raises
    ChunkMissing on the next slice; ``ref_hooks`` refs are soft and
    validated with one batched ``has_many``, so a value that merely
    looks like a cid cannot abort the mark."""
    nxt: list[bytes] = []
    soft: list[bytes] = []
    for raw in store.get_many(cids):
        for ref in chunk_refs(raw):
            if ref not in live:
                live.add(ref)
                nxt.append(ref)
        for hook in ref_hooks:
            for ref in hook(raw):
                if ref not in live:
                    soft.append(ref)
    if soft:
        soft = sorted(set(soft) - live)
        for ref, present in zip(soft, store.has_many(soft)):
            if present:
                live.add(ref)
                nxt.append(ref)
    return nxt


def filter_roots(store, roots) -> tuple[list[bytes], int]:
    """Drop dangling roots with one batched ``has_many``: roots come
    from user-controllable surfaces (tags, pins), so a stale one must
    not brick collection forever — it is reported, not raised.  Returns
    (present roots, missing count)."""
    want = sorted({bytes(u) for u in roots})
    frontier = [u for u, p in zip(want, store.has_many(want)) if p]
    return frontier, len(want) - len(frontier)


def mark(store, roots, ref_hooks=()) -> tuple[set[bytes], int, int]:
    """Batched reachability: returns (live cid set, store round-trips,
    count of missing roots)."""
    frontier, missing = filter_roots(store, roots)
    live: set[bytes] = set(frontier)
    rounds = 0
    while frontier:
        rounds += 1
        frontier = expand_refs(store, frontier, ref_hooks, live)
    return live, rounds, missing


class GarbageCollector:
    """Collector over one store.  Roots come from a BranchTable and/or a
    PinSet and/or explicit extra uids (the cluster dispatcher passes the
    union over all servlets as ``extra_roots``)."""

    def __init__(self, store, branches=None, pins: PinSet | None = None,
                 extra_roots=(), ref_hooks=()):
        self.store = store
        self.branches = branches
        self.pins = pins
        self.extra_roots = set(bytes(u) for u in extra_roots)
        self.ref_hooks = tuple(ref_hooks)

    def root_set(self) -> set[bytes]:
        roots = set(self.extra_roots)
        if self.branches is not None:
            roots |= self.branches.all_heads()
        if self.pins is not None:
            roots |= self.pins.uids()
        return roots

    def mark(self, roots=None) -> tuple[set[bytes], int, int]:
        return mark(self.store, self.root_set() if roots is None else roots,
                    self.ref_hooks)

    def sweep(self, live: set[bytes]) -> tuple[int, int]:
        """Delete everything stored but not live; returns
        (swept chunk count, reclaimed bytes).  Flushes afterwards so log
        tombstones are durable — a crash after the sweep must not replay
        swept chunks back to life."""
        dead = sorted(c for c in self.store.iter_cids() if c not in live)
        r0 = self.store.stats.reclaimed_bytes
        n = self.store.delete_many(dead) if dead else 0
        if n:
            self.store.flush()
        return n, self.store.stats.reclaimed_bytes - r0

    def collect(self) -> GCReport:
        roots = self.root_set()
        live, rounds, missing = self.mark(roots)
        # the sweep's flush feeds the durable-store compactor; report
        # the segment bytes it dropped alongside the logical reclaim
        c0 = self.store.stats.compacted_bytes
        swept, reclaimed = self.sweep(live)
        return GCReport(roots=len(roots), live_chunks=len(live),
                        swept_chunks=swept, reclaimed_bytes=reclaimed,
                        mark_rounds=rounds, missing_roots=missing,
                        compacted_bytes=(self.store.stats.compacted_bytes
                                         - c0))
