"""Incremental concurrent GC — tri-color mark-and-sweep as a resumable
state machine, safe beside live traffic (ROADMAP "concurrent /
incremental GC"; ForkBase §4 makes this tractable because chunks are
immutable and content-addressed: only the root set races).

Phases of one collection epoch:

  begin   epoch-numbered root-set SNAPSHOT: the branch tables (and pin
          sets) are copied once; committers keep moving afterwards.
          Write barriers are installed on every store the mutators
          write through.
  MARK    tri-color: the snapshot roots start gray; ``step(budget)``
          pops at most ``budget`` gray cids, reads them with ONE
          ``get_many`` and grays their unseen references (shared inner
          loop ``collector.expand_refs``).  Black = shaded and
          processed; white = never shaded.  When the gray queue drains,
          the condemned set is frozen in budget-bounded inventory
          slices (still MARK; see ``_freeze_slice``).
  SWEEP   the condemned set is frozen as inventory minus shaded;
          ``step(budget)`` deletes at most ``budget`` condemned cids
          per call (``delete_many`` slices — per owning node in the
          cluster).  The final slice flushes so log tombstones are
          durable.

Write barrier (the safety argument):

  * MARK: every put batch — dedup acks included — is shaded gray.  A
    new version's meta/tree chunks are therefore traversed, which also
    re-marks any *existing* white chunk the new value adopted by dedup
    or by structural reference; anything reachable from a post-snapshot
    head is reachable from shaded chunks or from snapshot roots.
    While the sliced inventory freeze is in progress, shading also
    pulls the cid back out of the partially built condemned set.
  * SWEEP: marking is over, so a put batch is *rescued* instead — its
    cids leave the condemned set before their slice is deleted.  A cid
    already swept is simply re-stored by the put (content addressing
    makes re-put identity-safe).  Chunks first stored during the sweep
    are not in the frozen inventory and cannot be condemned at all.
  * Root barrier (``fork`` from an explicit uid, new pins): during MARK
    the uid is shaded; during SWEEP it is rescued *transitively* through
    the condemned set, because re-rooting a detached subgraph must
    resurrect all of it, not just the head chunk.

Chunks condemned by the snapshot but re-abandoned mid-collection are
floating garbage: they survive this epoch and fall in the next — the
standard snapshot-at-the-beginning trade, never unsafe.

Epoch handshake with ``attest()`` (ROADMAP "incremental attestations
under concurrent GC"): an attestation commits to the branch heads of
the instant it was issued, but the table keeps moving — a head can be
retired right after signing and swept by the *next* collection, at
which point ``prove_member`` against the freshly signed attestation
dangles.  ``EpochFence`` closes the race: every attestation pins its
committed heads at the current collection epoch, collections root all
pins still inside a one-epoch grace window, and an attestation issued
while a collection is in flight additionally rescues its heads out of
the live condemned set (``attest_fence``).  The contract: proofs
against an attestation stay servable until the SECOND collection after
its issue begins — verifiers refresh at least once per GC epoch (the
attested epoch is stamped into the context, see proof.delta).
"""
from __future__ import annotations

from collections import deque
from enum import Enum

from .. import obs
from ..core.locking import make_lock
from ..errors import CollectionInFlight
from .collector import GCReport, chunk_refs, expand_refs, filter_roots
from .pins import PinSet


_BLOOM_BITS = 1 << 20        # 128 KiB bitset per overflowing epoch


def _bloom_slots(uid: bytes) -> tuple[int, int, int, int]:
    """Four bit positions for one uid.  uids are cryptographic hashes,
    so four distinct 4-byte slices are independent uniform indices — no
    extra hashing needed."""
    u = uid if len(uid) >= 16 else (uid + bytes(16 - len(uid)))
    return (int.from_bytes(u[0:4], "little") % _BLOOM_BITS,
            int.from_bytes(u[4:8], "little") % _BLOOM_BITS,
            int.from_bytes(u[8:12], "little") % _BLOOM_BITS,
            int.from_bytes(u[12:16], "little") % _BLOOM_BITS)


def _bloom_has(bloom: bytearray, uid: bytes) -> bool:
    return all(bloom[s >> 3] & (1 << (s & 7)) for s in _bloom_slots(uid))


class EpochFence:
    """Persistent attestation/collection epoch registry for one engine
    (or one cluster — collections there are cluster-wide).  Survives
    across collector instances so epoch numbers are monotone and pins
    outlive the collection they were issued under.

    Pin memory is bounded: each epoch keeps at most ``max_pins`` exact
    uids; overflow spills into a per-epoch Bloom bitset (128 KiB) that
    ``grace_roots`` intersects with the CURRENT heads (``heads_fn``).
    The trade, stated plainly: a spilled pin protects its uid only
    while the uid is still a live head when the collection starts — a
    head both retired *and* spilled past the cap loses its grace-window
    extension (its proofs may dangle one epoch early).  Bloom false
    positives merely widen the root set, which is always safe.  With
    the default cap (1M pins/epoch) the spill path never engages in
    practice; ``max_pins=None`` disables the bound entirely.

    The fence also carries the floating-garbage handoff between
    consecutive incremental collections: ``last_live`` is the previous
    epoch's shaded (live) set, against which the next epoch's sweep
    counts ``GCReport.floating_garbage`` — chunks that survived one
    collection only because they were orphaned mid-epoch."""

    def __init__(self, grace: int = 1, max_pins: int | None = 1 << 20):
        self.epoch = 0                 # collection epochs begun so far
        self.grace = grace             # epochs a pin outlives its issue
        self.max_pins = max_pins       # exact uids kept per epoch
        self.heads_fn = None           # current-head enumerator (spill path)
        # attests pin from mutator threads while the maintenance daemon
        # begins epochs — rank "fence", a true leaf (never held across
        # heads_fn, which may take servlet locks); core.locking.LOCK_ORDER
        self._fence_lock = make_lock("fence")
        self._pins: dict[int, set[bytes]] = {}
        self._blooms: dict[int, bytearray] = {}
        self._spilled: dict[int, int] = {}
        self.last_live: frozenset = frozenset()   # floating-garbage handoff

    def pin(self, uids) -> int:
        """Record the heads an attestation just committed to; returns
        the epoch number stamped into the attestation."""
        with self._fence_lock:
            e = self.epoch
            if uids:
                cur = self._pins.setdefault(e, set())
                for u in uids:
                    u = bytes(u)
                    if u in cur:
                        continue
                    if self.max_pins is None or len(cur) < self.max_pins:
                        cur.add(u)
                    else:                   # spill: bounded-memory path
                        bloom = self._blooms.get(e)
                        if bloom is None:
                            bloom = self._blooms[e] = bytearray(
                                _BLOOM_BITS // 8)
                        for s in _bloom_slots(u):
                            bloom[s >> 3] |= 1 << (s & 7)
                        self._spilled[e] = self._spilled.get(e, 0) + 1
        if uids:
            obs.inc("gc_fence_pins_total", len(uids))
        return e

    def pin_count(self, epoch: int | None = None) -> int:
        """Pins recorded for one epoch (exact + spilled) — the attest
        path's O(k) claim is asserted against this."""
        e = self.epoch if epoch is None else epoch
        return len(self._pins.get(e, ())) + self._spilled.get(e, 0)

    def begin_epoch(self) -> int:
        """A collection is starting: advance the epoch and expire pins
        that fell out of the grace window."""
        with self._fence_lock:
            self.epoch += 1
            epoch = self.epoch
            for e in [e for e in self._pins if e < epoch - self.grace]:
                del self._pins[e]
            for e in [e for e in self._blooms if e < epoch - self.grace]:
                del self._blooms[e]
                self._spilled.pop(e, None)
        obs.inc("gc_epochs_total")
        obs.set_gauge("gc_epoch", epoch)
        return epoch

    def grace_roots(self) -> set[bytes]:
        """Heads the starting collection must treat as roots: every pin
        still inside the grace window.  Spilled pins are recovered by
        filtering the current heads through the epoch blooms."""
        with self._fence_lock:     # snapshot only — heads_fn runs unlocked
            out: set[bytes] = set()
            for uids in self._pins.values():
                out |= uids
            blooms = [bytes(b) for b in self._blooms.values()]
        if blooms:
            heads = (set(self.heads_fn()) if self.heads_fn is not None
                     else set())
            for bloom in blooms:
                out.update(bytes(h) for h in heads
                           if _bloom_has(bloom, bytes(h)))
        return out


class GCPhase(Enum):
    IDLE = "idle"      # no collection in flight
    MARK = "mark"      # draining the gray queue in budget slices
    SWEEP = "sweep"    # deleting the condemned set in budget slices
    DONE = "done"      # report final; begin() starts the next epoch

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return self.value


class IncrementalCollector:
    """Resumable collector over one store.  ``begin()`` snapshots the
    roots and installs write barriers; ``step(budget)`` advances the
    mark or sweep by at most ``budget`` chunks and returns the phase;
    ``collect(budget)`` drives a whole epoch to DONE.

    The cluster dispatcher parameterizes the fan-out points:
    ``barrier_stores`` (every store committers write through),
    ``inventory_fn`` (the sweep inventory snapshot) and ``sweep_fn``
    (slice deletion, per owning node) — the state machine itself is
    shared between the embedded engine and the cluster.
    """

    def __init__(self, store, branches=None, pins: PinSet | None = None,
                 extra_roots=(), ref_hooks=(), *, barrier_stores=None,
                 inventory_fn=None, sweep_fn=None, flush_fn=None,
                 on_done=None, fence: EpochFence | None = None):
        self.store = store
        self.branches = branches
        self.pins = pins
        self.extra_roots = set(bytes(u) for u in extra_roots)
        self.ref_hooks = tuple(ref_hooks)
        self._barrier_stores = (list(barrier_stores)
                                if barrier_stores is not None else [store])
        self._inventory_fn = (inventory_fn if inventory_fn is not None
                              else lambda: self.store.iter_cids())
        self._sweep_fn = (sweep_fn if sweep_fn is not None
                          else self._sweep_slice)
        self._flush_fn = (flush_fn if flush_fn is not None
                          else self.store.flush)
        self._on_done = on_done
        self.fence = fence
        # true-thread safety for the barrier/gray-queue state: mutator
        # threads fire _put_barrier/root_barrier while the maintenance
        # daemon drives step() — one RLock serializes them.  Rank
        # "collector": inside servlet locks, outside index/store locks
        # (canonical order in core.locking.LOCK_ORDER; begin() therefore
        # gathers roots BEFORE taking this lock).
        self._collector_lock = make_lock("collector")
        self.phase = GCPhase.IDLE
        self.epoch = 0
        self.report: GCReport | None = None
        self._shaded: set[bytes] = set()        # gray or black (tri-color)
        self._gray: deque[bytes] = deque()
        self._inv_iter = None                   # sliced inventory freeze
        self._condemned: deque[bytes] = deque()
        self._condemned_set: set[bytes] = set()
        self._floating_from: frozenset = frozenset()  # prev epoch's live set
        self._pending_finish = False  # DONE reached; _finish_io still due

    # ------------------------------------------------------------ state
    @property
    def active(self) -> bool:
        return self.phase in (GCPhase.MARK, GCPhase.SWEEP)

    @property
    def marked(self) -> frozenset:
        """The shaded (gray + black) cid set — live this epoch.  Freed
        at DONE (``report.live_chunks`` keeps the count); empty between
        epochs."""
        return frozenset(self._shaded)

    # ------------------------------------------------------------ begin
    def begin(self, extra_roots=()) -> int:
        """Snapshot the root set, install the write barriers and enter
        MARK.  Returns the new epoch number.  The snapshot is a copy:
        branch tables may change freely afterwards (removed heads stay
        live this epoch — floating garbage, collected next epoch)."""
        if self.active:
            raise CollectionInFlight(self.epoch, self.phase)
        # root gathering runs UNLOCKED: all_heads/grace_roots may take
        # servlet locks, which mutators hold while waiting on the
        # collector lock in _put_barrier — holding it here would deadlock
        roots = set(self.extra_roots) | set(bytes(u) for u in extra_roots)
        if self.branches is not None:
            roots |= self.branches.all_heads()      # branch-table copy
        if self.pins is not None:
            roots |= self.pins.uids()
        if self.fence is not None:
            # epoch handshake: heads committed by attestations still in
            # their grace window survive this collection
            self.epoch = self.fence.begin_epoch()
            roots |= self.fence.grace_roots()
        else:
            self.epoch += 1
        frontier, missing = filter_roots(self.store, roots)
        with self._collector_lock:
            if self.active:
                raise CollectionInFlight(self.epoch, self.phase)
            # floating-garbage bound: chunks this epoch sweeps that the
            # PREVIOUS epoch marked live were orphaned mid-collection and
            # survived exactly one extra epoch — the snapshot-at-the-
            # beginning trade, now measured (GCReport.floating_garbage)
            self._floating_from = (self.fence.last_live
                                   if self.fence is not None
                                   else frozenset())
            self.report = GCReport(roots=len(roots), missing_roots=missing,
                                   epoch=self.epoch)
            self._shaded = set(frontier)
            self._gray = deque(frontier)
            self._inv_iter = None
            self._condemned = deque()
            self._condemned_set = set()
            for s in self._barrier_stores:
                s.add_put_listener(self._put_barrier)
                # park the collector lock on the store: one put batch
                # (write + barrier) becomes atomic against step() slices
                s._barrier_lock = self._collector_lock
            self.phase = GCPhase.MARK
        obs.emit("gc.begin", epoch=self.epoch, roots=len(roots),
                 missing_roots=missing)
        return self.epoch

    # ---------------------------------------------------------- barrier
    def _put_barrier(self, cids) -> None:
        """Store-level write barrier: fires on every put batch (ForkBase
        put/merge/truncate_history, WriteBuffer flush) of every store
        this collection watches."""
        with self._collector_lock:
            if self.phase is GCPhase.MARK:
                for c in cids:
                    if c not in self._shaded:
                        self._shaded.add(c)
                        self._gray.append(c)
                        self.report.barriered += 1
                    # the sliced inventory freeze may already have
                    # condemned this cid (it was white when its slice was
                    # snapshotted): shading it must also pull it back out
                    if self._condemned_set:
                        self._condemned_set.discard(c)
            elif self.phase is GCPhase.SWEEP:
                for c in cids:
                    if c in self._condemned_set:
                        self._condemned_set.discard(c)
                        self.report.barriered += 1

    def root_barrier(self, uid: bytes) -> None:
        """Re-rooting barrier: a mutator just made ``uid`` a root (fork
        from an explicit uid, a new pin).  During MARK shading it is
        enough — the mark traverses from it; during SWEEP the rescue is
        transitive through the condemned set, because marking is over
        and a re-rooted detached subgraph must ALL survive."""
        if not self.active:
            return
        uid = bytes(uid)
        with self._collector_lock:   # phase must not flip between check and rescue
            if self.phase is not GCPhase.SWEEP:
                self._put_barrier([uid] if self.store.has(uid) else [])
                return
            self._root_rescue(uid)

    def _root_rescue(self, uid: bytes) -> None:
        if uid not in self._condemned_set:
            return                   # black, already rescued, or swept
        frontier = [uid]
        while frontier:
            for c in frontier:
                self._condemned_set.discard(c)
            present = [c for c, p in zip(frontier,
                                         self.store.has_many(frontier))
                       if p]
            # only cids actually in the store were going to be deleted —
            # a frontier cid the store no longer holds (lost replica,
            # stale cluster index entry) was never rescued from anything
            # and must not inflate the barrier count
            self.report.barriered += len(present)
            nxt: list[bytes] = []
            for raw in self.store.get_many(present):
                refs = list(chunk_refs(raw))
                for hook in self.ref_hooks:   # app-level links too (a
                    refs.extend(hook(raw))    # ckpt manifest's tensor
                nxt.extend(r for r in refs    # roots live through hooks)
                           if r in self._condemned_set)
            frontier = sorted(set(nxt))

    def attest_fence(self, uids) -> None:
        """Epoch handshake with ``attest()``: the heads an attestation
        just committed to must survive this collection — shade (MARK)
        or transitively rescue (SWEEP) each one, exactly like a
        re-rooting event.  Between collections this is a no-op; the
        cross-epoch half of the handshake is the EpochFence pin set
        consumed by the next ``begin()``."""
        for u in uids:
            self.root_barrier(u)

    # ------------------------------------------------------------- step
    def step(self, budget: int = 256) -> GCPhase:
        """Advance the collection by at most ``budget`` chunks (marked
        OR swept OR inventory-frozen — one bounded pause) and return
        the phase.  Each active slice's wall-clock pause is recorded in
        the observability registry (``gc_slice_us`` histogram plus the
        bounded per-slice pause history ``obs.snapshot()['gc']``), and
        phase transitions land in the event journal."""
        if not obs.REGISTRY.enabled or not self.active:
            return self._step_inner(budget)
        before = self.phase
        t0 = obs.monotonic()
        phase = self._step_inner(budget)
        obs.record_gc_pause(str(before), obs.monotonic() - t0,
                            epoch=self.epoch)
        if phase is not before:
            obs.emit("gc.phase", epoch=self.epoch,
                     phase_from=str(before), phase_to=str(phase))
        return phase

    def _step_inner(self, budget: int = 256) -> GCPhase:
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        with self._collector_lock:
            phase = self._step_locked(budget)
            finishing = self._pending_finish
            self._pending_finish = False
        if finishing:
            # the finish flush (fsync + segment compaction) runs OUTSIDE
            # the collector lock: a durable flush can take milliseconds
            # and every mutator's write barrier would stall behind it
            # (LOCK002).  Safe unlocked: phase is DONE, the barriers are
            # unregistered, and only one thread drives step().
            self._finish_io()
        return phase

    def _step_locked(self, budget: int) -> GCPhase:
        if not self.active:
            return self.phase
        self.report.slices += 1
        if self.phase is GCPhase.MARK:
            spent = 0
            if self._gray:
                self.report.mark_rounds += 1
                batch = [self._gray.popleft()
                         for _ in range(min(budget, len(self._gray)))]
                spent = len(batch)
                fresh = expand_refs(self.store, batch, self.ref_hooks,
                                    self._shaded)
                self._gray.extend(fresh)
                if self._condemned_set:
                    # marking resumed mid-freeze (a barrier re-grayed a
                    # put): refs shaded now may sit in the partially
                    # frozen condemned set — pull them back out
                    for c in fresh:
                        self._condemned_set.discard(c)
                if self._gray or spent >= budget:
                    return self.phase
                # gray drained with budget to spare: spend the rest on
                # the inventory freeze NOW.  A mutator putting between
                # every slice re-grays a few chunks each time; if the
                # freeze only ran on steps that BEGAN with an empty gray
                # queue, such a mutator would livelock MARK forever —
                # the collection must make monotone progress per slice.
            self._freeze_slice(budget - spent)
            return self.phase
        # SWEEP: delete up to ``budget`` still-condemned cids
        batch: list[bytes] = []
        while self._condemned and len(batch) < budget:
            c = self._condemned.popleft()
            if c in self._condemned_set:          # not rescued meanwhile
                self._condemned_set.discard(c)
                batch.append(c)
        if batch:
            n, freed = self._sweep_fn(sorted(batch))
            self.report.swept_chunks += n
            self.report.reclaimed_bytes += freed
            if self._floating_from:
                self.report.floating_garbage += sum(
                    1 for c in batch if c in self._floating_from)
        if not self._condemned:
            self._finish()
        return self.phase

    def collect(self, budget: int = 256) -> GCReport:
        """Drive one whole epoch: begin (if idle) and step to DONE."""
        if not self.active:
            self.begin()
        while self.step(budget) is not GCPhase.DONE:
            pass
        return self.report

    # ---------------------------------------------------------- internal
    def _freeze_slice(self, budget: int) -> None:
        """Sliced inventory freeze (ROADMAP): the MARK->SWEEP transition
        used to filter the whole ``iter_cids()`` inventory against the
        shaded set in one O(store) pause; now each step() consumes at
        most ``budget`` inventory cids, building the condemned set
        across as many bounded slices as the store is large.

        Safety while the freeze is in progress: the phase stays MARK, so
        the write barrier keeps shading new puts gray (and pulls any
        already-condemned cid back out of the condemned set), and a
        non-empty gray queue is drained by mark slices before the next
        freeze slice — a cid enters SWEEP condemned only if it was
        still white after every barrier event that touched it."""
        if self._inv_iter is None:
            # backends snapshot iter_cids() as a cid list (a pointer
            # copy, no chunk payloads); the O(n) membership filtering
            # below is what gets sliced.  A generation list would shed
            # the copy too — noted in the ROADMAP as the production shape.
            self._inv_iter = iter(self._inventory_fn())
        taken = 0
        for cid in self._inv_iter:
            if cid not in self._shaded and cid not in self._condemned_set:
                self._condemned.append(cid)
                self._condemned_set.add(cid)
            taken += 1
            if taken >= budget:
                return
        # iterator exhausted: the condemned set is frozen — enter SWEEP.
        # The deque keeps inventory order (each sweep slice sorts its
        # own batch); a global sort here would be an O(dead) pause.
        self._inv_iter = None
        self.report.live_chunks = len(self._shaded)
        self.phase = GCPhase.SWEEP
        if not self._condemned_set:
            self._finish()

    def _sweep_slice(self, cids) -> tuple[int, int]:
        r0 = self.store.stats.reclaimed_bytes
        n = self.store.delete_many(cids)
        return n, self.store.stats.reclaimed_bytes - r0

    def _compacted_total(self) -> int:
        """Segment-compaction bytes across every store the finish flush
        touches (per-node on a cluster, else the engine's store)."""
        cluster = getattr(self.store, "cluster", None)
        if cluster is not None:
            return sum(n.store.stats.compacted_bytes
                       for n in cluster.nodes)
        return self.store.stats.compacted_bytes

    def _finish(self) -> None:
        """In-memory epilogue, caller holds the collector lock.  The
        blocking half (store flush/compaction, completion callbacks)
        is deferred to ``_finish_io`` which ``_step_inner`` runs after
        releasing the lock."""
        for s in self._barrier_stores:
            s.remove_put_listener(self._put_barrier)
            s._barrier_lock = None
        if self.fence is not None:
            # floating-garbage handoff: the next epoch counts its sweep
            # against this epoch's live set (one O(live) cid set held on
            # the persistent fence between collections)
            self.fence.last_live = frozenset(self._shaded)
        self._gray.clear()
        self._inv_iter = None
        self._condemned.clear()
        self._condemned_set = set()
        self._shaded = set()         # O(live) memory is the epoch's, not ours
        self.phase = GCPhase.DONE
        self._pending_finish = True

    def _finish_io(self) -> None:
        """Blocking finish work, run with NO locks held (fixes the
        LOCK002 finding: the old ``_finish`` fsync'd every node store —
        the segment compaction feed — while the collector lock stalled
        every write barrier in the cluster)."""
        if self.report.swept_chunks:
            c0 = self._compacted_total()
            self._flush_fn()         # durable tombstones, like collect();
            #   on a durable store this flush IS the compaction feed
            self.report.compacted_bytes += self._compacted_total() - c0
        obs.record_gc_report(self.report)
        obs.emit("gc.done", mode="incremental", epoch=self.epoch,
                 slices=self.report.slices,
                 swept=self.report.swept_chunks,
                 reclaimed_bytes=self.report.reclaimed_bytes,
                 barriered=self.report.barriered)
        if self._on_done is not None:
            self._on_done(self.report)
