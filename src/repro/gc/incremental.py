"""Incremental concurrent GC — tri-color mark-and-sweep as a resumable
state machine, safe beside live traffic (ROADMAP "concurrent /
incremental GC"; ForkBase §4 makes this tractable because chunks are
immutable and content-addressed: only the root set races).

Phases of one collection epoch:

  begin   epoch-numbered root-set SNAPSHOT: the branch tables (and pin
          sets) are copied once; committers keep moving afterwards.
          Write barriers are installed on every store the mutators
          write through.
  MARK    tri-color: the snapshot roots start gray; ``step(budget)``
          pops at most ``budget`` gray cids, reads them with ONE
          ``get_many`` and grays their unseen references (shared inner
          loop ``collector.expand_refs``).  Black = shaded and
          processed; white = never shaded.
  SWEEP   when the gray queue drains, the condemned set is frozen as
          inventory minus shaded; ``step(budget)`` deletes at most
          ``budget`` condemned cids per call (``delete_many`` slices —
          per owning node in the cluster).  The final slice flushes so
          log tombstones are durable.

Write barrier (the safety argument):

  * MARK: every put batch — dedup acks included — is shaded gray.  A
    new version's meta/tree chunks are therefore traversed, which also
    re-marks any *existing* white chunk the new value adopted by dedup
    or by structural reference; anything reachable from a post-snapshot
    head is reachable from shaded chunks or from snapshot roots.
  * SWEEP: marking is over, so a put batch is *rescued* instead — its
    cids leave the condemned set before their slice is deleted.  A cid
    already swept is simply re-stored by the put (content addressing
    makes re-put identity-safe).  Chunks first stored during the sweep
    are not in the frozen inventory and cannot be condemned at all.
  * Root barrier (``fork`` from an explicit uid, new pins): during MARK
    the uid is shaded; during SWEEP it is rescued *transitively* through
    the condemned set, because re-rooting a detached subgraph must
    resurrect all of it, not just the head chunk.

Chunks condemned by the snapshot but re-abandoned mid-collection are
floating garbage: they survive this epoch and fall in the next — the
standard snapshot-at-the-beginning trade, never unsafe.
"""
from __future__ import annotations

from collections import deque
from enum import Enum

from .collector import GCReport, chunk_refs, expand_refs, filter_roots
from .pins import PinSet


class GCPhase(Enum):
    IDLE = "idle"      # no collection in flight
    MARK = "mark"      # draining the gray queue in budget slices
    SWEEP = "sweep"    # deleting the condemned set in budget slices
    DONE = "done"      # report final; begin() starts the next epoch

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return self.value


class IncrementalCollector:
    """Resumable collector over one store.  ``begin()`` snapshots the
    roots and installs write barriers; ``step(budget)`` advances the
    mark or sweep by at most ``budget`` chunks and returns the phase;
    ``collect(budget)`` drives a whole epoch to DONE.

    The cluster dispatcher parameterizes the fan-out points:
    ``barrier_stores`` (every store committers write through),
    ``inventory_fn`` (the sweep inventory snapshot) and ``sweep_fn``
    (slice deletion, per owning node) — the state machine itself is
    shared between the embedded engine and the cluster.
    """

    def __init__(self, store, branches=None, pins: PinSet | None = None,
                 extra_roots=(), ref_hooks=(), *, barrier_stores=None,
                 inventory_fn=None, sweep_fn=None, flush_fn=None,
                 on_done=None):
        self.store = store
        self.branches = branches
        self.pins = pins
        self.extra_roots = set(bytes(u) for u in extra_roots)
        self.ref_hooks = tuple(ref_hooks)
        self._barrier_stores = (list(barrier_stores)
                                if barrier_stores is not None else [store])
        self._inventory_fn = (inventory_fn if inventory_fn is not None
                              else lambda: self.store.iter_cids())
        self._sweep_fn = (sweep_fn if sweep_fn is not None
                          else self._sweep_slice)
        self._flush_fn = (flush_fn if flush_fn is not None
                          else self.store.flush)
        self._on_done = on_done
        self.phase = GCPhase.IDLE
        self.epoch = 0
        self.report: GCReport | None = None
        self._shaded: set[bytes] = set()        # gray or black (tri-color)
        self._gray: deque[bytes] = deque()
        self._condemned: deque[bytes] = deque()
        self._condemned_set: set[bytes] = set()

    # ------------------------------------------------------------ state
    @property
    def active(self) -> bool:
        return self.phase in (GCPhase.MARK, GCPhase.SWEEP)

    @property
    def marked(self) -> frozenset:
        """The shaded (gray + black) cid set — live this epoch.  Freed
        at DONE (``report.live_chunks`` keeps the count); empty between
        epochs."""
        return frozenset(self._shaded)

    # ------------------------------------------------------------ begin
    def begin(self, extra_roots=()) -> int:
        """Snapshot the root set, install the write barriers and enter
        MARK.  Returns the new epoch number.  The snapshot is a copy:
        branch tables may change freely afterwards (removed heads stay
        live this epoch — floating garbage, collected next epoch)."""
        if self.active:
            raise RuntimeError(
                f"collection already in flight (epoch {self.epoch}, "
                f"phase {self.phase})")
        roots = set(self.extra_roots) | set(bytes(u) for u in extra_roots)
        if self.branches is not None:
            roots |= self.branches.all_heads()      # branch-table copy
        if self.pins is not None:
            roots |= self.pins.uids()
        frontier, missing = filter_roots(self.store, roots)
        self.epoch += 1
        self.report = GCReport(roots=len(roots), missing_roots=missing,
                               epoch=self.epoch)
        self._shaded = set(frontier)
        self._gray = deque(frontier)
        self._condemned = deque()
        self._condemned_set = set()
        for s in self._barrier_stores:
            s.add_put_listener(self._put_barrier)
        self.phase = GCPhase.MARK
        return self.epoch

    # ---------------------------------------------------------- barrier
    def _put_barrier(self, cids) -> None:
        """Store-level write barrier: fires on every put batch (ForkBase
        put/merge/truncate_history, WriteBuffer flush) of every store
        this collection watches."""
        if self.phase is GCPhase.MARK:
            for c in cids:
                if c not in self._shaded:
                    self._shaded.add(c)
                    self._gray.append(c)
                    self.report.barriered += 1
        elif self.phase is GCPhase.SWEEP:
            for c in cids:
                if c in self._condemned_set:
                    self._condemned_set.discard(c)
                    self.report.barriered += 1

    def root_barrier(self, uid: bytes) -> None:
        """Re-rooting barrier: a mutator just made ``uid`` a root (fork
        from an explicit uid, a new pin).  During MARK shading it is
        enough — the mark traverses from it; during SWEEP the rescue is
        transitive through the condemned set, because marking is over
        and a re-rooted detached subgraph must ALL survive."""
        if not self.active:
            return
        uid = bytes(uid)
        if self.phase is GCPhase.MARK:
            self._put_barrier([uid] if self.store.has(uid) else [])
            return
        if uid not in self._condemned_set:
            return                   # black, already rescued, or swept
        frontier = [uid]
        while frontier:
            for c in frontier:
                self._condemned_set.discard(c)
            self.report.barriered += len(frontier)
            present = [c for c, p in zip(frontier,
                                         self.store.has_many(frontier))
                       if p]
            nxt: list[bytes] = []
            for raw in self.store.get_many(present):
                refs = list(chunk_refs(raw))
                for hook in self.ref_hooks:   # app-level links too (a
                    refs.extend(hook(raw))    # ckpt manifest's tensor
                nxt.extend(r for r in refs    # roots live through hooks)
                           if r in self._condemned_set)
            frontier = sorted(set(nxt))

    # ------------------------------------------------------------- step
    def step(self, budget: int = 256) -> GCPhase:
        """Advance the collection by at most ``budget`` chunks (marked
        OR swept — one bounded pause) and return the phase.  The
        MARK->SWEEP transition step freezes the condemned set without
        deleting anything, so a slice never exceeds its budget."""
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        if not self.active:
            return self.phase
        self.report.slices += 1
        if self.phase is GCPhase.MARK:
            if self._gray:
                self.report.mark_rounds += 1
                batch = [self._gray.popleft()
                         for _ in range(min(budget, len(self._gray)))]
                self._gray.extend(
                    expand_refs(self.store, batch, self.ref_hooks,
                                self._shaded))
            if not self._gray:
                self._freeze_condemned()
            return self.phase
        # SWEEP: delete up to ``budget`` still-condemned cids
        batch: list[bytes] = []
        while self._condemned and len(batch) < budget:
            c = self._condemned.popleft()
            if c in self._condemned_set:          # not rescued meanwhile
                self._condemned_set.discard(c)
                batch.append(c)
        if batch:
            n, freed = self._sweep_fn(sorted(batch))
            self.report.swept_chunks += n
            self.report.reclaimed_bytes += freed
        if not self._condemned:
            self._finish()
        return self.phase

    def collect(self, budget: int = 256) -> GCReport:
        """Drive one whole epoch: begin (if idle) and step to DONE."""
        if not self.active:
            self.begin()
        while self.step(budget) is not GCPhase.DONE:
            pass
        return self.report

    # ---------------------------------------------------------- internal
    def _freeze_condemned(self) -> None:
        """Gray queue drained: freeze inventory-minus-shaded as the
        condemned set and enter SWEEP.  Chunks put after this instant
        are absent from the frozen inventory and can never be swept."""
        self.report.live_chunks = len(self._shaded)
        cond = sorted(c for c in self._inventory_fn()
                      if c not in self._shaded)
        self._condemned = deque(cond)
        self._condemned_set = set(cond)
        self.phase = GCPhase.SWEEP
        if not self._condemned:
            self._finish()

    def _sweep_slice(self, cids) -> tuple[int, int]:
        r0 = self.store.stats.reclaimed_bytes
        n = self.store.delete_many(cids)
        return n, self.store.stats.reclaimed_bytes - r0

    def _finish(self) -> None:
        for s in self._barrier_stores:
            s.remove_put_listener(self._put_barrier)
        if self.report.swept_chunks:
            self._flush_fn()         # durable tombstones, like collect()
        self._gray.clear()
        self._condemned.clear()
        self._condemned_set = set()
        self._shaded = set()         # O(live) memory is the epoch's, not ours
        self.phase = GCPhase.DONE
        if self._on_done is not None:
            self._on_done(self.report)
