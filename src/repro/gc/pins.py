"""PinSet — explicit GC roots for state the branch tables can't see.

Two users:
  * in-flight readers: a long scan holds the uid it is walking so a
    concurrent ``collect()`` can't sweep chunks out from under it;
  * checkpoint retention holds: ``CheckpointStore.prune`` pins versions
    an external consumer (eval job, export) still needs even though the
    retention policy would retire them.

Pins are reference-counted, so nested holds of the same uid compose.
"""
from __future__ import annotations

from collections import Counter
from contextlib import contextmanager


class PinSet:
    def __init__(self, on_pin=None):
        self._refs: Counter[bytes] = Counter()
        # root barrier for incremental GC: pinning a detached uid while
        # a collection is in flight must shade/rescue it (the engine
        # wires this to its active collectors)
        self.on_pin = on_pin

    def pin(self, *uids: bytes) -> None:
        for u in uids:
            self._refs[bytes(u)] += 1
            if self.on_pin is not None:
                self.on_pin(bytes(u))

    def unpin(self, *uids: bytes) -> None:
        for u in uids:
            u = bytes(u)
            if self._refs[u] <= 1:
                del self._refs[u]
            else:
                self._refs[u] -= 1

    @contextmanager
    def hold(self, *uids: bytes):
        """Scoped pin for an in-flight reader."""
        self.pin(*uids)
        try:
            yield
        finally:
            self.unpin(*uids)

    def uids(self) -> set[bytes]:
        return set(self._refs)

    def __contains__(self, uid: bytes) -> bool:
        return bytes(uid) in self._refs

    def __len__(self) -> int:
        return len(self._refs)
