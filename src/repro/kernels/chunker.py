"""Pallas TPU kernel: rolling-hash boundary bitmap for content-defined
chunking (the paper's POS-Tree hot-spot — §4.3.3 reports the rolling hash
as 20% of tree-build cost; Table 4 shows it dominating Put latency).

TPU adaptation (DESIGN.md §3): the byte-serial CDC scan is re-derived as a
data-parallel computation.  With G_m = rotr(h(b_m), m mod 32),

    P_i = XOR_{j=0..k-1} rotl(h(b_{i-j}), j) = rotl(S_i ^ S_{i-k}, i mod 32)

where S is the running prefix-XOR of G.  Per block the prefix-XOR is a
log2-depth doubling scan along the lane axis — 13 vector ops instead of a
48-deep serial window — and h() is the murmur32 finalizer evaluated
arithmetically (no table gather, which the TPU VPU hates).

Layout: the wrapper reshapes the stream into overlapping rows of
ROW_LEN = HALO + ROW_STRIDE bytes (HALO covers the window so each row is
self-contained; both constants are multiples of 32 so ``pos mod 32`` is a
pure function of the lane index).  The kernel processes SUBLANES=8 rows per
grid step as a (8, ROW_LEN) u32 tile in VMEM — one boundary flag per
payload byte.

Validated against ref.boundary_bitmap_ref in interpret mode (this container
is CPU-only); compiled path is exercised by tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROW_STRIDE = 4992          # payload bytes per row (multiple of 32 and 128)
HALO = 128                 # front halo >= window (multiple of 32)
ROW_LEN = HALO + ROW_STRIDE
SUBLANES = 8               # rows per grid step

_GOLD = 0x9E3779B9
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35


def _mix32(x):
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(_M2)
    return x ^ (x >> jnp.uint32(16))


def _h_byte(b, seed: int):
    """h(byte) == rolling.byte_table(seed)[byte], computed arithmetically."""
    return _mix32(b + jnp.uint32((seed * 0x9E3779B9) & 0xFFFFFFFF))


def _rotl_v(x, r):
    """rotl by per-element amounts r in [0, 32)."""
    return (x << r) | (x >> ((jnp.uint32(32) - r) & jnp.uint32(31)))


def _rotr_v(x, r):
    return (x >> r) | (x << ((jnp.uint32(32) - r) & jnp.uint32(31)))


def _chunker_kernel(x_ref, out_ref, *, window: int, q: int, seed: int):
    x = x_ref[...].astype(jnp.uint32)          # (SUBLANES, ROW_LEN) bytes
    lane = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    g = _rotr_v(_h_byte(x, seed), lane & jnp.uint32(31))
    # prefix-XOR along lanes: log2 doubling scan
    s = g
    shift = 1
    while shift < ROW_LEN:
        shifted = jnp.pad(s, ((0, 0), (shift, 0)))[:, :ROW_LEN]
        s = s ^ shifted
        shift *= 2
    # windowed XOR: W_i = S_i ^ S_{i-window}
    s_k = jnp.pad(s, ((0, 0), (window, 0)))[:, :ROW_LEN]
    w = s ^ s_k
    p = _rotl_v(w, lane & jnp.uint32(31))
    hit = (p & jnp.uint32((1 << q) - 1)) == 0
    out_ref[...] = hit[:, HALO:].astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("window", "q", "seed", "nrows"))
def _run(rows, *, window: int, q: int, seed: int, nrows: int):
    grid = nrows // SUBLANES
    return pl.pallas_call(
        functools.partial(_chunker_kernel, window=window, q=q, seed=seed),
        grid=(grid,),
        in_specs=[pl.BlockSpec((SUBLANES, ROW_LEN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((SUBLANES, ROW_STRIDE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, ROW_STRIDE), jnp.uint8),
        interpret=_INTERPRET,
    )(rows)


# CPU container: interpret mode (executes the kernel body in Python);
# on TPU this flips to False and the same BlockSpecs drive real VMEM tiles.
_INTERPRET = jax.default_backend() != "tpu"


def boundary_bitmap_pallas(data: np.ndarray, window: int, q: int,
                           seed: int = 0xF0B) -> np.ndarray:
    """Drop-in replacement for rolling.boundary_bitmap."""
    assert window <= HALO, f"window {window} exceeds kernel halo {HALO}"
    data = np.asarray(data, dtype=np.uint8)
    n = data.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    nrows = max(1, -(-n // ROW_STRIDE))
    nrows = -(-nrows // SUBLANES) * SUBLANES   # pad rows to sublane multiple
    padded = np.zeros(nrows * ROW_STRIDE + HALO, dtype=np.uint8)
    padded[HALO:HALO + n] = data
    # overlapping rows: row r covers padded[r*STRIDE : r*STRIDE + ROW_LEN)
    idx = (np.arange(nrows)[:, None] * ROW_STRIDE
           + np.arange(ROW_LEN)[None, :])
    rows = padded[idx]
    out = np.asarray(_run(rows, window=window, q=q, seed=seed,
                          nrows=nrows))
    bitmap = out.reshape(-1)[:n].astype(bool)
    bitmap[:window - 1] = False               # no full window yet
    return bitmap
