"""Pallas TPU kernel: 256-bit content hash for the dedup path
(DESIGN.md §3: SHA-256's bit-level structure is hostile to the TPU VPU;
the paper explicitly allows alternative hash functions for cids).

Sponge over u32 words: the state is one native (8, 128) u32 vreg tile;
each 4 KB block is absorbed by XOR and diffused with FP_ROUNDS rounds of
{multiply by odd constant, xor-rotate, lane-roll add, sublane-roll add} —
all elementwise or roll ops the VPU executes natively.  The grid walks
blocks sequentially (TPU grids are serial), carrying the state in a VMEM
scratch accumulator; the final step injects the length, folds lanes and
finalizes.

Bit-for-bit identical to ref.fphash_ref (the numpy oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import FP_BLOCK_WORDS, FP_ROUNDS, FP_STATE, fp_init_state

_GOLD = 0x9E3779B9
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35

_INTERPRET = jax.default_backend() != "tpu"


def _mix32(x):
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(_M2)
    return x ^ (x >> jnp.uint32(16))


def _rotr(x, r: int):
    r &= 31
    if r == 0:
        return x
    return (x >> jnp.uint32(r)) | (x << jnp.uint32(32 - r))


def _round(state):
    state = state * jnp.uint32(_GOLD)
    state = state ^ _rotr(state, 13)
    state = state + pltpu_roll(state, 1, axis=1)
    state = state ^ _rotr(state, 7)
    state = state + pltpu_roll(state, 1, axis=0)
    return state


def pltpu_roll(x, shift: int, axis: int):
    """np.roll equivalent; lane/sublane rotates are native TPU ops."""
    return jnp.roll(x, shift, axis=axis)


def _fphash_kernel(words_ref, len_ref, init_ref, out_ref, state_ref, *,
                   nblocks: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        state_ref[...] = init_ref[...]

    state = state_ref[...] ^ words_ref[...].reshape(FP_STATE)
    for _ in range(FP_ROUNDS):
        state = _round(state)
    state_ref[...] = state

    @pl.when(b == nblocks - 1)
    def _finalize():
        st = state_ref[...] ^ len_ref[0].astype(jnp.uint32)
        st = _round(_round(st))
        folded = st
        shift = 64
        while shift >= 1:   # xor-reduce 128 lanes, log-depth
            folded = folded ^ pltpu_roll(folded, shift, axis=1)
            shift //= 2
        digest = folded[:, 0]
        digest = _mix32(digest ^ (jax.lax.iota(jnp.uint32, 8) * jnp.uint32(_GOLD)))
        out_ref[...] = digest


@functools.partial(jax.jit, static_argnames=("nblocks",))
def _run(words, length, init, *, nblocks: int):
    return pl.pallas_call(
        functools.partial(_fphash_kernel, nblocks=nblocks),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, FP_BLOCK_WORDS), lambda b: (b, 0)),
                  pl.BlockSpec((1,), lambda b: (0,)),
                  pl.BlockSpec(FP_STATE, lambda b: (0, 0))],
        out_specs=pl.BlockSpec((8,), lambda b: (0,)),
        out_shape=jax.ShapeDtypeStruct((8,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM(FP_STATE, jnp.uint32)],
        interpret=_INTERPRET,
    )(words, length, init)


def fphash(data: bytes) -> bytes:
    """256-bit content hash of `data` (the Pallas dedup-path cid)."""
    n = len(data)
    nblocks = max(1, -(-max(n, 1) // (FP_BLOCK_WORDS * 4)))
    buf = np.zeros(nblocks * FP_BLOCK_WORDS * 4, dtype=np.uint8)
    buf[:n] = np.frombuffer(data, dtype=np.uint8)
    words = buf.view("<u4").astype(np.uint32).reshape(nblocks,
                                                      FP_BLOCK_WORDS)
    out = _run(words, jnp.asarray([n & 0xFFFFFFFF], dtype=jnp.uint32),
               jnp.asarray(fp_init_state(), dtype=jnp.uint32),
               nblocks=nblocks)
    return np.asarray(out).astype("<u4").tobytes()
