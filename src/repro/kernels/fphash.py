"""Pallas TPU kernel: 256-bit content hash for the dedup path
(DESIGN.md §3: SHA-256's bit-level structure is hostile to the TPU VPU;
the paper explicitly allows alternative hash functions for cids).

Sponge over u32 words: the state is one native (8, 128) u32 vreg tile;
each 4 KB block is absorbed by XOR and diffused with FP_ROUNDS rounds of
{multiply by odd constant, xor-rotate, lane-roll add, sublane-roll add} —
all elementwise or roll ops the VPU executes natively.  The grid walks
blocks sequentially (TPU grids are serial), carrying the state in a VMEM
scratch accumulator; the final step injects the length, folds lanes and
finalizes.

Bit-for-bit identical to ref.fphash_ref (the numpy oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import FP_BLOCK_WORDS, FP_ROUNDS, FP_STATE, fp_init_state

_GOLD = 0x9E3779B9
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35

_INTERPRET = jax.default_backend() != "tpu"


def _mix32(x):
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(_M2)
    return x ^ (x >> jnp.uint32(16))


def _rotr(x, r: int):
    r &= 31
    if r == 0:
        return x
    return (x >> jnp.uint32(r)) | (x << jnp.uint32(32 - r))


def _round(state):
    state = state * jnp.uint32(_GOLD)
    state = state ^ _rotr(state, 13)
    state = state + pltpu_roll(state, 1, axis=1)
    state = state ^ _rotr(state, 7)
    state = state + pltpu_roll(state, 1, axis=0)
    return state


def pltpu_roll(x, shift: int, axis: int):
    """np.roll equivalent; lane/sublane rotates are native TPU ops."""
    return jnp.roll(x, shift, axis=axis)


def _fphash_kernel(words_ref, len_ref, init_ref, out_ref, state_ref, *,
                   nblocks: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        state_ref[...] = init_ref[...]

    state = state_ref[...] ^ words_ref[...].reshape(FP_STATE)
    for _ in range(FP_ROUNDS):
        state = _round(state)
    state_ref[...] = state

    @pl.when(b == nblocks - 1)
    def _finalize():
        st = state_ref[...] ^ len_ref[0].astype(jnp.uint32)
        st = _round(_round(st))
        folded = st
        shift = 64
        while shift >= 1:   # xor-reduce 128 lanes, log-depth
            folded = folded ^ pltpu_roll(folded, shift, axis=1)
            shift //= 2
        digest = folded[:, 0]
        digest = _mix32(digest ^ (jax.lax.iota(jnp.uint32, 8) * jnp.uint32(_GOLD)))
        out_ref[...] = digest


@functools.partial(jax.jit, static_argnames=("nblocks",))
def _run(words, length, init, *, nblocks: int):
    return pl.pallas_call(
        functools.partial(_fphash_kernel, nblocks=nblocks),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, FP_BLOCK_WORDS), lambda b: (b, 0)),
                  pl.BlockSpec((1,), lambda b: (0,)),
                  pl.BlockSpec(FP_STATE, lambda b: (0, 0))],
        out_specs=pl.BlockSpec((8,), lambda b: (0,)),
        out_shape=jax.ShapeDtypeStruct((8,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM(FP_STATE, jnp.uint32)],
        interpret=_INTERPRET,
    )(words, length, init)


def fphash(data: bytes) -> bytes:
    """256-bit content hash of `data` (the Pallas dedup-path cid)."""
    n = len(data)
    nblocks = max(1, -(-max(n, 1) // (FP_BLOCK_WORDS * 4)))
    buf = np.zeros(nblocks * FP_BLOCK_WORDS * 4, dtype=np.uint8)
    buf[:n] = np.frombuffer(data, dtype=np.uint8)
    words = buf.view("<u4").astype(np.uint32).reshape(nblocks,
                                                      FP_BLOCK_WORDS)
    out = _run(words, jnp.asarray([n & 0xFFFFFFFF], dtype=jnp.uint32),
               jnp.asarray(fp_init_state(), dtype=jnp.uint32),
               nblocks=nblocks)
    return np.asarray(out).astype("<u4").tobytes()


# ----------------------------------------------------------- batched path
#
# The storage engine commits a value's chunks with one put_many batch;
# this is the matching hash entry point: ONE kernel launch digests every
# chunk of the batch.  Grid = (chunk, block); TPU grids iterate serially
# with the last axis fastest, so the VMEM state accumulator is re-seeded
# at each chunk's block 0, absorbs only that chunk's own blocks (shorter
# chunks skip the zero-padding tail), and finalizes into out[chunk] at
# its last real block — bit-for-bit identical to fphash() per chunk.

def _fphash_many_kernel(words_ref, len_ref, nb_ref, init_ref, out_ref,
                        state_ref):
    b = pl.program_id(1)
    nb = nb_ref[0]

    @pl.when(b == 0)
    def _init():
        state_ref[...] = init_ref[...]

    @pl.when(b < nb)
    def _absorb():
        state = state_ref[...] ^ words_ref[...].reshape(FP_STATE)
        for _ in range(FP_ROUNDS):
            state = _round(state)
        state_ref[...] = state

    @pl.when(b == nb - 1)
    def _finalize():
        st = state_ref[...] ^ len_ref[0].astype(jnp.uint32)
        st = _round(_round(st))
        folded = st
        shift = 64
        while shift >= 1:   # xor-reduce 128 lanes, log-depth
            folded = folded ^ pltpu_roll(folded, shift, axis=1)
            shift //= 2
        digest = folded[:, 0]
        digest = _mix32(digest ^ (jax.lax.iota(jnp.uint32, 8) * jnp.uint32(_GOLD)))
        out_ref[...] = digest.reshape(1, 8)


@functools.partial(jax.jit, static_argnames=("nchunks", "maxnb"))
def _run_many(words, lengths, nbs, init, *, nchunks: int, maxnb: int):
    return pl.pallas_call(
        _fphash_many_kernel,
        grid=(nchunks, maxnb),
        in_specs=[pl.BlockSpec((1, 1, FP_BLOCK_WORDS), lambda i, b: (i, b, 0)),
                  pl.BlockSpec((1,), lambda i, b: (i,)),
                  pl.BlockSpec((1,), lambda i, b: (i,)),
                  pl.BlockSpec(FP_STATE, lambda i, b: (0, 0))],
        out_specs=pl.BlockSpec((1, 8), lambda i, b: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nchunks, 8), jnp.uint32),
        scratch_shapes=[pltpu.VMEM(FP_STATE, jnp.uint32)],
        interpret=_INTERPRET,
    )(words, lengths, nbs, init)


def _pow2(x: int) -> int:
    return 1 << max(0, x - 1).bit_length()


# ----------------------------------------------------------- host fallback
#
# Off-TPU, pl.pallas_call(interpret=True) is a correctness oracle, not a
# perf path (~100x slower than hashlib).  The batched entry point instead
# runs the same sponge as a *vectorized numpy* computation — one array op
# sweep per block index across every chunk of the bucket — bit-for-bit
# identical to the kernel (asserted by the conformance test), so cids are
# stable across hosts and TPUs.

_GOLD_NP = np.uint32(_GOLD)


def _host_rotr(x: np.ndarray, r: int) -> np.ndarray:
    r &= 31
    if r == 0:
        return x
    return (x >> np.uint32(r)) | (x << np.uint32(32 - r))


def _host_round(state: np.ndarray) -> np.ndarray:
    state = state * _GOLD_NP
    state = state ^ _host_rotr(state, 13)
    state = state + np.roll(state, 1, axis=-1)
    state = state ^ _host_rotr(state, 7)
    state = state + np.roll(state, 1, axis=-2)
    return state


def _host_mix32(x: np.ndarray) -> np.ndarray:
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(_M1)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(_M2)
    return x ^ (x >> np.uint32(16))


def _fphash_many_host(blobs: list[bytes], nbs: list[int]) -> list[bytes]:
    out: list[bytes | None] = [None] * len(blobs)
    buckets: dict[int, list[int]] = {}
    for i, nb in enumerate(nbs):
        buckets.setdefault(nb, []).append(i)
    init = np.asarray(fp_init_state(), dtype=np.uint32)
    with np.errstate(over="ignore"):
        for nb, idx in buckets.items():
            m = len(idx)
            buf = np.zeros((m, nb * FP_BLOCK_WORDS * 4), dtype=np.uint8)
            for r, i in enumerate(idx):
                buf[r, :len(blobs[i])] = np.frombuffer(blobs[i],
                                                       dtype=np.uint8)
            words = buf.view("<u4").astype(np.uint32).reshape(
                (m, nb) + FP_STATE)
            state = np.broadcast_to(init, (m,) + FP_STATE)
            for b in range(nb):
                state = state ^ words[:, b]
                for _ in range(FP_ROUNDS):
                    state = _host_round(state)
            lens = np.asarray([len(blobs[i]) & 0xFFFFFFFF for i in idx],
                              dtype=np.uint32)
            state = state ^ lens[:, None, None]
            state = _host_round(_host_round(state))
            folded = np.bitwise_xor.reduce(state, axis=-1)
            folded = _host_mix32(
                folded ^ (np.arange(8, dtype=np.uint32)[None, :] * _GOLD_NP))
            res = folded.astype("<u4")
            for r, i in enumerate(idx):
                out[i] = res[r].tobytes()
    return out  # type: ignore[return-value]


def fphash_many(blobs) -> list[bytes]:
    """Vectorized cid path behind ``core.hashing.content_hash_many``:
    hash a batch of byte strings with one kernel launch per block-count
    bucket (for typical 4 KB chunk streams that is ONE launch for the
    whole value).  Rows are bucketed by pow2 block count so one outlier
    chunk cannot force every row to its width (memory stays O(input
    bytes), not O(n x max)), and batch counts round up to powers of two,
    bounding jit retraces to O(log^2) shape buckets.  The kernel masks
    per-chunk, so padding never enters a digest.  Without a TPU the same
    sponge runs as one vectorized numpy sweep per bucket instead of the
    (much slower) Pallas interpreter — digests are identical either way."""
    blobs = [bytes(b) for b in blobs]
    if not blobs:
        return []
    nbs = [max(1, -(-max(len(b), 1) // (FP_BLOCK_WORDS * 4))) for b in blobs]
    if _INTERPRET:
        return _fphash_many_host(blobs, nbs)
    buckets: dict[int, list[int]] = {}
    for i, nb in enumerate(nbs):
        buckets.setdefault(_pow2(nb), []).append(i)
    out: list[bytes | None] = [None] * len(blobs)
    for maxnb, idx in buckets.items():
        n_pad = _pow2(len(idx))
        buf = np.zeros((n_pad, maxnb * FP_BLOCK_WORDS * 4), dtype=np.uint8)
        for r, i in enumerate(idx):
            buf[r, :len(blobs[i])] = np.frombuffer(blobs[i], dtype=np.uint8)
        words = buf.view("<u4").astype(np.uint32).reshape(n_pad, maxnb,
                                                          FP_BLOCK_WORDS)
        pad = n_pad - len(idx)               # padding rows: 1 empty block
        lens = [len(blobs[i]) & 0xFFFFFFFF for i in idx] + [0] * pad
        bnbs = [nbs[i] for i in idx] + [1] * pad
        res = _run_many(
            words,
            jnp.asarray(lens, dtype=jnp.uint32),
            jnp.asarray(bnbs, dtype=jnp.int32),
            jnp.asarray(fp_init_state(), dtype=jnp.uint32),
            nchunks=n_pad, maxnb=maxnb)
        res = np.asarray(res[:len(idx)]).astype("<u4")
        for r, i in enumerate(idx):
            out[i] = res[r].tobytes()
    return out  # type: ignore[return-value]
