"""jit'd public wrappers for the Pallas kernels + engine integration.

``use_pallas_chunker()`` flips the whole storage engine (core.chunker) to
the Pallas boundary kernel; ``use_pallas_hash()`` switches cid hashing to
the fphash kernel (dedup path — see DESIGN.md §3 for the two-tier hash
policy).  Both are opt-in so the default engine stays dependency-light.
"""
from __future__ import annotations

import numpy as np

from repro.core import chunker as _core_chunker
from repro.core import hashing as _core_hashing
from repro.core import rolling as _core_rolling

from .chunker import boundary_bitmap_pallas
from .fphash import fphash
from .ref import boundary_bitmap_ref, fphash_ref


def boundary_bitmap(data, window: int = 48, q: int = 12) -> np.ndarray:
    """Pallas-accelerated content-defined chunk boundary bitmap."""
    return boundary_bitmap_pallas(np.asarray(data, dtype=np.uint8),
                                  window, q)


def content_hash(data: bytes) -> bytes:
    """Pallas-accelerated 256-bit content hash (dedup-path cid)."""
    return fphash(bytes(data))


def use_pallas_chunker(enable: bool = True) -> None:
    _core_chunker.set_bitmap_impl(
        boundary_bitmap_pallas if enable else _core_rolling.boundary_bitmap)


def use_pallas_hash(enable: bool = True) -> None:
    """Delegates to hashing.use_fphash/use_sha256 so the batched entry
    point (fphash_many: one launch per value) switches together with the
    singular one — a bare set_default_hash(fphash) would silently fall
    back to one kernel launch per chunk in put_many."""
    if enable:
        _core_hashing.use_fphash()
    else:
        _core_hashing.use_sha256()


__all__ = ["boundary_bitmap", "content_hash", "use_pallas_chunker",
           "use_pallas_hash", "boundary_bitmap_ref", "fphash_ref"]
