"""Pure-numpy/jnp oracles for the Pallas kernels.

  * boundary_bitmap_ref — cyclic-polynomial rolling-hash pattern bitmap
    (identical to repro.core.rolling, the storage engine's CPU path);
  * fphash_ref          — 256-bit TPU-native content hash (dedup path).

tests/test_kernels.py sweeps shapes/dtypes and asserts the Pallas kernels
(interpret=True) match these bit-for-bit.
"""
from __future__ import annotations

import numpy as np

from repro.core import rolling

# ------------------------------------------------------------- chunker ref

def boundary_bitmap_ref(data: np.ndarray, window: int, q: int) -> np.ndarray:
    return rolling.boundary_bitmap(np.asarray(data, dtype=np.uint8),
                                   window, q)


# ------------------------------------------------------------- fphash ref

FP_ROUNDS = 4
FP_BLOCK_WORDS = 1024            # 4 KB per absorb block
FP_STATE = (8, 128)              # u32 sponge state = one native vreg tile
_GOLD = np.uint32(0x9E3779B9)


def _rotr(x: np.ndarray, r: int) -> np.ndarray:
    r &= 31
    if r == 0:
        return x
    return ((x >> np.uint32(r)) | (x << np.uint32(32 - r))) \
        & np.uint32(0xFFFFFFFF)


def fp_init_state() -> np.ndarray:
    idx = np.arange(8 * 128, dtype=np.uint32).reshape(FP_STATE)
    return rolling.mix32(idx + _GOLD)


def fp_round(state: np.ndarray) -> np.ndarray:
    """One diffusion round: multiply, xor-rotate, cross-lane/sublane mix.
    All ops are elementwise or lane/sublane rolls — native on the TPU VPU."""
    with np.errstate(over="ignore"):
        state = (state * _GOLD) & np.uint32(0xFFFFFFFF)
        state ^= _rotr(state, 13)
        state = (state + np.roll(state, 1, axis=1)) & np.uint32(0xFFFFFFFF)
        state ^= _rotr(state, 7)
        state = (state + np.roll(state, 1, axis=0)) & np.uint32(0xFFFFFFFF)
    return state


def fphash_ref(data: bytes) -> bytes:
    """256-bit keyed content hash: zero-pad to a 4 KB block multiple,
    absorb blocks Merkle–Damgard style, inject the true length, fold."""
    n = len(data)
    nblocks = max(1, -(-max(n, 1) // (FP_BLOCK_WORDS * 4)))
    buf = np.zeros(nblocks * FP_BLOCK_WORDS * 4, dtype=np.uint8)
    buf[:n] = np.frombuffer(data, dtype=np.uint8)
    words = buf.view("<u4").astype(np.uint32)
    state = fp_init_state()
    for b in range(nblocks):
        blk = words[b * FP_BLOCK_WORDS:(b + 1) * FP_BLOCK_WORDS]
        state = state ^ blk.reshape(FP_STATE)
        for _ in range(FP_ROUNDS):
            state = fp_round(state)
    state = state ^ np.uint32(n & 0xFFFFFFFF)
    state = fp_round(fp_round(state))
    # fold 8x128 -> 8 words: xor-reduce lanes, then finalize
    folded = state[:, 0]
    for c in range(1, 128):
        folded = folded ^ state[:, c]
    folded = rolling.mix32(folded ^ (np.arange(8, dtype=np.uint32) * _GOLD))
    return folded.astype("<u4").tobytes()
