import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first
# init.  512 host devices back the 2x16x16 production mesh; smoke tests and
# benches never import this module and keep seeing 1 device.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402
from pathlib import Path       # noqa: E402

import jax           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, SHAPES, input_specs, shapes_for  # noqa: E402
from ..obs import monotonic                                   # noqa: E402
from ..roofline import analyze_hlo                            # noqa: E402
from ..models import model as model_mod                       # noqa: E402
from ..shardings import Sharding                              # noqa: E402
from ..train import AdamWConfig, init_train_state, make_train_step  # noqa: E402
from .mesh import make_production_mesh                        # noqa: E402

"""Multi-pod dry-run (deliverable e): for EVERY (architecture x input
shape) cell, ``jit(step).lower(**ShapeDtypeStructs).compile()`` must
succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh.

No arrays are ever materialized: model/optimizer state comes from
jax.eval_shape over the init functions; inputs from configs.input_specs.
Each cell's memory_analysis / cost_analysis / collective-op census is
written to experiments/dryrun/<arch>__<shape>__<mesh>.json — the roofline
analysis (repro/roofline.py, EXPERIMENTS.md §Roofline) consumes these.
"""

OUTDIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every tensor literal like bf16[2,512,128] in an
    HLO result-shape string (handles tuples)."""
    sizes = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
             "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
             "u64": 8, "c64": 8}
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", text):
        dt, dims = m.group(1), m.group(2)
        if dt not in sizes:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * sizes[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Loop-aware collective census over optimized per-device HLO.

    Computations are scanned for collective ops; while-loop bodies are
    multiplied by their trip count (recovered from the loop condition's
    comparison constant — scan lowers to a counted while).
    """
    comps: dict[str, list] = {}
    cur = None
    trip_const: dict[str, int] = {}
    for line in hlo.splitlines():
        m = re.match(r"^%?([\w\.\-]+)[^=]*\{\s*$", line.strip())
        if not line.startswith(" ") and ("{" in line) and ("=" not in line.split("{")[0]):
            name = line.split("{")[0].strip().lstrip("%").split(" ")[0]
            name = name.split("(")[0].rstrip(".0123456789") or name
            cur = line.split("(")[0].strip().lstrip("%")
            comps.setdefault(cur, [])
            continue
        if cur is None:
            continue
        ls = line.strip()
        for op in COLLECTIVES:
            if re.search(rf"= [^=]*\b{op}\(", ls) or \
                    re.search(rf"\b{op}-(start|done)\(", ls):
                shape_part = ls.split("=")[1] if "=" in ls else ls
                shape_part = shape_part.split(op)[0]
                comps[cur].append((op, _shape_bytes(shape_part)))
                break
        cm = re.search(r"compare\([^)]*\).*direction=LT", ls)
        if "constant(" in ls and cur:
            mc = re.search(r"s32\[\] constant\((\d+)\)", ls)
            if mc:
                trip_const[cur] = max(trip_const.get(cur, 0),
                                      int(mc.group(1)))

    # find while ops: body=..., condition=...
    whiles = re.findall(r"while\([^)]*\), condition=%?([\w\.\-]+), "
                        r"body=%?([\w\.\-]+)", hlo)
    body_trip = {}
    for cond, body in whiles:
        body_trip[body] = max(trip_const.get(cond, 1), 1)

    per_op = {op: 0 for op in COLLECTIVES}
    counts = {op: 0 for op in COLLECTIVES}
    for comp, ops in comps.items():
        mult = body_trip.get(comp, 1)
        for op, nbytes in ops:
            per_op[op] += nbytes * mult
            counts[op] += mult
    return {"bytes_per_op": per_op, "counts": counts,
            "total_bytes": sum(per_op.values()),
            "n_while_bodies": len(body_trip)}


def eval_state_specs(cfg, shd):
    state_shapes = jax.eval_shape(
        partial(init_train_state, cfg, shards=shd.tp),
        jax.random.PRNGKey(0))
    return state_shapes, shd.state_specs(state_shapes)


def _named(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatch: int = 1, variant: str = "base",
               overrides: dict | None = None) -> dict:
    import dataclasses
    cfg = ARCHS[arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    shd = Sharding(mesh, cfg, shape.global_batch)
    ispecs = input_specs(cfg, shape)
    t0 = monotonic()

    if shape.kind == "train":
        state_shapes, sspecs = eval_state_specs(cfg, shd)
        mb = microbatch if microbatch > 1 else cfg.train_microbatch
        step = make_train_step(cfg, shd, AdamWConfig(), microbatch=mb)
        bspecs = shd.batch_specs(ispecs)
        jfn = jax.jit(step,
                      in_shardings=(_named(mesh, sspecs),
                                    _named(mesh, bspecs)),
                      out_shardings=(_named(mesh, sspecs), None),
                      donate_argnums=(0,))
        with mesh:
            lowered = jfn.lower(state_shapes, ispecs)
    else:
        params_shapes = jax.eval_shape(
            partial(model_mod.init_params, cfg, shards=shd.tp),
            jax.random.PRNGKey(0))
        pspecs = shd.param_specs(params_shapes)
        if shape.kind == "prefill":
            def fn(params, batch):
                return model_mod.prefill(params, batch, cfg, shd)
            bspecs = shd.batch_specs(ispecs)
            jfn = jax.jit(fn, in_shardings=(_named(mesh, pspecs),
                                            _named(mesh, bspecs)))
            with mesh:
                lowered = jfn.lower(params_shapes, ispecs)
        else:                                  # decode
            cache_shapes = jax.eval_shape(
                partial(model_mod.init_cache, cfg, shape.global_batch,
                        shape.seq_len))
            cspecs = shd.cache_specs(cache_shapes)

            def fn(params, cache, batch):
                return model_mod.decode_step(params, cache, batch, cfg, shd)
            bspecs = shd.batch_specs(ispecs)
            jfn = jax.jit(fn, in_shardings=(_named(mesh, pspecs),
                                            _named(mesh, cspecs),
                                            _named(mesh, bspecs)),
                          donate_argnums=(1,))
            with mesh:
                lowered = jfn.lower(params_shapes, cache_shapes, ispecs)
    t_lower = monotonic() - t0

    t0 = monotonic()
    compiled = lowered.compile()
    t_compile = monotonic() - t0

    mem = compiled.memory_analysis()
    print(mem)                                # proves it fits
    ca = compiled.cost_analysis() or {}
    print({k: ca[k] for k in sorted(ca) if not k.endswith("}")})

    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    loop_aware = analyze_hlo(hlo)
    n_chips = 512 if multi_pod else 256

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "microbatch": microbatch if microbatch > 1 else cfg.train_microbatch,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                / 2**30, 3),
        },
        "cost": {"flops_per_device": ca.get("flops", 0.0),
                 "bytes_per_device": ca.get("bytes accessed", 0.0),
                 "transcendentals": ca.get("transcendentals", 0.0)},
        "collectives": coll,
        "loop_aware": loop_aware,
        "params_total": cfg.params_count(),
        "params_active": cfg.active_params_count(),
        "tokens_per_step": (shape.global_batch * shape.seq_len
                            if shape.kind != "decode"
                            else shape.global_batch),
        "kind": shape.kind,
    }
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--variant", default="base")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides for perf variants, e.g. "
                         "moe_impl=onehot remat_policy=dots kv_quant=0")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = (int(v) if v.lstrip("-").isdigit()
                        else v == "True" if v in ("True", "False") else v)
    if "kv_quant" in overrides:
        overrides["kv_quant"] = bool(overrides["kv_quant"])
    if "fsdp" in overrides:
        overrides["fsdp"] = bool(overrides["fsdp"])

    OUTDIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        cfg = ARCHS[arch]
        shapes = [args.shape] if args.shape else shapes_for(cfg)
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.variant != "base":
                    tag += f"__{args.variant}"
                out = OUTDIR / f"{tag}.json"
                if out.exists() and not args.force:
                    print(f"[skip] {tag}")
                    continue
                print(f"[run ] {tag} ...", flush=True)
                try:
                    res = lower_cell(arch, shape, mp,
                                     microbatch=args.microbatch,
                                     variant=args.variant,
                                     overrides=overrides)
                    out.write_text(json.dumps(res, indent=1))
                    print(f"[ ok ] {tag}: compile={res['compile_s']}s "
                          f"peak={res['memory']['peak_per_device_gb']}GB "
                          f"flops/dev={res['cost']['flops_per_device']:.3g} "
                          f"coll={res['collectives']['total_bytes']:.3g}B",
                          flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("\nDRY-RUN: all requested cells lowered + compiled.")


if __name__ == "__main__":
    main()
