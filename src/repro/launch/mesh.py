"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (TPU v5e pod), axes (data, model).
    Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) — the 'pod'
    axis is pure DP across the DCN/ICI-linked pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(n_devices: int, model_axis: int = 16):
    """Elastic helper: best mesh for whatever devices survive (runtime/
    elastic.py re-shards checkpoints onto this after a failure)."""
    model = min(model_axis, n_devices)
    while n_devices % model:
        model //= 2
    data = n_devices // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
