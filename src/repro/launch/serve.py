"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --batch 4 --prompt-len 64 --gen 32 --smoke
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, smoke as smoke_cfg
from ..obs import monotonic
from ..models import model as M
from ..shardings import Sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke or jax.default_backend() == "cpu":
        cfg = smoke_cfg(cfg)
    shd = Sharding(None, cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, shards=4)
    B, S = args.batch, args.prompt_len
    T = S + args.gen
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                          jnp.bfloat16)

    t0 = monotonic()
    if cfg.family in ("hybrid", "ssm", "dense", "moe", "audio", "vlm"):
        cache, logits = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, shd, cache_len=T))(params,
                                                                 batch)
    t_prefill = monotonic() - t0
    decode = jax.jit(lambda p, c, b: M.decode_step(p, c, b, cfg, shd))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    pos0 = S + (cfg.n_patches if cfg.frontend == "vision" else 0)
    t0 = monotonic()
    for i in range(args.gen - 1):
        pos = jnp.full((B,), pos0 + i, jnp.int32)
        cache, logits = decode(params, cache, {"tokens": tok, "pos": pos})
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = monotonic() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    assert (gen < cfg.vocab).all() and np.isfinite(
        np.asarray(logits, np.float32)).all()
    print(f"{cfg.name}: prefill({B}x{S}) {t_prefill:.2f}s; "
          f"decode {args.gen} tokens {dt:.2f}s "
          f"({B * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s); "
          f"sample: {gen[0, :12].tolist()}")


if __name__ == "__main__":
    main()
