"""Training launcher.

On a real TPU fleet this process runs per host under the production mesh
(mesh.make_production_mesh); on this CPU container it drives the same code
path at reduced scale (--smoke).  Checkpoints stream into ForkBase; any
crash resumes from the branch head (runtime/controller.py).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 50 --smoke
"""
from __future__ import annotations

import argparse

import jax

from ..ckpt import CheckpointStore
from ..configs import ARCHS, smoke as smoke_cfg
from ..runtime.controller import TrainController
from ..shardings import Sharding
from ..train import AdamWConfig, init_train_state, make_train_step
from ..train.data import SyntheticLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--branch", default="run")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-feasible)")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke or jax.default_backend() == "cpu":
        cfg = smoke_cfg(cfg)
    shd = Sharding(None, cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0), shards=4)
    ds = SyntheticLM(cfg.vocab, args.seq, args.batch,
                     frontend=cfg.frontend, n_patches=cfg.n_patches,
                     d_model=cfg.d_model)
    step = jax.jit(make_train_step(
        cfg, shd, AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps),
        microbatch=1))
    ctl = TrainController(step, state, ds, CheckpointStore(),
                          branch=args.branch, ckpt_every=args.ckpt_every)
    ctl.run(args.steps)
    losses = [l for _, l in ctl.metrics_log]
    print(f"{cfg.name}: {args.steps} steps, loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}; ckpt dedup "
          f"{ctl.ckpt.dedup_stats.dedup_ratio:.2f}x")


if __name__ == "__main__":
    main()
