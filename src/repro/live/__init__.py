"""Live/Archive split (forkless flat-state fast path).

  LiveTable    flat dict-of-key->value head state: O(1) get/put, backed
               by the POS-Tree archive for history, forks and proofs
  EpochPolicy  dirty-key/byte thresholds that trigger automatic folds
  FoldReport   what one epoch fold committed
  EpochReport  what one ForkBase.commit_epoch() did engine-wide
  LiveStats    flat-path counters (hits/misses/folds/fold cost)

Entry points: ``ForkBase.live(key, branch)`` / ``ForkBase.commit_epoch()``
(embedded engine), ``Cluster.live(key, branch)`` / ``Cluster.commit_epoch()``
(routed per servlet).
"""
from .table import (EpochPolicy, EpochReport, FoldReport, LiveStats,
                    LiveTable)

__all__ = ["EpochPolicy", "EpochReport", "FoldReport", "LiveStats",
           "LiveTable"]
