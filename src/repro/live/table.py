"""LiveDB/ArchiveDB split — the forkless flat-state fast path.

ForkBase pays O(log n) POS-Tree I/O on every get/put even though most
traffic only touches the *current* head of a branch.  The Sonic Labs
line of work ("Efficient Forkless Blockchain Databases") splits live
state from the authenticated archive: a flat O(1) table absorbs puts
and serves gets, and the Merkle commitment is computed once per *epoch*
instead of once per operation.

``LiveTable`` is that flat table for one (key, branch) head:

  * ``get``/``put``/``delete`` are dict operations — no tree walk, no
    chunking, no hashing;
  * the accumulated delta folds into the head's POS-Tree Map at an
    epoch boundary (``fold()``, or automatically when ``EpochPolicy``
    thresholds trip): ONE versioned Put whose FMap commit merges the
    sorted dirty keys into the tree in a single batched pass — one
    ``content_hash_many`` dispatch per tree level and one WriteBuffer
    ``put_many`` flush (see ``FMap.commit``'s rebuild fast path);
  * because POS-Tree node boundaries are a function of content alone,
    the folded root is bit-identical to the root of a tree built by
    direct per-op puts — history, forks, proofs and Diff are untouched.

Forks, merges and ``get(uid=...)`` route through the archive; the
engine folds a dirty head before forking or merging it (db.py).  A
branch-table listener marks the table stale when anything else moves
the head (an external put, a merge, a fork landing on this branch), so
a revalidation reloads the archive tree before the next operation —
the dirty overlay survives and reapplies on top of the new head
(last-writer-wins, the same semantics as two successive puts).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .. import obs
from ..core.branch import DEFAULT_BRANCH
from ..core.types import FMap

_DEL = object()          # deletion sentinel in the dirty overlay


@dataclass
class LiveStats:
    """Flat-path counters — the LiveTable analogue of StoreStats."""

    gets: int = 0                 # get() calls served
    hits: int = 0                 # served from the overlay / clean cache
    misses: int = 0               # fell through to the archive tree
    puts: int = 0                 # put() calls absorbed
    deletes: int = 0              # delete() calls absorbed
    folds: int = 0                # epoch folds committed
    auto_folds: int = 0           # folds triggered by EpochPolicy
    folded_keys: int = 0          # dirty keys folded across all epochs
    fold_seconds: float = 0.0     # wall-clock spent folding
    revalidations: int = 0        # archive-head reloads (external moves)
    dirty_bytes: int = 0          # current overlay payload bytes

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(1, self.gets)


@dataclass(frozen=True)
class EpochPolicy:
    """When a put should trigger an automatic fold.  ``None`` disables a
    threshold; the default folds on ~64k dirty keys or 32 MB of dirty
    payload, whichever comes first."""

    max_dirty_keys: int | None = 1 << 16
    max_dirty_bytes: int | None = 32 << 20

    def due(self, dirty_keys: int, dirty_bytes: int) -> bool:
        return ((self.max_dirty_keys is not None
                 and dirty_keys >= self.max_dirty_keys)
                or (self.max_dirty_bytes is not None
                    and dirty_bytes >= self.max_dirty_bytes))


@dataclass
class FoldReport:
    """What one ``fold()`` did."""

    key: bytes
    branch: str
    uid: bytes | None             # new head uid (None: nothing dirty)
    folded_keys: int = 0
    deleted_keys: int = 0
    seconds: float = 0.0


@dataclass
class EpochReport:
    """What one ``ForkBase.commit_epoch()`` did across all live tables."""

    folds: list[FoldReport] = field(default_factory=list)
    attestation: object | None = None

    @property
    def folded_keys(self) -> int:
        return sum(f.folded_keys for f in self.folds)

    @property
    def folded_uids(self) -> list[bytes]:
        return [f.uid for f in self.folds if f.uid is not None]


class LiveTable:
    """Flat head state for one (ForkBase key, branch).

    Obtain through ``ForkBase.live(key, branch)`` — the engine registers
    the staleness listener and folds the table before fork/merge/remove
    of its key.  Direct construction works but leaves those hooks to
    the caller.
    """

    def __init__(self, db, key: bytes, branch: str = DEFAULT_BRANCH, *,
                 policy: EpochPolicy | None = None):
        self.db = db
        self.key = bytes(key)
        self.branch = branch
        self.policy = policy if policy is not None else EpochPolicy()
        self.stats = LiveStats()
        self._dirty: dict[bytes, object] = {}   # overlay; _DEL = delete
        self._clean: dict[bytes, bytes] = {}    # archive read-through cache
        self._absent: set[bytes] = set()        # negative read-through cache
        self._tree = None                       # head Map's POSTree
        self._base_uid: bytes | None = None     # head uid the tree mirrors
        self._stale = True                      # reload before first use

    # ------------------------------------------------------------ state
    @property
    def dirty_count(self) -> int:
        return len(self._dirty)

    @property
    def base_uid(self) -> bytes | None:
        """Head uid of the last fold/revalidation (the archive anchor)."""
        self._revalidate()
        return self._base_uid

    def _mark_stale(self) -> None:
        """Branch-table listener hook: something touched this key."""
        self._stale = True

    def _revalidate(self) -> None:
        """Reload the archive tree if the branch head moved under us
        (external put, merge, fork landing here).  The dirty overlay is
        kept: it reapplies on top of the new head at the next fold —
        exactly what two successive puts would have produced."""
        if not self._stale:
            return
        self._stale = False
        head = self.db.branches.head(self.key, self.branch)
        if head == self._base_uid:
            return
        self.stats.revalidations += 1
        self._base_uid = head
        self._clean.clear()
        self._absent.clear()
        self._tree = None
        if head is not None:
            h = self.db.get(self.key, uid=head)
            self._tree = h.map().tree      # may be None for an empty put

    # ------------------------------------------------------- flat verbs
    def get(self, k: bytes) -> bytes | None:
        """O(1) for every key previously written, read, or preloaded;
        a cold key costs one archive ``find_key`` and is cached."""
        self._revalidate()
        k = bytes(k)
        st = self.stats
        st.gets += 1
        v = self._dirty.get(k)
        if v is not None or k in self._dirty:
            st.hits += 1
            return None if v is _DEL else v  # type: ignore[return-value]
        v = self._clean.get(k)
        if v is not None:
            st.hits += 1
            return v
        if k in self._absent:
            st.hits += 1
            return None
        st.misses += 1
        if self._tree is None or self._tree.total_count == 0:
            self._absent.add(k)
            return None
        found, _, _, gi = self._tree.find_key(k)
        if not found:
            self._absent.add(k)
            return None
        v = self._tree.get_item(gi)[1]
        self._clean[k] = v
        return v

    def put(self, k: bytes, v: bytes) -> None:
        self._revalidate()
        k, v = bytes(k), bytes(v)
        old = self._dirty.get(k)
        if isinstance(old, bytes):
            self.stats.dirty_bytes -= len(k) + len(old)
        self._dirty[k] = v
        self._absent.discard(k)
        st = self.stats
        st.puts += 1
        st.dirty_bytes += len(k) + len(v)
        if self.policy.due(len(self._dirty), st.dirty_bytes):
            st.auto_folds += 1
            self.fold()

    def delete(self, k: bytes) -> None:
        self._revalidate()
        k = bytes(k)
        old = self._dirty.get(k)
        if isinstance(old, bytes):
            self.stats.dirty_bytes -= len(k) + len(old)
        self._dirty[k] = _DEL
        self.stats.deletes += 1

    def load_all(self) -> int:
        """Preload the whole archive map into the clean cache, so every
        subsequent get is a dict hit (the LiveDB serving shape).
        Returns the number of entries loaded."""
        self._revalidate()
        if self._tree is None:
            return 0
        n = 0
        for k, v in self._tree.iter_elements():
            if k not in self._clean and k not in self._dirty:
                self._clean[k] = v
                n += 1
        return n

    def items(self):
        """Sorted merged iteration of the full live state (archive +
        overlay) — the scan verb; does not populate the cache."""
        self._revalidate()
        m = (FMap.from_tree(self._tree) if self._tree is not None
             else FMap(params=self.db.params))
        for k, v in self._dirty.items():
            if v is _DEL:
                m.delete(k)
            else:
                m.set(k, v)
        return m.items()

    # ------------------------------------------------------------- fold
    def fold(self, *, context: bytes = b"") -> FoldReport:
        """Epoch boundary: commit the accumulated delta into the POS-Tree
        archive as ONE versioned Put and adopt the new head.

        The FMap commit underneath merges the sorted dirty keys into the
        tree in one batched pass (build-from-merged-stream when the
        delta dominates, clustered splice otherwise — identical roots
        either way), and the Put's WriteBuffer flushes every chunk with
        a single ``put_many``, which also fires the GC write barrier so
        an in-flight collection shades/rescues everything the fold just
        referenced."""
        self._revalidate()
        rep = FoldReport(self.key, self.branch, self._base_uid)
        if not self._dirty:
            return rep
        t0 = time.perf_counter()
        m = (FMap.from_tree(self._tree) if self._tree is not None
             else FMap(params=self.db.params))
        deleted = 0
        for k, v in self._dirty.items():
            if v is _DEL:
                m.delete(k)
                deleted += 1
            else:
                m.set(k, v)
        uid = self.db.put(self.key, m, self.branch, context=context)
        # adopt: the committed FMap's tree IS the new head's tree
        self._tree = m.tree
        self._base_uid = uid
        self._stale = False          # the head move was our own put
        for k, v in self._dirty.items():
            if v is _DEL:
                self._clean.pop(k, None)
                self._absent.add(k)
            else:
                self._clean[k] = v   # folded keys stay hot
                self._absent.discard(k)
        n = len(self._dirty)
        self._dirty.clear()
        st = self.stats
        st.dirty_bytes = 0
        st.folds += 1
        st.folded_keys += n
        dt = time.perf_counter() - t0
        st.fold_seconds += dt
        rep.uid = uid
        rep.folded_keys = n
        rep.deleted_keys = deleted
        rep.seconds = dt
        # route the self-timed fold into the shared observability layer:
        # one journal event per epoch fold plus the fold-latency histogram
        obs.emit("live.fold", key=self.key, branch=self.branch,
                 folded_keys=n, deleted_keys=deleted, uid=uid,
                 seconds=round(dt, 6))
        obs.observe("live_fold_us", dt)
        return rep


__all__ = ["EpochPolicy", "EpochReport", "FoldReport", "LiveStats",
           "LiveTable"]
