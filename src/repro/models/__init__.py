from . import layers, model, moe, ssm, xlstm
from .model import (backbone, decode_step, embed_inputs, init_cache,
                    init_params, lm_loss, prefill, train_loss)
