"""Transformer building blocks: RMSNorm, RoPE, GQA attention (flash-style
double-scan, memory-bounded), SwiGLU/GELU MLP.

Attention uses an online-softmax block algorithm (outer scan over query
blocks, inner scan over KV blocks) so the S x S score matrix never
materializes — mandatory for prefill_32k and the 4k training shapes at
production batch.  The same machinery accepts an additive per-block decay
bias, which models/xlstm.py reuses for the parallel mLSTM form.

GQA + TP head padding: when n_heads is not a multiple of the model-axis
size (qwen2-7b: 28 heads on a 16-way axis) each KV group is padded with
zero-weight query heads (wq columns and wo rows are zero), which keeps the
math exact while making the padded head count divide the axis.  KV heads
are repeated per group before flash attention (activation-only cost, freed
by remat); the decode path keeps the grouped form and never repeats the
cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, pos, theta: float):
    """x (..., S, H, dh); pos (..., S) int32 positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                    # (dh/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _blockify(x, block, axis=1):
    n = x.shape[axis]
    nb = n // block
    shape = x.shape[:axis] + (nb, block) + x.shape[axis + 1:]
    return x.reshape(shape), nb


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 512,
                    kv_block: int = 1024, decay: tuple | None = None,
                    softmax_scale: float | None = None,
                    mlstm_norm: bool = False):
    """Online-softmax attention; q/k/v: (B, S, H, dh) (KV pre-repeated).

    decay: optional (F, i_gate) arrays (B, S, H) adding the mLSTM bias
    D_ij = F_i - F_j + i_j to the pre-softmax logits (xlstm.py);
    mlstm_norm uses the mLSTM denominator max(|l|, exp(-m)).

    Memory: O(q_block x kv_block) per (batch, head) — outer scan over query
    blocks, inner scan over KV blocks carrying (acc, m, l).
    """
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0

    qb, nq = _blockify(q, q_block)                  # (B, nq, qb, H, dh)
    kb, nk = _blockify(k, kv_block)                 # (B, nk, kb, H, dh)
    vb, _ = _blockify(v, kv_block)
    if decay is not None:
        F, ig = decay                               # (B, S, H)
        Fq, _ = _blockify(F, q_block)
        Fk, _ = _blockify(F, kv_block)
        igk, _ = _blockify(ig, kv_block)

    def q_step(_, qi):
        qc = qb[:, qi].astype(jnp.float32)          # (B, qb, H, dh)
        m0 = jnp.full((B, q_block, H), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, q_block, H), jnp.float32)
        a0 = jnp.zeros((B, q_block, H, dh), jnp.float32)

        def kv_step(carry, ki):
            acc, m, l = carry
            kc = kb[:, ki].astype(jnp.float32)
            vc = vb[:, ki].astype(jnp.float32)
            s = jnp.einsum("bqhd,bphd->bqhp", qc, kc) * scale
            if decay is not None:
                d = (Fq[:, qi][:, :, None, :]       # (B,qb,1,H)
                     - Fk[:, ki][:, None, :, :]     # (B,1,kb,H)
                     + igk[:, ki][:, None, :, :])   # -> (B,qb,kb,H)
                s = s + jnp.moveaxis(d, -1, 2)      # (B,qb,H,kb)
            if causal:
                qpos = qi * q_block + jnp.arange(q_block)
                kpos = ki * kv_block + jnp.arange(kv_block)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bqhp,bphd->bqhd", p, vc))
            return (acc_new, m_new, l_new), ()

        (acc, m, l), _ = lax.scan(jax.checkpoint(kv_step, prevent_cse=False),
                                  (a0, m0, l0), jnp.arange(nk))
        if mlstm_norm:
            denom = jnp.maximum(jnp.abs(l), jnp.exp(-jnp.where(
                jnp.isfinite(m), m, 0.0))) + 1e-6
        else:
            denom = jnp.maximum(l, 1e-30)
        return (), acc / denom[..., None]

    _, out = lax.scan(jax.checkpoint(q_step, prevent_cse=False), (),
                      jnp.arange(nq))            # (nq, B, qb, H, dh)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, dh)
    return out.astype(q.dtype)


def quantize_kv(x):
    """(..., dh) -> int8 values + fp32 per-(...,) scale."""
    import jax.numpy as jnp
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale):
    import jax.numpy as jnp
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def repeat_kv(k, groups: int):
    """(B, S, KV, dh) -> (B, S, KV*groups, dh), group-aligned."""
    B, S, KV, dh = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, groups, dh))
    return k.reshape(B, S, KV * groups, dh)


def attention_block(p, x, cfg, shd, pos=None, cache=None):
    """Attention sublayer (pre-norm applied by caller).

    Train/prefill: pos None.  Decode: x (B, 1, d), pos (B,), cache
    {'k','v'}: (B, T, KV, dh), functionally updated.
    Returns (out, new_cache or None).
    """
    B, S, _ = x.shape
    KV, dh = cfg.n_kv_heads, cfg.dh
    Hp = p["wq"].shape[1] // dh                     # padded head count
    G = Hp // KV
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hp, dh)
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    pvec = jnp.arange(S)[None, :] if pos is None else pos[:, None]
    q = apply_rope(q, pvec, cfg.rope_theta)
    k = apply_rope(k, pvec, cfg.rope_theta)

    if cache is not None and pos is not None:       # ---- decode
        quant = "ks" in cache

        def row(cr, nr, pr):
            return lax.dynamic_update_slice(
                cr, nr, (pr,) + (0,) * (cr.ndim - 1))
        if quant:
            kq, ksc = quantize_kv(k)
            vq, vsc = quantize_kv(v)
            ck = jax.vmap(row)(cache["k"], kq, pos)
            cv = jax.vmap(row)(cache["v"], vq, pos)
            cks = jax.vmap(row)(cache["ks"], ksc, pos)
            cvs = jax.vmap(row)(cache["vs"], vsc, pos)
            kf = dequantize_kv(ck, cks)
            vf = dequantize_kv(cv, cvs)
            new_cache = {"k": ck, "v": cv, "ks": cks, "vs": cvs}
        else:
            ck = jax.vmap(row)(cache["k"], k, pos)
            cv = jax.vmap(row)(cache["v"], v, pos)
            kf, vf = ck.astype(jnp.float32), cv.astype(jnp.float32)
            new_cache = {"k": ck, "v": cv}
        kf = shd.constrain(kf, "batch", "cache_seq", None, None)
        vf = shd.constrain(vf, "batch", "cache_seq", None, None)
        T = kf.shape[1]
        qf = q.reshape(B, KV, G, dh).astype(jnp.float32)
        s = jnp.einsum("bkgd,btkd->bkgt", qf, kf)
        s = s / math.sqrt(dh)
        mask = jnp.arange(T)[None, :] <= pos[:, None]       # (B, T)
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        o = jnp.einsum("bkgt,btkd->bkgd", w, vf)
        o = o.reshape(B, 1, Hp, dh).astype(x.dtype)
    else:                                            # ---- train / prefill
        q = shd.constrain(q, "batch", "seq", "heads", None)
        kf = repeat_kv(k, G)
        vf = repeat_kv(v, G)
        kf = shd.constrain(kf, "batch", "seq", "heads", None)
        vf = shd.constrain(vf, "batch", "seq", "heads", None)
        o = flash_attention(q, kf, vf, causal=True)
        o = shd.constrain(o, "batch", "seq", "heads", None)
        if cache is not None:                        # prefill fills cache
            T = cache["k"].shape[1]
            pad = ((0, 0), (0, T - S), (0, 0), (0, 0))
            if "ks" in cache:
                kq, ksc = quantize_kv(k)
                vq, vsc = quantize_kv(v)
                pad3 = pad[:-1]
                new_cache = {"k": jnp.pad(kq, pad), "v": jnp.pad(vq, pad),
                             "ks": jnp.pad(ksc, pad3),
                             "vs": jnp.pad(vsc, pad3)}
            else:
                new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        else:
            new_cache = None
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, Hp * dh), p["wo"])
    return out, new_cache


def mlp_block(p, x, cfg, shd):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shd.constrain(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# ------------------------------------------------------------------- init

def padded_heads(cfg, shards: int = 16) -> int:
    """Padded per-group head count * KV (see module docstring)."""
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    Hp = H
    if H % shards != 0 and H > shards:
        # pad per-group so total padded heads divide `shards`
        Gp = G
        while (KV * Gp) % shards != 0:
            Gp += 1
        Hp = KV * Gp
    return Hp


def init_attention(key, cfg, shards: int = 16):
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    Hp = padded_heads(cfg, shards)
    G, Gp = H // KV, Hp // KV
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    # generate (d, KV, Gp, dh) with zeros at g >= G, then flatten
    wq = jax.random.normal(ks[0], (d, KV, Gp, dh), jnp.float32) * std
    wo = jax.random.normal(ks[3], (KV, Gp, dh, d), jnp.float32) * (H * dh) ** -0.5
    if Gp != G:
        wq = wq.at[:, :, G:, :].set(0.0)
        wo = wo.at[:, G:, :, :].set(0.0)
    p = {"wq": wq.reshape(d, Hp * dh).astype(jnp.bfloat16),
         "wk": (jax.random.normal(ks[1], (d, KV * dh)) * std
                ).astype(jnp.bfloat16),
         "wv": (jax.random.normal(ks[2], (d, KV * dh)) * std
                ).astype(jnp.bfloat16),
         "wo": wo.reshape(Hp * dh, d).astype(jnp.bfloat16)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hp * dh,), jnp.bfloat16)
        p["bk"] = jnp.zeros((KV * dh,), jnp.bfloat16)
        p["bv"] = jnp.zeros((KV * dh,), jnp.bfloat16)
    return p


def init_mlp(key, cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_in": (jax.random.normal(ks[0], (d, f)) * d ** -0.5
                  ).astype(jnp.bfloat16),
         "w_out": (jax.random.normal(ks[1], (f, d)) * f ** -0.5
                   ).astype(jnp.bfloat16)}
    if cfg.act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[2], (d, f)) * d ** -0.5
                       ).astype(jnp.bfloat16)
    return p
