"""Composable decoder model: init / train-forward / prefill / decode for
all 10 assigned architectures.

Families:
  dense | audio | vlm : uniform [attn + MLP] stack, lax.scan over layers
  moe                 : uniform [attn + MoE] stack, scan over layers
  hybrid (zamba2)     : 54 Mamba2 layers + ONE shared attn+MLP block
                        (weight-tied) applied every `attn_every` layers —
                        scan over groups, inner scan over the group's
                        mamba layers
  ssm (xlstm)         : 12-layer python loop of mLSTM/sLSTM blocks

Stacks are scanned so HLO size is depth-independent (80-layer qwen1.5-110b
compiles as one loop); each scanned body is wrapped in jax.checkpoint
(remat) so activation memory is O(sqrt-ish), with matmul outputs saveable.

Modality frontends are stubs per the assignment: internvl2 consumes
precomputed patch embeddings through a linear connector; musicgen consumes
the EnCodec token stream directly (single-codebook stand-in).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (attention_block, init_attention, init_mlp, mlp_block,
                     rms_norm)

_POLICIES = {"dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
             "none": None}


def remat_policy(cfg):
    return _POLICIES[getattr(cfg, "remat_policy", "dots")]


REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def xlstm_groups(cfg):
    """(n_groups, period) for the periodic sLSTM placement; (0, 0) if the
    stack is pure mLSTM.  slstm_at must be (0, p, 2p, ...)."""
    if not cfg.slstm_at:
        return 0, 0
    G = len(cfg.slstm_at)
    period = cfg.n_layers // G
    assert tuple(cfg.slstm_at) == tuple(range(0, cfg.n_layers, period)), \
        f"slstm_at must be periodic, got {cfg.slstm_at}"
    return G, period


def padded_vocab(cfg, shards: int = 16) -> int:
    return -(-cfg.vocab // shards) * shards


# ===================================================================== init

def _init_tx_layer(key, cfg, shards):
    ks = jax.random.split(key, 3)
    p = {"attn": init_attention(ks[0], cfg, shards),
         "ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def init_params(cfg, key, shards: int = 16):
    kemb, klay, kextra, kout = jax.random.split(key, 4)
    V = padded_vocab(cfg, shards)
    d = cfg.d_model
    params = {
        "embed": (jax.random.normal(kemb, (V, d)) * d ** -0.5
                  ).astype(jnp.bfloat16),
        "unembed": (jax.random.normal(kout, (d, V)) * d ** -0.5
                    ).astype(jnp.bfloat16),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        keys = jax.random.split(klay, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_tx_layer(k, cfg, shards))(keys)
    elif cfg.family == "hybrid":
        keys = jax.random.split(klay, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: ssm_mod.init_mamba(k, cfg))(keys)
        ks = jax.random.split(kextra, 2)
        params["shared_attn"] = {
            "attn": init_attention(ks[0], cfg, shards),
            "mlp": init_mlp(ks[1], cfg),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32)}
    elif cfg.family == "ssm":
        # periodic structure: G groups of [sLSTM, (period-1) x mLSTM]
        # (slstm_at must be (0, p, 2p, ...)); pure-mLSTM stack if empty.
        G, period = xlstm_groups(cfg)
        keys = jax.random.split(klay, cfg.n_layers)

        def one_m(k):
            km, kn = jax.random.split(k)
            return {"cell": xlstm_mod.init_mlstm(km, cfg),
                    "ln": jnp.ones((d,), jnp.float32)}

        if G:
            def one_s(k):
                return {"cell": xlstm_mod.init_slstm(k, cfg),
                        "ln": jnp.ones((d,), jnp.float32)}
            skeys = keys[::period]
            mkeys = jnp.stack([jnp.stack([keys[g * period + j]
                                          for j in range(1, period)])
                               for g in range(G)])
            params["layers"] = {
                "slstm": jax.vmap(one_s)(jnp.stack(list(skeys))),
                "mlstm": jax.vmap(jax.vmap(one_m))(mkeys)}
        else:
            params["layers"] = {"mlstm": jax.vmap(one_m)(keys)}
    if cfg.frontend == "vision":
        params["frontend"] = {"proj": (jax.random.normal(kextra, (d, d))
                                       * d ** -0.5).astype(jnp.bfloat16)}
    return params


# ================================================================== embed

def embed_inputs(params, batch, cfg, shd):
    """Returns x (B, S, d).  VLM: [projected patches ; token embeds]."""
    tok = batch["tokens"]
    x = jnp.take(params["embed"], tok, axis=0)
    x = shd.constrain(x, "batch", "seq", None)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = jnp.einsum("bpd,de->bpe", batch["patch_embeds"].astype(x.dtype),
                        params["frontend"]["proj"])
        x = jnp.concatenate([pe, x], axis=1)
        x = shd.constrain(x, "batch", "seq", None)
    return x


# ============================================================ train stacks

def _tx_layer_fwd(lp, h, cfg, shd):
    h = shd.constrain(h, "batch", "seq_res", None)
    a, _ = attention_block(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                           cfg, shd)
    h = h + shd.constrain(a, "batch", "seq_res", None)
    hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        ff, aux = moe_mod.moe_block(lp["moe"], hn, cfg, shd)
    else:
        ff, aux = mlp_block(lp["mlp"], hn, cfg, shd), (0.0, 0.0)
    h = h + shd.constrain(ff, "batch", "seq_res", None)
    return shd.constrain(h, "batch", "seq_res", None), aux


def backbone(params, x, cfg, shd):
    """x (B,S,d) -> (final hidden, aux losses)."""
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(carry, lp):
            h, lb, z = carry
            h2, (alb, az) = _tx_layer_fwd(lp, h, cfg, shd)
            return (h2, lb + alb, z + az), ()
        if cfg.remat:
            body = jax.checkpoint(body, policy=remat_policy(cfg),
                                  prevent_cse=False)
        (x, lb, z), _ = lax.scan(body, (x, 0.0, 0.0), params["layers"])
        aux = (lb / cfg.n_layers, z / cfg.n_layers)
    elif cfg.family == "hybrid":
        k = cfg.attn_every
        ng = cfg.n_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
        sa = params["shared_attn"]

        def group(carry, gp):
            h = carry

            def mamba_one(hh, lp):
                o, _ = ssm_mod.mamba_block(lp, hh, cfg, shd)
                return hh + o, ()
            if cfg.remat:
                mamba_one = jax.checkpoint(mamba_one, policy=remat_policy(cfg),
                                           prevent_cse=False)
            h, _ = lax.scan(mamba_one, h, gp)
            a, _ = attention_block(sa["attn"],
                                   rms_norm(h, sa["ln1"], cfg.norm_eps),
                                   cfg, shd)
            h = h + a
            h = h + mlp_block(sa["mlp"],
                              rms_norm(h, sa["ln2"], cfg.norm_eps), cfg, shd)
            return h, ()
        if cfg.remat:
            group = jax.checkpoint(group, policy=remat_policy(cfg),
                                   prevent_cse=False)
        x, _ = lax.scan(group, x, grouped)
        aux = (0.0, 0.0)
    else:                                    # ssm / xlstm
        G, period = xlstm_groups(cfg)

        def m_one(h, lp):
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            return h + xlstm_mod.mlstm_parallel(lp["cell"], hn, cfg, shd), ()
        if cfg.remat:
            m_one = jax.checkpoint(m_one, policy=remat_policy(cfg),
                                   prevent_cse=False)
        if G:
            def group(h, gp):
                hn = rms_norm(h, gp["slstm"]["ln"], cfg.norm_eps)
                o, _ = xlstm_mod.slstm_block(gp["slstm"]["cell"], hn, cfg,
                                             shd)
                h = h + o
                h, _ = lax.scan(m_one, h, gp["mlstm"])
                return h, ()
            if cfg.remat:
                group = jax.checkpoint(group, policy=remat_policy(cfg),
                                       prevent_cse=False)
            x, _ = lax.scan(group, x, params["layers"])
        else:
            x, _ = lax.scan(m_one, x, params["layers"]["mlstm"])
        aux = (0.0, 0.0)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


# ==================================================================== loss

def lm_loss(params, x, labels, cfg, shd, chunk: int = 512):
    """Chunked cross-entropy: logits materialize only for `chunk` positions
    at a time (vocab stays TP-sharded; padded vocab masked with -1e9)."""
    B, S, d = x.shape
    V = params["unembed"].shape[1]
    chunk = min(chunk, S)
    while S % chunk:           # largest chunk <= requested that divides S
        chunk -= 1
    nc = S // chunk
    pad_mask = (jnp.arange(V) >= cfg.vocab) * (-1e9)

    def body(carry, ci):
        nll, cnt = carry
        xc = lax.dynamic_slice_in_dim(x, ci * chunk, chunk, 1)
        lc = lax.dynamic_slice_in_dim(labels, ci * chunk, chunk, 1)
        logits = jnp.einsum("bsd,dv->bsv", xc,
                            params["unembed"]).astype(jnp.float32)
        logits = logits + pad_mask
        logits = shd.constrain(logits, "batch", "seq", "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(jnp.clip(lc, 0, V - 1), V, dtype=jnp.bfloat16)
        gold = jnp.einsum("bsv,bsv->bs", logits.astype(jnp.bfloat16),
                          oh).astype(jnp.float32)
        valid = (lc >= 0).astype(jnp.float32)
        nll = nll + jnp.sum((logz - gold) * valid)
        return (nll, cnt + jnp.sum(valid)), ()

    (nll, cnt), _ = lax.scan(jax.checkpoint(body, prevent_cse=False),
                             (0.0, 0.0), jnp.arange(nc))
    return nll / jnp.maximum(cnt, 1.0)


def train_loss(params, batch, cfg, shd):
    x = embed_inputs(params, batch, cfg, shd)
    h, (lb, z) = backbone(params, x, cfg, shd)
    h = shd.constrain(h, "batch", "seq", None)   # regather seq for loss
    if cfg.frontend == "vision":
        h = h[:, -batch["labels"].shape[1]:]    # loss on text positions
    loss = lm_loss(params, h, batch["labels"], cfg, shd)
    return loss + 0.01 * lb + 1e-3 * z, {"ce": loss, "lb": lb, "z": z}


# ================================================================= serving

def init_cache(cfg, B: int, T: int, dtype=jnp.bfloat16):
    """Decode cache pytree (use jax.eval_shape for dry-run specs)."""
    KV, dh, L = cfg.n_kv_heads, cfg.dh, cfg.n_layers
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if cfg.kv_quant:
            return {"k": jnp.zeros((L, B, T, KV, dh), jnp.int8),
                    "v": jnp.zeros((L, B, T, KV, dh), jnp.int8),
                    "ks": jnp.zeros((L, B, T, KV), jnp.float32),
                    "vs": jnp.zeros((L, B, T, KV), jnp.float32)}
        return {"k": jnp.zeros((L, B, T, KV, dh), dtype),
                "v": jnp.zeros((L, B, T, KV, dh), dtype)}
    if cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        return {"conv": jnp.zeros((L, B, 3, cfg.d_inner), dtype),
                "ssd": jnp.zeros((L, B, cfg.n_ssm_heads, cfg.ssm_state,
                                  cfg.ssm_headdim), jnp.float32),
                "attn_k": jnp.zeros((ng, B, T, KV, dh), dtype),
                "attn_v": jnp.zeros((ng, B, T, KV, dh), dtype)}
    # ssm / xlstm: recurrent states, O(1) in T
    G, period = xlstm_groups(cfg)
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H

    def mstates(*lead):
        return {"C": jnp.zeros(lead + (B, H, dh, dh), jnp.float32),
                "n": jnp.zeros(lead + (B, H, dh), jnp.float32),
                "m": jnp.full(lead + (B, H), -1e30, jnp.float32)}
    if G:
        z = jnp.zeros((G, B, d), jnp.float32)
        return {"slstm": (z, z + 1e-6, z, z - 1e30),
                "mlstm": mstates(G, period - 1)}
    return {"mlstm": mstates(cfg.n_layers)}


def decode_step(params, cache, batch, cfg, shd):
    """One-token decode against a T-long cache.  batch: tokens (B,1),
    pos (B,).  Returns (new_cache, logits (B, V))."""
    tok, pos = batch["tokens"], batch["pos"]
    x = jnp.take(params["embed"], tok, axis=0)       # (B,1,d)
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        quant = "ks" in cache

        def body(h, packed):
            if quant:
                lp, ck, cv, cks, cvs = packed
                lc = {"k": ck, "v": cv, "ks": cks, "vs": cvs}
            else:
                lp, ck, cv = packed
                lc = {"k": ck, "v": cv}
            a, nc = attention_block(lp["attn"],
                                    rms_norm(h, lp["ln1"], cfg.norm_eps),
                                    cfg, shd, pos=pos, cache=lc)
            h = h + a
            hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                ff, _ = moe_mod.moe_block(lp["moe"], hn, cfg, shd)
            else:
                ff = mlp_block(lp["mlp"], hn, cfg, shd)
            out = (tuple(nc[x_] for x_ in ("k", "v", "ks", "vs"))
                   if quant else (nc["k"], nc["v"]))
            return h + ff, out
        if quant:
            xs = (params["layers"], cache["k"], cache["v"], cache["ks"],
                  cache["vs"])
            x, (nk, nv, nks, nvs) = lax.scan(body, x, xs)
            new_cache = {"k": nk, "v": nv, "ks": nks, "vs": nvs}
        else:
            x, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
            new_cache = {"k": nk, "v": nv}
    elif cfg.family == "hybrid":
        k = cfg.attn_every
        ng = cfg.n_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
        gconv = cache["conv"].reshape((ng, k) + cache["conv"].shape[1:])
        gssd = cache["ssd"].reshape((ng, k) + cache["ssd"].shape[1:])
        sa = params["shared_attn"]

        def group(h, packed):
            gp, cv, sd, ak, av = packed

            def one(hh, inner):
                lp, c1, s1 = inner
                o, ns = ssm_mod.mamba_block(lp, hh, cfg, shd,
                                            state={"conv": c1, "ssd": s1})
                return hh + o, (ns["conv"], ns["ssd"])
            h, (nc1, ns1) = lax.scan(one, h, (gp, cv, sd))
            a, nca = attention_block(sa["attn"],
                                     rms_norm(h, sa["ln1"], cfg.norm_eps),
                                     cfg, shd, pos=pos,
                                     cache={"k": ak, "v": av})
            h = h + a
            h = h + mlp_block(sa["mlp"],
                              rms_norm(h, sa["ln2"], cfg.norm_eps), cfg, shd)
            return h, (nc1, ns1, nca["k"], nca["v"])
        x, (nconv, nssd, nak, nav) = lax.scan(
            group, x, (grouped, gconv, gssd, cache["attn_k"],
                       cache["attn_v"]))
        new_cache = {"conv": nconv.reshape(cache["conv"].shape),
                     "ssd": nssd.reshape(cache["ssd"].shape),
                     "attn_k": nak, "attn_v": nav}
    else:                                            # xlstm
        G, period = xlstm_groups(cfg)

        def m_one(h, packed):
            lp, st = packed
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            o, ns = xlstm_mod.mlstm_decode(lp["cell"], hn, cfg, st)
            return h + o, ns
        if G:
            def group(h, packed):
                gp, s_st, m_st = packed
                hn = rms_norm(h, gp["slstm"]["ln"], cfg.norm_eps)
                s2 = xlstm_mod._slstm_cell(gp["slstm"]["cell"], hn[:, 0],
                                           s_st, cfg)
                h_out = rms_norm(s2[2][:, None, :].astype(h.dtype),
                                 gp["slstm"]["cell"]["norm_h"], cfg.norm_eps)
                h = h + jnp.einsum("bsd,de->bse", h_out,
                                   gp["slstm"]["cell"]["w_out"])
                h, nm = lax.scan(m_one, h, (gp["mlstm"], m_st))
                return h, (s2, nm)
            x, (ns, nm) = lax.scan(group, x,
                                   (params["layers"], cache["slstm"],
                                    cache["mlstm"]))
            new_cache = {"slstm": ns, "mlstm": nm}
        else:
            x, nm = lax.scan(m_one, x,
                             (params["layers"]["mlstm"], cache["mlstm"]))
            new_cache = {"mlstm": nm}
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    V = params["unembed"].shape[1]
    logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"])[:, 0]
    logits = logits + (jnp.arange(V) >= cfg.vocab) * (-1e9)
    return new_cache, logits


def prefill(params, batch, cfg, shd, cache_len: int | None = None):
    """Process a full prompt, filling a decode cache; returns
    (cache, last-position logits)."""
    tok = batch["tokens"]
    B = tok.shape[0]
    x = embed_inputs(params, batch, cfg, shd)
    S = x.shape[1]
    T = cache_len or S
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(h, lp):
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            empty = {"k": jnp.zeros((B, T, cfg.n_kv_heads, cfg.dh), h.dtype),
                     "v": jnp.zeros((B, T, cfg.n_kv_heads, cfg.dh), h.dtype)}
            if cfg.kv_quant:
                empty["ks"] = jnp.zeros((B, T, cfg.n_kv_heads), jnp.float32)
                empty["vs"] = jnp.zeros((B, T, cfg.n_kv_heads), jnp.float32)
            a, nc = attention_block(lp["attn"], hn, cfg, shd, cache=empty)
            h = h + a
            hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                ff, _ = moe_mod.moe_block(lp["moe"], hn, cfg, shd)
            else:
                ff = mlp_block(lp["mlp"], hn, cfg, shd)
            out = (tuple(nc[x_] for x_ in ("k", "v", "ks", "vs"))
                   if cfg.kv_quant else (nc["k"], nc["v"]))
            return h + ff, out
        if cfg.remat:
            body = jax.checkpoint(body, policy=remat_policy(cfg),
                                  prevent_cse=False)
        if cfg.kv_quant:
            x, (nk, nv, nks, nvs) = lax.scan(body, x, params["layers"])
            cache = {"k": nk, "v": nv, "ks": nks, "vs": nvs}
        else:
            x, (nk, nv) = lax.scan(body, x, params["layers"])
            cache = {"k": nk, "v": nv}
    elif cfg.family == "hybrid":
        k = cfg.attn_every
        ng = cfg.n_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
        sa = params["shared_attn"]

        def group(h, gp):
            def one(hh, lp):
                o, ns = ssm_mod.mamba_block(lp, hh, cfg, shd)
                return hh + o, (ns["conv"], ns["ssd"])
            h, (ncv, nsd) = lax.scan(one, h, gp)
            hn = rms_norm(h, sa["ln1"], cfg.norm_eps)
            a, nca = attention_block(
                sa["attn"], hn, cfg, shd,
                cache={"k": jnp.zeros((B, T, cfg.n_kv_heads, cfg.dh),
                                      h.dtype),
                       "v": jnp.zeros((B, T, cfg.n_kv_heads, cfg.dh),
                                      h.dtype)})
            h = h + a
            h = h + mlp_block(sa["mlp"],
                              rms_norm(h, sa["ln2"], cfg.norm_eps), cfg, shd)
            return h, (ncv, nsd, nca["k"], nca["v"])
        if cfg.remat:
            group = jax.checkpoint(group, policy=remat_policy(cfg),
                                   prevent_cse=False)
        x, (nconv, nssd, nak, nav) = lax.scan(group, x, grouped)
        cache = {"conv": nconv.reshape((cfg.n_layers,) + nconv.shape[2:]),
                 "ssd": nssd.reshape((cfg.n_layers,) + nssd.shape[2:]),
                 "attn_k": nak, "attn_v": nav}
    else:                                            # xlstm
        G, period = xlstm_groups(cfg)

        def m_one(h, lp):
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            o = xlstm_mod.mlstm_parallel(lp["cell"], hn, cfg, shd)
            st = xlstm_mod.mlstm_final_state(lp["cell"], hn, cfg)
            return h + o, st
        if cfg.remat:
            m_one = jax.checkpoint(m_one, policy=remat_policy(cfg),
                                   prevent_cse=False)
        if G:
            def group(h, gp):
                hn = rms_norm(h, gp["slstm"]["ln"], cfg.norm_eps)
                o, s_st = xlstm_mod.slstm_block(gp["slstm"]["cell"], hn,
                                                cfg, shd)
                h = h + o
                h, m_st = lax.scan(m_one, h, gp["mlstm"])
                return h, (s_st, m_st)
            if cfg.remat:
                group = jax.checkpoint(group, policy=remat_policy(cfg),
                                       prevent_cse=False)
            x, (ns, nm) = lax.scan(group, x, params["layers"])
            cache = {"slstm": ns, "mlstm": nm}
        else:
            x, nm = lax.scan(m_one, x, params["layers"]["mlstm"])
            cache = {"mlstm": nm}
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    V = params["unembed"].shape[1]
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["unembed"])
    logits = logits + (jnp.arange(V) >= cfg.vocab) * (-1e9)
    return cache, logits
