"""Mixture-of-Experts layer (olmoe, deepseek-moe): top-k router, shared +
routed experts, expert parallelism over the 'model' mesh axis.

Two dispatch implementations (cfg.moe_impl):

  * 'gather' (default, production path) — shard_map over the mesh: each
    model shard owns E/tp experts; activations are replicated across
    'model' at the MoE boundary, so dispatch is a LOCAL sort + gather into
    per-expert capacity buffers (zero dispatch-matmul FLOPs), expert GEMMs
    are local, and the combine is a single psum over 'model'.  This is the
    einsum-free analogue of all-to-all EP: the token payload crosses the
    ICI exactly once (in the psum).

  * 'onehot' — classic capacity one-hot einsum dispatch (Mesh-TF/GShard
    style).  Kept as the paper-faithful-baseline-style reference and for
    small configs/tests; its dispatch einsums burn T*E*C*d MACs, which the
    roofline analysis exposes (see EXPERIMENTS.md §Perf).

Both produce identical outputs up to capacity-drop tie-breaking; tests
compare them on small shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def router_probs(x, w_router):
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def aux_losses(probs, top_idx, n_experts: int):
    """Load-balance loss (Switch) + router z-loss."""
    T, k = top_idx.shape
    me = jnp.mean(probs, axis=0)                          # (E,)
    ce = jnp.zeros((n_experts,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0 / (T * k))
    lb = n_experts * jnp.sum(me * ce)
    z = jnp.mean(jnp.log(jnp.sum(jnp.exp(
        jnp.clip(probs, 1e-9, 1.0)), axis=-1)) ** 2)
    return lb, z


def _expert_ffn(h_in, w_in, w_gate, w_out, act: str):
    """(E, C, d) x (E, d, f) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", h_in, w_in)
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", h_in, w_gate)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def moe_gather_local(x, p, cfg, *, e_start, e_local, capacity, axis_name):
    """Local shard body (inside shard_map): x (T, d) is this data-shard's
    tokens, replicated across 'model'; this model shard computes its
    e_local experts and psums the combine.

    Memory discipline: the only (expert, capacity, d) tensor built is the
    local expert input buffer — the (T*k, d) gathered view never exists.
    For each local expert slot (e, c) we compute which *sorted routed
    token* fills it (slot-inverse indexing) and gather exactly E_local*C
    rows."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity
    probs, _ = router_probs(x, p["router"])               # (T, E) replicated
    top_p, top_i = lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)                            # (T*k,)
    order = jnp.argsort(flat_e)                           # stable
    se = flat_e[order]
    st = order // k                                       # token of sorted slot
    sp = top_p.reshape(-1)[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts                  # exclusive prefix
    eids = e_start + jnp.arange(e_local)
    src = starts[eids][:, None] + jnp.arange(C)[None, :]  # (e_local, C)
    valid = jnp.arange(C)[None, :] < jnp.minimum(counts[eids], C)[:, None]
    src = jnp.clip(src, 0, T * k - 1)
    tok = st[src]                                         # (e_local, C)
    gate = sp[src] * valid                                # (e_local, C)
    buf = x[tok] * valid[..., None].astype(x.dtype)       # (e_local, C, d)
    y = _expert_ffn(buf, p["w_in"], p.get("w_gate"), p["w_out"], cfg.act)
    contrib = y * gate[..., None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok.reshape(-1)].add(
        contrib.reshape(-1, d))
    out = lax.psum(out, axis_name) if axis_name else out  # combine over EP
    lb, z = aux_losses(probs, top_i, E)
    return out, lb, z


def moe_onehot(x, p, cfg, *, capacity):
    """Reference one-hot dispatch (per data shard, experts model-sharded by
    GSPMD from the weight sharding).  x: (T, d)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    probs, _ = router_probs(x, p["router"])
    top_p, top_i = lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.float32)      # (T, k, E)
    # capacity positions per expert, k-slot priority order
    pos = (jnp.cumsum(oh.reshape(T * k, E), axis=0) - 1.0).reshape(T, k, E)
    keep = (pos < capacity) * oh
    pos = jnp.clip(pos, 0, capacity - 1).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # (T,k,E,C)
    disp = (keep[..., None] * pos_oh).sum(1)              # (T, E, C)
    comb = (keep * top_p[..., None])[..., None] * pos_oh  # (T,k,E,C)
    comb = comb.sum(1)                                    # (T, E, C)
    h_in = jnp.einsum("tec,td->ecd", disp.astype(x.dtype), x)
    y = _expert_ffn(h_in, p["w_in"], p.get("w_gate"), p["w_out"], cfg.act)
    out = jnp.einsum("tec,ecd->td", comb.astype(x.dtype), y)
    lb, z = aux_losses(probs, top_i, E)
    return out, lb, z


def moe_block(p, x, cfg, shd):
    """x (B, S, d) -> (B, S, d) plus aux losses via shd context.

    Shared experts (deepseek) run as a dense MLP on every token, TP-sharded
    like a regular FFN; routed experts are EP-sharded.
    """
    B, S, d = x.shape
    T = B * S
    cap = int(cfg.capacity_factor * T * cfg.top_k / cfg.n_experts /
              max(1, shd.dp_size))
    cap = max(cap, cfg.top_k)
    xt = x.reshape(T, d)

    if cfg.moe_impl == "gather" and shd.mesh is not None:
        out2, lb, z = shd.moe_shard_map(
            functools.partial(moe_gather_local, cfg=cfg, capacity=cap),
            xt, p)
    elif cfg.moe_impl == "gather":
        out2, lb, z = moe_gather_local(
            xt, p, cfg, e_start=0, e_local=cfg.n_experts, capacity=cap,
            axis_name=None)
    else:
        out2, lb, z = moe_onehot(xt, p, cfg, capacity=cap)
    out = out2.reshape(B, S, d)
    if cfg.n_shared_experts:
        h = jnp.einsum("bsd,df->bsf", x, p["shared_w_in"])
        g = jnp.einsum("bsd,df->bsf", x, p["shared_w_gate"])
        h = jax.nn.silu(g) * h
        h = shd.constrain(h, "batch", "seq", "ff")
        out = out + jnp.einsum("bsf,fd->bsd", h, p["shared_w_out"])
    return out, (lb, z)


def init_moe(key, cfg):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    p = {"router": (jax.random.normal(ks[0], (d, E)) * std
                    ).astype(jnp.float32),
         "w_in": (jax.random.normal(ks[1], (E, d, f)) * std
                  ).astype(jnp.bfloat16),
         "w_gate": (jax.random.normal(ks[2], (E, d, f)) * std
                    ).astype(jnp.bfloat16),
         "w_out": (jax.random.normal(ks[3], (E, f, d)) * f ** -0.5
                   ).astype(jnp.bfloat16)}
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["shared_w_in"] = (jax.random.normal(ks[4], (d, fs)) * std
                            ).astype(jnp.bfloat16)
        p["shared_w_gate"] = (jax.random.normal(ks[5], (d, fs)) * std
                              ).astype(jnp.bfloat16)
        p["shared_w_out"] = (jax.random.normal(ks[6], (fs, d)) * fs ** -0.5
                             ).astype(jnp.bfloat16)
    return p
