"""Mamba2 block (zamba2) — chunked SSD (state-space duality) algorithm.

Recurrence per head h (state N x P):   H_t = a_t H_{t-1} + B_t (dt_t x_t)^T
readout:                               y_t = C_t^T H_t + D x_t

The chunked algorithm splits the sequence into chunks of `ssd_chunk`:
  * intra-chunk: a masked quadratic (attention-like) term using in-chunk
    decay products exp(cum_i - cum_j);
  * inter-chunk: per-chunk boundary states carried by a lax.scan (the only
    sequential dependency — O(S/chunk) steps).

Heads shard over 'model' (zamba2: 80 heads / 16 = 5); B/C are group-shared
(n_groups=1) and replicated.  Decode keeps (conv tail, H state) — O(1) in
context length, which is why zamba2 runs the long_500k shape.

Simplifications vs the reference CUDA kernels (documented in DESIGN.md):
depthwise conv applied to x only (not B/C), n_groups=1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def depthwise_conv(x, w, conv_state=None):
    """x (B, S, D), w (K, D) causal depthwise conv.
    Returns (y, new_state) where state is the trailing K-1 inputs."""
    K = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return y, new_state


def ssd_chunked(xin, la, Bm, Cm, *, chunk: int = 128, h0=None):
    """xin (B,S,H,P) = dt*x; la (B,S,H) = log decay; Bm/Cm (B,S,N).
    Returns (y (B,S,H,P), h_last (B,H,N,P))."""
    B, S, H, P = xin.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    nc = S // c
    xin_ = xin.reshape(B, nc, c, H, P)
    la_ = la.reshape(B, nc, c, H).astype(jnp.float32)
    Bm_ = Bm.reshape(B, nc, c, N).astype(jnp.float32)
    Cm_ = Cm.reshape(B, nc, c, N).astype(jnp.float32)
    cum = jnp.cumsum(la_, axis=2)                       # (B,nc,c,H)

    # ---- intra-chunk (quadratic within chunk)
    att = jnp.einsum("bgin,bgjn->bgij", Cm_, Bm_)       # (B,nc,c,c)
    # contribution of input j to output i >= j decays by prod_{t=j+1..i} a_t
    # = exp(cum_i - cum_j);  i == j contributes undecayed (exp(0)).
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    w = jnp.exp(dec)                                    # (B,nc,c,c,H)
    y_intra = jnp.einsum("bgij,bgijh,bgjhp->bgihp",
                         att, w, xin_.astype(jnp.float32))

    # ---- chunk boundary states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (B,nc,c,H)
    S_chunk = jnp.einsum("bgjn,bgjh,bgjhp->bghnp",
                         Bm_, decay_to_end, xin_.astype(jnp.float32))

    # ---- inter-chunk scan
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,nc,H)

    def step(h, inputs):
        s_c, dec_c = inputs                             # (B,H,N,P), (B,H)
        h_new = h * dec_c[:, :, None, None] + s_c
        return h_new, h                                 # emit state BEFORE chunk

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_last, h_prevs = lax.scan(step,
                               h0,
                               (jnp.moveaxis(S_chunk, 1, 0),
                                jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)               # (B,nc,H,N,P)
    y_inter = jnp.einsum("bgin,bghnp,bgih->bgihp",
                         Cm_, h_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(xin.dtype), h_last


def mamba_block(p, x, cfg, shd, state=None):
    """x (B, S, d) -> (B, S, d).  state: None (train/prefill from scratch)
    or {'conv': (B,K-1,di), 'ssd': (B,H,N,P)} for decode."""
    B, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_headdim
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xr = jnp.einsum("bsd,de->bse", x, p["in_x"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["in_dt"])
    xr = shd.constrain(xr, "batch", "seq", "dinner")
    z = shd.constrain(z, "batch", "seq", "dinner")
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = depthwise_conv(xr, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)
    la = dt * A                                                   # log decay
    xh = xc.reshape(B, S, H, P)
    xin = xh * dt[..., None].astype(xh.dtype)
    h0 = state["ssd"] if state is not None else None
    y, h_last = ssd_chunked(xin, la, Bm, Cm,
                            chunk=min(128, S), h0=h0)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = {"conv": new_conv, "ssd": h_last}
    return out, new_state


def init_mamba(key, cfg):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    return {
        "in_z": (jax.random.normal(ks[0], (d, di)) * std).astype(jnp.bfloat16),
        "in_x": (jax.random.normal(ks[1], (d, di)) * std).astype(jnp.bfloat16),
        "in_B": (jax.random.normal(ks[2], (d, N)) * std).astype(jnp.bfloat16),
        "in_C": (jax.random.normal(ks[3], (d, N)) * std).astype(jnp.bfloat16),
        "in_dt": (jax.random.normal(ks[4], (d, H)) * std).astype(jnp.bfloat16),
        "conv_w": (jax.random.normal(ks[5], (4, di)) * 0.5).astype(jnp.bfloat16),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.bfloat16),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[6], (di, d)) * di ** -0.5
                     ).astype(jnp.bfloat16),
    }
