"""xLSTM blocks (xlstm-125m): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, recurrent scan).

mLSTM trains in its parallel form — the same online-softmax block machinery
as flash attention, with the additive decay bias D_ij = F_i - F_j + i_j
(F = cumulative log-sigmoid forget gates) and the mLSTM denominator
max(|l|, exp(-m)) (layers.flash_attention(decay=..., mlstm_norm=True)).
Decode uses the recurrent matrix-state update: O(1) state per token, which
is what makes the long_500k shape runnable.

sLSTM has no parallel form (its forget gate depends on the previous hidden
state), so it runs as a lax.scan over time with exponential-gate
stabilizer state m.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import flash_attention, rms_norm


# ------------------------------------------------------------------ mLSTM

def mlstm_parallel(p, x, cfg, shd):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, H, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, H, dh)
    ig = jnp.einsum("bsd,dh->bsh", x, p["wi"]).astype(jnp.float32)
    fg = jnp.einsum("bsd,dh->bsh", x, p["wf"]).astype(jnp.float32)
    F = jnp.cumsum(jax.nn.log_sigmoid(fg), axis=1)        # (B,S,H)
    h = flash_attention(q, k, v, causal=True, decay=(F, ig),
                        mlstm_norm=True,
                        softmax_scale=1.0 / math.sqrt(dh))
    h = rms_norm(h.reshape(B, S, d), p["norm_h"], cfg.norm_eps)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wo_gate"]))
    return jnp.einsum("bse,ed->bsd", h * o.astype(h.dtype), p["w_out"])


def mlstm_decode(p, x, cfg, state):
    """x (B,1,d); state {'C': (B,H,dh,dh), 'n': (B,H,dh), 'm': (B,H)}."""
    B, _, d = x.shape
    H = cfg.n_heads
    dh = d // H
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, H, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, H, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, H, dh)
    ig = jnp.einsum("bsd,dh->bh", x, p["wi"]).astype(jnp.float32)
    fg = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bh", x, p["wf"]).astype(jnp.float32))
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(fg + m, ig)
    a = jnp.exp(fg + m - m_new)[..., None]                # (B,H,1)
    b = jnp.exp(ig - m_new)[..., None]
    kf = k.astype(jnp.float32) / math.sqrt(dh)
    C_new = C * a[..., None] + b[..., None] * (
        kf[..., :, None] * v.astype(jnp.float32)[..., None, :])
    n_new = n * a + b * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)),
                      jnp.exp(-m_new)) + 1e-6
    h = (num / den[..., None]).reshape(B, 1, d).astype(x.dtype)
    h = rms_norm(h, p["norm_h"], cfg.norm_eps)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wo_gate"]))
    out = jnp.einsum("bse,ed->bsd", h * o.astype(h.dtype), p["w_out"])
    return out, {"C": C_new, "n": n_new, "m": m_new}


def mlstm_final_state(p, x, cfg):
    """Exact recurrent state after a parallel-form prefill of x (B,S,d):
    C_S = sum_j exp(F_S - F_j + i_j - m*) k_j v_j^T (log-weighted sum),
    n_S likewise, m = m*.  One einsum — used for prefill->decode handoff."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, H, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, H, dh)
    ig = jnp.einsum("bsd,dh->bsh", x, p["wi"]).astype(jnp.float32)
    fg = jnp.einsum("bsd,dh->bsh", x, p["wf"]).astype(jnp.float32)
    F = jnp.cumsum(jax.nn.log_sigmoid(fg), axis=1)
    w = F[:, -1:, :] - F + ig                             # (B,S,H)
    m = w.max(axis=1)                                     # (B,H)
    a = jnp.exp(w - m[:, None, :])                        # (B,S,H)
    kf = k.astype(jnp.float32) / math.sqrt(dh)
    C = jnp.einsum("bsh,bshd,bshe->bhde", a, kf, v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshd->bhd", a, kf)
    return {"C": C, "n": n, "m": m}


def init_mlstm(key, cfg):
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {"wq": (jax.random.normal(ks[0], (d, d)) * std).astype(jnp.bfloat16),
            "wk": (jax.random.normal(ks[1], (d, d)) * std).astype(jnp.bfloat16),
            "wv": (jax.random.normal(ks[2], (d, d)) * std).astype(jnp.bfloat16),
            "wi": (jax.random.normal(ks[3], (d, H)) * std).astype(jnp.bfloat16),
            "wf": (jax.random.normal(ks[4], (d, H)) * std).astype(jnp.bfloat16),
            "wo_gate": (jax.random.normal(ks[5], (d, d)) * std
                        ).astype(jnp.bfloat16),
            "w_out": (jax.random.normal(ks[0], (d, d)) * std
                      ).astype(jnp.bfloat16),
            "norm_h": jnp.ones((d,), jnp.float32)}


def init_mlstm_state(cfg, B):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return {"C": jnp.zeros((B, H, dh, dh), jnp.float32),
            "n": jnp.zeros((B, H, dh), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32)}


# ------------------------------------------------------------------ sLSTM

def _slstm_cell(p, x_t, state, cfg):
    """One step.  x_t (B, d); state tuple (c, n, h, m) each (B, d)."""
    c, n, h, m = state
    B, d = x_t.shape
    H = cfg.n_heads
    dh = d // H
    hh = h.reshape(B, H, dh)

    def gate(wx, r):
        rec = jnp.einsum("bhd,hde->bhe", hh.astype(jnp.float32),
                         r.astype(jnp.float32)).reshape(B, d)
        return jnp.einsum("bd,de->be", x_t,
                          wx).astype(jnp.float32) + rec

    zi = jnp.tanh(gate(p["wz"], p["rz"]))
    ii = gate(p["wi"], p["ri"])
    ff = gate(p["wf"], p["rf"])
    oo = jax.nn.sigmoid(gate(p["wo"], p["ro"]))
    lf = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(lf + m, ii)
    i_e = jnp.exp(ii - m_new)
    f_e = jnp.exp(lf + m - m_new)
    c_new = f_e * c + i_e * zi
    n_new = jnp.maximum(f_e * n + i_e, jnp.exp(-m_new))
    h_new = oo * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def slstm_block(p, x, cfg, shd, state=None):
    """x (B, S, d) scan over time.  Returns (out, final_state)."""
    B, S, d = x.shape
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = (z, z + 1e-6, z, z - 1e30)

    def step(st, x_t):
        st2 = _slstm_cell(p, x_t, st, cfg)
        return st2, st2[2]                                # emit h

    state, hs = lax.scan(jax.checkpoint(step, prevent_cse=False),
                         state, jnp.moveaxis(x, 0, 1))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)           # (B,S,d)
    hs = rms_norm(hs, p["norm_h"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", hs, p["w_out"])
    return out, state


def init_slstm(key, cfg):
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 9)
    std = d ** -0.5
    p = {}
    for i, g in enumerate("zifo"):
        p[f"w{g}"] = (jax.random.normal(ks[i], (d, d)) * std
                      ).astype(jnp.bfloat16)
        p[f"r{g}"] = (jax.random.normal(ks[4 + i], (H, dh, dh)) * dh ** -0.5
                      ).astype(jnp.bfloat16)
    p["w_out"] = (jax.random.normal(ks[8], (d, d)) * std).astype(jnp.bfloat16)
    p["norm_h"] = jnp.ones((d,), jnp.float32)
    return p


def init_slstm_state(cfg, B):
    d = cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return (z, z + 1e-6, z, z - 1e30)
