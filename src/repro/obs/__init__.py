"""Unified observability layer: metrics, spans, events, exporters.

Stdlib-only (no intra-``repro`` imports), so every subsystem — storage
backends included — can depend on it without cycles.  One process-wide
:data:`REGISTRY` holds all instruments; ``REPRO_OBS=0`` in the
environment starts the process disabled, and :func:`enable` /
:func:`disable` flip it at runtime.  Disabled mode reduces every
record path to a flag check (gated <10% overhead by the
``obs-overhead`` CI job).

Typical use::

    from repro import obs

    with obs.trace("client.put", key=key) as sp:
        cluster.put(key, value)          # nested layer spans attach to sp
    obs.emit("myapp.thing", detail=42)
    snap = obs.snapshot(stores={"store": db.store.stats})
"""
from __future__ import annotations

from .events import EVENTS, EventLog, emit
from .export import prometheus_text, snapshot
from .metrics import REGISTRY, Counter, Gauge, Histogram, MetricsRegistry
from .trace import (Span, clear_recent_spans, current_span, monotonic,
                    recent_spans, trace)

__all__ = [
    "Counter",
    "EVENTS",
    "EventLog",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "Span",
    "clear_recent_spans",
    "counter",
    "current_span",
    "disable",
    "emit",
    "enable",
    "enabled",
    "gauge",
    "histogram",
    "inc",
    "monotonic",
    "observe",
    "prometheus_text",
    "recent_spans",
    "record_gc_pause",
    "record_gc_report",
    "reset",
    "set_gauge",
    "snapshot",
    "trace",
]


def enabled() -> bool:
    return REGISTRY.enabled


def enable() -> None:
    REGISTRY.enable()


def disable() -> None:
    REGISTRY.disable()


def reset() -> None:
    """Drop all instruments, events and span history (tests/benches)."""
    REGISTRY.reset()
    EVENTS.clear()
    clear_recent_spans()


def counter(name: str, labels: dict | None = None) -> Counter:
    return REGISTRY.counter(name, labels)


def gauge(name: str, labels: dict | None = None) -> Gauge:
    return REGISTRY.gauge(name, labels)


def histogram(name: str, labels: dict | None = None) -> Histogram:
    return REGISTRY.histogram(name, labels)


def inc(name: str, n: int = 1, labels: dict | None = None) -> None:
    """Bump a named counter (no-op when disabled)."""
    if REGISTRY.enabled:
        REGISTRY.counter(name, labels).inc(n)


def set_gauge(name: str, value, labels: dict | None = None) -> None:
    if REGISTRY.enabled:
        REGISTRY.gauge(name, labels).set(value)


def observe(name: str, seconds: float, labels: dict | None = None) -> None:
    """Record a duration into a named histogram (no-op when disabled)."""
    if REGISTRY.enabled:
        REGISTRY.histogram(name, labels).observe(seconds)


def record_gc_report(report) -> None:
    """File a ``GCReport`` (dataclass or dict) into bounded history."""
    if not REGISTRY.enabled:
        return
    if not isinstance(report, dict):
        import dataclasses
        report = dataclasses.asdict(report)
    REGISTRY.record_gc_report(report)


def record_gc_pause(phase: str, seconds: float, *, epoch: int = 0) -> None:
    REGISTRY.record_gc_pause(str(phase), seconds, epoch=epoch)
