"""Bounded structured event journal.

Lifecycle events — GC phase transitions, epoch folds, segment
compactions, tier demotions/promotions, audit findings and
quarantine/release, torn-tail truncations on reopen — land here as
small dicts in a ring buffer, optionally teed to a JSONL sink.  Every
emit also bumps the ``events_total{kind=...}`` counter in the registry
so event *rates* survive after the ring has wrapped.
"""
from __future__ import annotations

import json
import time
from collections import Counter as _TallyCounter
from collections import deque

from .metrics import REGISTRY
from .trace import _jsonable, monotonic

__all__ = ["EventLog", "EVENTS", "emit"]


class EventLog:
    """Ring buffer of structured events plus an optional JSONL sink."""

    def __init__(self, capacity: int = 1024, sink_path: str | None = None,
                 registry=None):
        self._ring: deque[dict] = deque(maxlen=capacity)
        self._counts: _TallyCounter[str] = _TallyCounter()
        self._sink = None
        self._reg = registry if registry is not None else REGISTRY
        if sink_path:
            self.open_sink(sink_path)

    def open_sink(self, path: str) -> None:
        self.close_sink()
        self._sink = open(path, "a", encoding="utf-8")

    def close_sink(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    def emit(self, kind: str, **attrs) -> None:
        if not self._reg.enabled:
            return
        # ``ts`` (wall clock) is for the JSONL sink and humans; ``mono_us``
        # shares the span clock (trace.monotonic), so events and span
        # timelines correlate — snapshot() exports the same clock's "now"
        # repro: allow(CONTRACT002): journal timestamps are wall-clock on
        # purpose so external logs can be correlated; ordering never uses
        # ts — it uses mono_us from the span clock
        ev = {"kind": kind, "ts": round(time.time(), 6),
              "mono_us": round(monotonic() * 1e6, 3)}
        for k, v in attrs.items():
            ev[k] = _jsonable(v)
        self._ring.append(ev)
        self._counts[kind] += 1
        self._reg.counter("events_total", {"kind": kind}).inc()
        if self._sink is not None:
            self._sink.write(json.dumps(ev, sort_keys=True) + "\n")
            self._sink.flush()

    def events(self, kind: str | None = None, limit: int = 0) -> list[dict]:
        out = [e for e in self._ring if kind is None or e["kind"] == kind]
        return out[-limit:] if limit else out

    def counts(self) -> dict[str, int]:
        return dict(self._counts)

    def clear(self) -> None:
        self._ring.clear()
        self._counts.clear()

    def __len__(self) -> int:
        return len(self._ring)


#: Process-wide journal — subsystems emit here via :func:`emit`.
EVENTS = EventLog()


def emit(kind: str, **attrs) -> None:
    """Emit a structured event into the global journal (no-op when
    observability is disabled)."""
    EVENTS.emit(kind, **attrs)
