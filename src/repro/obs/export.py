"""Exporters: JSON snapshot and Prometheus-style text dump.

``snapshot()`` merges the metrics registry (with histogram
percentiles), the event journal, GC report/pause history, recent span
trees, and any ``StoreStats`` the caller passes — pulled at snapshot
time, never pushed into registry counters, so a backend reopen that
*replays* its persisted stats can never double-count here.
"""
from __future__ import annotations

from .events import EVENTS
from .metrics import REGISTRY, Counter, Gauge
from .trace import monotonic, recent_spans

__all__ = ["snapshot", "prometheus_text"]


def snapshot(stores=None, extra=None, *, events_limit: int = 256) -> dict:
    """JSON-safe observability snapshot.

    ``stores``: optional mapping of name → object with ``as_dict()``
    (``StoreStats``).  ``extra``: dict merged into the top level
    (subsystem verbs like ``ForkBase.observe`` use it).
    """
    out = {
        "enabled": REGISTRY.enabled,
        # monotonic reference point (same clock as event ``mono_us`` and
        # span ``start_us``): consumers compute event/span ages against
        # this instead of wall time, immune to clock steps
        "now_us": round(monotonic() * 1e6, 3),
        "metrics": REGISTRY.as_dict(),
        "events": EVENTS.events(limit=events_limit),
        "event_counts": EVENTS.counts(),
        "gc": {
            "reports": list(REGISTRY.gc_reports),
            "slice_pauses": list(REGISTRY.gc_pauses),
        },
        "spans": [sp.as_dict() for sp in recent_spans()],
    }
    if stores:
        out["stores"] = {name: st.as_dict() for name, st in stores.items()}
    if extra:
        for k, v in extra.items():
            out[k] = v
    return out


def prometheus_text(stores=None) -> str:
    """Prometheus exposition-style dump of every registered instrument
    (plus optional ``StoreStats`` rendered as gauges)."""
    lines: list[str] = []
    seen_types: set[str] = set()

    def _type(name: str, kind: str) -> None:
        if name not in seen_types:
            seen_types.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for key, inst in REGISTRY.instruments():
        if isinstance(inst, Counter):
            _type(inst.name, "counter")
            lines.append(f"{key} {inst.value}")
        elif isinstance(inst, Gauge):
            _type(inst.name, "gauge")
            lines.append(f"{key} {inst.value}")
        else:  # Histogram -> summary-style quantiles
            _type(inst.name, "summary")
            base, brace, rest = key.partition("{")
            inner = rest[:-1] if brace else ""

            def q(quantile, value, _inner=inner, _base=base):
                lab = (f"{_inner},quantile=\"{quantile}\"" if _inner
                       else f"quantile=\"{quantile}\"")
                lines.append(f"{_base}{{{lab}}} {value}")

            q("0.5", inst.p50)
            q("0.99", inst.p99)
            q("1", inst.max_us)
            lines.append(f"{base}_count{'{' + inner + '}' if inner else ''} "
                         f"{inst.count}")
            lines.append(f"{base}_sum{'{' + inner + '}' if inner else ''} "
                         f"{round(inst.sum_us, 3)}")
    if stores:
        for sname, st in sorted(stores.items()):
            for field, v in st.as_dict().items():
                name = f"store_{field}"
                _type(name, "gauge")
                lines.append(f'{name}{{store="{sname}"}} {v}')
    return "\n".join(lines) + "\n"
