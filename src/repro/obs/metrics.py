"""Process-wide metrics registry: counters, gauges, latency histograms.

Stdlib-only by design — ``repro.storage`` and every other subsystem can
import this module without creating an import cycle.  All instruments
hang off one :class:`MetricsRegistry` (the module singleton
``REGISTRY``); a single ``enabled`` flag turns every record path into a
cheap no-op, which is what the ``obs-overhead`` CI gate measures.

Histograms use fixed power-of-two microsecond buckets (bucket *i* holds
samples in ``[2**(i-1), 2**i) µs``), so ``observe()`` is one
``bit_length()`` call and an increment — no allocation, no deps — while
still answering p50/p99/max questions well enough for pause and
latency attribution.
"""
from __future__ import annotations

import os
import threading
from collections import deque

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
]

# 40 buckets cover [1 µs, 2**39 µs ~= 6.4 days) — anything slower
# saturates the last bucket rather than raising.
_NBUCKETS = 40


def _render_key(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "labels", "value", "_reg")

    def __init__(self, name, labels, reg):
        self.name = name
        self.labels = labels
        self.value = 0
        self._reg = reg

    def inc(self, n: int = 1) -> None:
        if self._reg.enabled:
            self.value += n

    def as_value(self):
        return self.value


class Gauge:
    """Last-write-wins scalar (ints or floats)."""

    __slots__ = ("name", "labels", "value", "_reg")

    def __init__(self, name, labels, reg):
        self.name = name
        self.labels = labels
        self.value = 0
        self._reg = reg

    def set(self, v) -> None:
        if self._reg.enabled:
            self.value = v

    def inc(self, n=1) -> None:
        if self._reg.enabled:
            self.value += n

    def dec(self, n=1) -> None:
        if self._reg.enabled:
            self.value -= n

    def as_value(self):
        return self.value


class Histogram:
    """Fixed power-of-two µs-bucket latency histogram.

    ``observe()`` takes *seconds* (what ``perf_counter`` deltas give
    you) and buckets in microseconds.  Percentiles are answered at the
    bucket upper bound — coarse (factor-of-two) but monotone, stable,
    and free of any per-sample storage.
    """

    __slots__ = ("name", "labels", "buckets", "count", "sum_us", "max_us",
                 "_reg")

    def __init__(self, name, labels, reg):
        self.name = name
        self.labels = labels
        self.buckets = [0] * _NBUCKETS
        self.count = 0
        self.sum_us = 0.0
        self.max_us = 0.0
        self._reg = reg

    def observe(self, seconds: float) -> None:
        if not self._reg.enabled:
            return
        us = seconds * 1e6
        i = int(us).bit_length()
        if i >= _NBUCKETS:
            i = _NBUCKETS - 1
        self.buckets[i] += 1
        self.count += 1
        self.sum_us += us
        if us > self.max_us:
            self.max_us = us

    def percentile(self, p: float) -> float:
        """Upper bucket bound (µs) below which fraction ``p`` of samples
        fall.  Returns 0.0 for an empty histogram."""
        if self.count == 0:
            return 0.0
        want = p * self.count
        seen = 0
        for i, c in enumerate(self.buckets):
            seen += c
            if seen >= want:
                return float(1 << i)
        return float(1 << (_NBUCKETS - 1))

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)

    @property
    def mean_us(self) -> float:
        return self.sum_us / self.count if self.count else 0.0

    def as_value(self):
        return {
            "count": self.count,
            "sum_us": round(self.sum_us, 3),
            "mean_us": round(self.mean_us, 3),
            "p50_us": self.p50,
            "p99_us": self.p99,
            "max_us": round(self.max_us, 3),
        }


class MetricsRegistry:
    """Named instrument store plus the global enabled flag.

    ``counter/gauge/histogram`` are get-or-create: callers anywhere in
    the process that name the same instrument (and labels) share it.
    GC telemetry keeps bounded history here too — ``gc_reports`` holds
    recent ``GCReport`` dicts, ``gc_pauses`` the per-``step()`` pause
    samples — so ``obs.snapshot()`` can answer "how long are GC pauses
    really" without any subsystem retaining its own log.
    """

    def __init__(self, enabled: bool | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_OBS", "1") not in ("0", "false")
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}
        self.gc_reports: deque[dict] = deque(maxlen=64)
        self.gc_pauses: deque[dict] = deque(maxlen=512)

    # ------------------------------------------------------ instruments
    def _get(self, cls, name: str, labels: dict | None):
        lab = tuple(sorted((str(k), str(v)) for k, v in labels.items())) \
            if labels else ()
        key = _render_key(name, lab)
        inst = self._instruments.get(key)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(key)
                if inst is None:
                    inst = cls(name, lab, self)
                    self._instruments[key] = inst
        if type(inst) is not cls:
            raise TypeError(f"{key} already registered as "
                            f"{type(inst).__name__}")
        return inst

    def counter(self, name: str, labels: dict | None = None) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: dict | None = None) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, labels: dict | None = None) -> Histogram:
        return self._get(Histogram, name, labels)

    # --------------------------------------------------------- switches
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop all instruments and history (tests, bench trials)."""
        with self._lock:
            self._instruments.clear()
            self.gc_reports.clear()
            self.gc_pauses.clear()

    # ----------------------------------------------------- gc telemetry
    def record_gc_report(self, report_dict: dict) -> None:
        if self.enabled:
            self.gc_reports.append(report_dict)

    def record_gc_pause(self, phase: str, seconds: float, *,
                        epoch: int = 0) -> None:
        if not self.enabled:
            return
        self.gc_pauses.append({"phase": phase, "epoch": epoch,
                               "us": round(seconds * 1e6, 3)})
        self.histogram("gc_slice_us").observe(seconds)

    # ----------------------------------------------------------- export
    def as_dict(self) -> dict:
        out: dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for key, inst in sorted(self._instruments.items()):
            if isinstance(inst, Counter):
                out["counters"][key] = inst.as_value()
            elif isinstance(inst, Gauge):
                out["gauges"][key] = inst.as_value()
            else:
                out["histograms"][key] = inst.as_value()
        return out

    def instruments(self):
        return sorted(self._instruments.items())


REGISTRY = MetricsRegistry()
