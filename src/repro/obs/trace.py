"""Span tracing with contextvar propagation.

``trace(name, **attrs)`` is a context manager.  Spans link to the
current span via a :mod:`contextvars` variable, so one client operation
(``Cluster.put`` → servlet → ``ForkBase`` → tiered → segment) yields a
single parent span whose children record per-layer durations and
chunk/byte counts — the paper's "where does a Put spend its time"
question answered from one ``with`` block at the call site.

When the registry is disabled, ``trace()`` returns a shared null
context manager: the whole cost is one attribute check plus a kwargs
dict, which is what keeps the disabled-mode overhead under the CI gate.
"""
from __future__ import annotations

import contextvars
import itertools
import time
from collections import deque

from .metrics import REGISTRY

__all__ = ["Span", "trace", "current_span", "recent_spans", "monotonic"]

#: Monotonic timer helper (satellite: replaces wall-clock ``time.time()``
#: deltas — immune to clock steps, so timings can't go negative).
monotonic = time.perf_counter

_ids = itertools.count(1)
_current: contextvars.ContextVar[Span | None] = contextvars.ContextVar(
    "repro_obs_span", default=None)
# Finished spans with no parent land here so exporters can show recent
# operation trees without anyone holding a reference.
_recent_roots: deque[Span] = deque(maxlen=32)

MAX_CHILDREN = 128


class Span:
    """One timed region.  ``duration_s`` is set on exit; ``children``
    holds nested finished spans (bounded — overflow counts into
    ``dropped_children`` rather than growing without limit)."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "start_s",
                 "duration_s", "children", "dropped_children", "error")

    def __init__(self, name: str, attrs: dict, parent: Span | None):
        self.name = name
        self.attrs = attrs
        self.span_id = next(_ids)
        self.parent_id = parent.span_id if parent is not None else 0
        self.start_s = 0.0
        self.duration_s = 0.0
        self.children: list[Span] = []
        self.dropped_children = 0
        self.error = ""

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def _adopt(self, child: Span) -> None:
        if len(self.children) < MAX_CHILDREN:
            self.children.append(child)
        else:
            self.dropped_children += 1

    def child_seconds(self) -> float:
        return sum(c.duration_s for c in self.children)

    def walk(self):
        """Yield this span and every descendant, depth-first."""
        yield self
        for c in self.children:
            yield from c.walk()

    def as_dict(self) -> dict:
        d = {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            # same clock as event ``mono_us``, so exported span trees and
            # the event journal line up on one timeline
            "start_us": round(self.start_s * 1e6, 3),
            "us": round(self.duration_s * 1e6, 3),
            "attrs": {k: _jsonable(v) for k, v in self.attrs.items()},
            "children": [c.as_dict() for c in self.children],
        }
        if self.error:
            d["error"] = self.error
        if self.dropped_children:
            d["dropped_children"] = self.dropped_children
        return d

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, us={self.duration_s * 1e6:.1f})")


def _jsonable(v):
    if isinstance(v, (bytes, bytearray, memoryview)):
        return bytes(v).hex()
    if isinstance(v, (set, frozenset)):
        return sorted(_jsonable(x) for x in v)
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


class _NullTrace:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, et, ev, tb):
        return False


_NULL = _NullTrace()


class _Trace:
    __slots__ = ("_name", "_attrs", "_hist", "_span", "_parent", "_token")

    def __init__(self, name, attrs, hist):
        self._name = name
        self._attrs = attrs
        self._hist = hist
        self._span = None
        self._parent = None
        self._token = None

    def __enter__(self) -> Span:
        self._parent = _current.get()
        sp = Span(self._name, self._attrs, self._parent)
        self._span = sp
        self._token = _current.set(sp)
        sp.start_s = monotonic()
        return sp

    def __exit__(self, et, ev, tb):
        sp = self._span
        sp.duration_s = monotonic() - sp.start_s
        _current.reset(self._token)
        if et is not None:
            sp.error = et.__name__
        parent = self._parent
        if parent is not None:
            parent._adopt(sp)
        else:
            _recent_roots.append(sp)
        if self._hist is not None:
            self._hist.observe(sp.duration_s)
        return False


def trace(name: str, _hist=None, **attrs):
    """Open a span named ``name``.  Yields the :class:`Span` (or ``None``
    when observability is disabled).  ``_hist``: optional Histogram that
    receives the span duration on exit."""
    if not REGISTRY.enabled:
        return _NULL
    return _Trace(name, attrs, _hist)


def current_span() -> Span | None:
    return _current.get()


def recent_spans() -> list[Span]:
    """Recently finished root spans, oldest first."""
    return list(_recent_roots)


def clear_recent_spans() -> None:
    _recent_roots.clear()
