"""Tamper-evidence proof subsystem: stateless verifiers over the
Merkle structure (paper §3.2, §4.3; UStore's verifiable access).

A verifier holding only a trusted anchor — a POS-Tree root cid, a
version uid, or a signed head attestation — can check:

  membership   an element/key is (or is not) in a value
               (prove_member / prove_absence -> verify_member[_many])
  lineage      a version is an ancestor of a trusted head, and at what
               distance (prove_lineage -> verify_lineage)
  attestation  a branch head is committed to by an engine/servlet
               (ForkBase.attest -> prove_head -> verify_head)
  audit        sampled cross-replica / cluster integrity, anchored on
               attestations (Auditor)

No verifier touches the store; proofs carry the raw chunks whose hashes
close the chain.  Batch verification routes all hashing through
``content_hash_many`` — one Pallas ``fphash`` launch per batch on TPU.
"""
from .attest import (Attestation, HeadProof, attest_heads, head_entries,
                     merkle_root, prove_head, verify_attestation,
                     verify_head)
from .audit import AuditDaemon, AuditFinding, Auditor, AuditReport
from .delta import (DeltaAttestor, DeltaStats, attestation_epoch,
                    pack_epoch, unpack_epoch)
from .lineage import (LineageProof, lineage_path, prove_lineage,
                      verify_lineage)
from .membership import (Claim, InvalidProof, MembershipProof,
                         ProofCache, VerifyMemo, prove_absence,
                         prove_member, verify_member, verify_member_many)
from ..core.fobject import FObject
from ..core.hashing import content_hash_many


def verify_version(uid: bytes, meta_raw: bytes) -> FObject:
    """Stateless uid -> version record binding: the meta chunk must hash
    to the trusted uid; returns the authenticated FObject (whose ``data``
    is the value root cid for chunkable types — the anchor for
    membership proofs underneath)."""
    from ..core import chunk as ck
    if content_hash_many([bytes(meta_raw)])[0] != bytes(uid):
        raise InvalidProof("meta chunk does not hash to uid")
    try:
        if ck.chunk_type(meta_raw) != ck.META:
            raise InvalidProof("not a meta chunk")
        return FObject.deserialize(bytes(meta_raw), bytes(uid))
    except InvalidProof:
        raise
    except Exception as e:
        raise InvalidProof(f"malformed meta chunk: {e}") from e


__all__ = [
    "Attestation", "HeadProof", "attest_heads", "head_entries",
    "merkle_root", "prove_head", "verify_attestation", "verify_head",
    "AuditDaemon", "AuditFinding", "Auditor", "AuditReport",
    "DeltaAttestor", "DeltaStats", "attestation_epoch", "pack_epoch",
    "unpack_epoch",
    "LineageProof", "lineage_path", "prove_lineage", "verify_lineage",
    "Claim", "InvalidProof", "MembershipProof", "ProofCache",
    "VerifyMemo", "prove_absence", "prove_member", "verify_member",
    "verify_member_many", "verify_version",
]
