"""Head attestations: a compact commitment to a branch table.

``attest_heads`` Merkle-izes every (key, branch tag, head uid) triple —
tagged branches plus untagged fork-on-conflict heads — into one root
digest, optionally HMAC-signed.  The attestation is the light client's
trust anchor (the substrate paper's auditor use-case): ``prove_head``
yields an O(log heads) audit path showing a single head is committed to
by the root, and from that head uid, lineage and membership proofs
authenticate everything beneath it — value roots, elements, history —
with no store access anywhere.

The tree is a plain binary Merkle tree over the sorted entry encodings
(domain-separated leaf/node hashes, odd nodes promoted), deliberately
independent of the POS-Tree: a branch table is small, mutates wholesale
per attestation epoch, and needs nothing content-defined.
"""
from __future__ import annotations

import hmac as _hmac
import struct
from dataclasses import dataclass

from ..core.hashing import content_hash, content_hash_many
from .membership import MAGIC, InvalidProof

_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")

ATTESTATION = 5
HEAD_PROOF = 6

UB_TAG = "\x00ub"       # pseudo-tag for untagged (FoC) heads


def _lv(b: bytes) -> bytes:
    return _U32.pack(len(b)) + b


def encode_entry(key: bytes, tag: str, uid: bytes) -> bytes:
    return _lv(bytes(key)) + _lv(tag.encode()) + bytes(uid)


def decode_entry(e: bytes) -> tuple[bytes, str, bytes]:
    """Parse one committed head entry.  Every framing length is
    validated and every parse failure surfaces as InvalidProof — a
    malformed entry inside an otherwise-valid attestation (e.g. a buggy
    or hostile attester committing garbage) must not leak struct.error
    or silently-truncated fields through ``verify_head``."""
    try:
        (kl,) = _U32.unpack_from(e, 0)
        key = e[4:4 + kl]
        if len(key) != kl:
            raise InvalidProof("truncated entry key")
        i = 4 + kl
        (tl,) = _U32.unpack_from(e, i)
        tag = e[i + 4:i + 4 + tl]
        if len(tag) != tl:
            raise InvalidProof("truncated entry tag")
        uid = e[i + 4 + tl:]
        if len(uid) != 32:
            raise InvalidProof("bad entry uid")
        return bytes(key), tag.decode(), bytes(uid)
    except InvalidProof:
        raise
    except (struct.error, IndexError, UnicodeDecodeError) as exc:
        raise InvalidProof(f"malformed head entry: {exc}") from exc


def head_entries(branches) -> list[bytes]:
    """Deterministic serialized entry list of a BranchTable: every tagged
    head plus every untagged (FoC) head that is not merely an alias of a
    tagged one."""
    out = []
    for key in branches.keys():
        tb = branches.tagged(key)
        for tag, uid in tb.items():
            out.append(encode_entry(key, tag, uid))
        aliased = set(tb.values())
        for uid in branches.untagged(key):
            if uid not in aliased:
                out.append(encode_entry(key, UB_TAG, uid))
    return sorted(out)


# ------------------------------------------------------------- merkle tree

def leaf_hash(entry: bytes) -> bytes:
    return content_hash(b"\x00" + entry)


def node_hash(left: bytes, right: bytes) -> bytes:
    return content_hash(b"\x01" + left + right)


EMPTY_ROOT = b"\x00" * 32


def merkle_root(leaves: list[bytes]) -> bytes:
    """Root over pre-hashed leaf digests (odd node promoted)."""
    if not leaves:
        return EMPTY_ROOT
    level = list(leaves)
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            nxt.append(node_hash(level[i], level[i + 1]))
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


def _merkle_path(leaves: list[bytes], index: int) -> list[bytes]:
    sibs = []
    level = list(leaves)
    i = index
    while len(level) > 1:
        sib = i ^ 1
        if sib < len(level):
            sibs.append(level[sib])
        nxt = []
        for j in range(0, len(level) - 1, 2):
            nxt.append(node_hash(level[j], level[j + 1]))
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
        i //= 2
    return sibs


# -------------------------------------------------------------- attestation

@dataclass(frozen=True)
class Attestation:
    root: bytes
    count: int                    # number of committed head entries
    context: bytes = b""          # epoch / node id / app nonce
    sig: bytes = b""              # HMAC over root|count|context

    def signing_bytes(self) -> bytes:
        return self.root + _U32.pack(self.count) + self.context

    def to_bytes(self) -> bytes:
        return (bytes([MAGIC, ATTESTATION]) + self.root
                + _U32.pack(self.count) + _lv(self.context) + _lv(self.sig))

    @classmethod
    def from_bytes(cls, data: bytes) -> "Attestation":
        try:
            if data[0] != MAGIC or data[1] != ATTESTATION:
                raise InvalidProof("bad magic")
            root = bytes(data[2:34])
            if len(root) != 32:
                raise InvalidProof("truncated root")
            (count,) = _U32.unpack_from(data, 34)
            (cl,) = _U32.unpack_from(data, 38)
            ctx = bytes(data[42:42 + cl])
            if len(ctx) != cl:
                raise InvalidProof("truncated context")
            i = 42 + cl
            (sl,) = _U32.unpack_from(data, i)
            sig = bytes(data[i + 4:i + 4 + sl])
            if len(sig) != sl or i + 4 + sl != len(data):
                raise InvalidProof("bad framing")
        except (struct.error, IndexError) as e:
            raise InvalidProof(f"unparseable attestation: {e}") from e
        return cls(root, count, ctx, sig)


def sign(att: Attestation, secret: bytes) -> Attestation:
    sig = _hmac.new(secret, att.signing_bytes(), "sha256").digest()
    return Attestation(att.root, att.count, att.context, sig)


def verify_attestation(att, secret: bytes | None = None) -> Attestation:
    """Parse + (when ``secret`` given) authenticate the signature."""
    a = (att if isinstance(att, Attestation)
         else Attestation.from_bytes(bytes(att)))
    if secret is not None:
        want = _hmac.new(secret, a.signing_bytes(), "sha256").digest()
        if not _hmac.compare_digest(want, a.sig):
            raise InvalidProof("attestation signature mismatch")
    return a


def attest_heads(branches, context: bytes = b"",
                 secret: bytes | None = None) -> Attestation:
    entries = head_entries(branches)
    leaves = content_hash_many([b"\x00" + e for e in entries])
    att = Attestation(merkle_root(leaves), len(entries), bytes(context))
    return sign(att, secret) if secret is not None else att


# -------------------------------------------------------------- head proofs

@dataclass(frozen=True)
class HeadProof:
    index: int
    entry: bytes                  # encode_entry(key, tag, uid)
    siblings: tuple[bytes, ...]

    def to_bytes(self) -> bytes:
        return (bytes([MAGIC, HEAD_PROOF]) + _U32.pack(self.index)
                + _lv(self.entry) + _U16.pack(len(self.siblings))
                + b"".join(self.siblings))

    @classmethod
    def from_bytes(cls, data: bytes) -> "HeadProof":
        try:
            if data[0] != MAGIC or data[1] != HEAD_PROOF:
                raise InvalidProof("bad magic")
            (index,) = _U32.unpack_from(data, 2)
            (el,) = _U32.unpack_from(data, 6)
            entry = bytes(data[10:10 + el])
            if len(entry) != el:
                raise InvalidProof("truncated entry")
            i = 10 + el
            (ns,) = _U16.unpack_from(data, i)
            i += 2
            sibs = []
            for _ in range(ns):
                sibs.append(bytes(data[i:i + 32])); i += 32
                if len(sibs[-1]) != 32:
                    raise InvalidProof("truncated sibling")
            if i != len(data):
                raise InvalidProof("bad framing")
        except (struct.error, IndexError) as e:
            raise InvalidProof(f"unparseable head proof: {e}") from e
        return cls(index, entry, tuple(sibs))

    @property
    def size(self) -> int:
        return len(self.to_bytes())


def entry_leaves(entries: list[bytes]) -> list[bytes]:
    """Leaf digests for a serialized entry list — ONE hash batch."""
    return content_hash_many([b"\x00" + e for e in entries])


def prove_entry(entries: list[bytes], leaves: list[bytes],
                entry: bytes) -> HeadProof:
    """Audit path for one entry against precomputed (entries, leaves) —
    the auditor's batched path: many proofs, one tree, one hash batch."""
    try:
        index = entries.index(entry)
    except ValueError:
        raise KeyError(entry) from None
    return HeadProof(index, entry, tuple(_merkle_path(leaves, index)))


def prove_head(branches, key: bytes, tag: str | None = None,
               uid: bytes | None = None) -> HeadProof:
    """Audit path for one head: a tagged branch (``tag``) or an untagged
    FoC head (``uid``)."""
    key = bytes(key)
    if tag is None:
        if uid is None:
            raise ValueError("need tag or uid")
        tag = UB_TAG
        entry = encode_entry(key, tag, uid)
    else:
        head = branches.head(key, tag)
        if head is None:
            raise KeyError(tag)
        entry = encode_entry(key, tag, head)
    entries = head_entries(branches)
    return prove_entry(entries, entry_leaves(entries), entry)


def verify_head(attestation, proof,
                secret: bytes | None = None) -> tuple[bytes, str, bytes]:
    """Stateless: does the attestation commit to this head?  Returns the
    authenticated (key, tag, head uid); raises InvalidProof.  The sibling
    walk is replayed against the attested entry COUNT, so a forged count,
    index, or path length cannot reach the committed root."""
    att = verify_attestation(attestation, secret)
    p = (proof if isinstance(proof, HeadProof)
         else HeadProof.from_bytes(bytes(proof)))
    if not (0 <= p.index < att.count):
        raise InvalidProof("index outside attested entry count")
    digest = leaf_hash(p.entry)
    i, width = p.index, att.count
    sibs = list(p.siblings)
    while width > 1:
        sib = i ^ 1
        if sib < width:
            if not sibs:
                raise InvalidProof("audit path too short")
            other = sibs.pop(0)
            digest = (node_hash(digest, other) if i % 2 == 0
                      else node_hash(other, digest))
        i //= 2
        width = (width + 1) // 2
    if sibs:
        raise InvalidProof("audit path too long")
    if digest != att.root:
        raise InvalidProof("head not committed by attestation root")
    return decode_entry(p.entry)
