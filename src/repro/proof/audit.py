"""Replica & cluster auditor: sampled tamper-evidence checks.

The auditor is the *consumer* of the proof subsystem: it anchors on
attested heads, samples chunks and branches, and verifies everything
with the stateless verifiers — so a passing audit means an external
verifier holding only the attestations would accept the same state.

  audit_replicas   every ring copy of each sampled cid must be present
                   and hash back to the cid (corrupt / missing copies
                   are reported with the offending replica index);
  audit_engine     sampled heads of one servlet: head proofs against a
                   fresh attestation, meta chunks re-hashed, membership
                   proofs of sampled elements, lineage proofs one step
                   into history — all through the stateless verifiers;
  audit_cluster    the dispatcher's view: per-node placement checks of
                   the master index, per-servlet engine audits, and
                   key-routing divergence (a key with branch state on
                   two servlets means the dispatch rule was violated).

All content hashing is batched: one ``content_hash_many`` per audit
phase (one Pallas ``fphash`` launch on TPU), not one hash per copy.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .. import obs
from ..core.fobject import CHUNKABLE_TYPES, FObject
from ..core.hashing import content_hash_many
from ..core.postree import POSTree
from .attest import verify_head
from .lineage import LineageProof, verify_lineage
from .membership import (InvalidProof, VerifyMemo, prove_member,
                         verify_member_many)


@dataclass(frozen=True)
class AuditFinding:
    node: str                 # offending replica / cluster node
    kind: str                 # "corrupt" | "missing" | "diverged" | "bad-proof"
    detail: str
    cid: bytes = b""

    def __str__(self) -> str:
        at = f" cid={self.cid.hex()[:16]}" if self.cid else ""
        return f"[{self.kind}] {self.node}: {self.detail}{at}"


@dataclass
class AuditReport:
    chunks_checked: int = 0
    copies_checked: int = 0
    heads_checked: int = 0
    proofs_verified: int = 0
    findings: list[AuditFinding] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def merge(self, other: "AuditReport") -> "AuditReport":
        self.chunks_checked += other.chunks_checked
        self.copies_checked += other.copies_checked
        self.heads_checked += other.heads_checked
        self.proofs_verified += other.proofs_verified
        self.findings.extend(other.findings)
        return self

    def __str__(self) -> str:
        head = (f"audit: {self.chunks_checked} chunks "
                f"({self.copies_checked} copies), {self.heads_checked} "
                f"heads, {self.proofs_verified} proofs verified")
        if self.ok:
            return head + " — OK"
        return head + "\n" + "\n".join(f"  {f}" for f in self.findings)


class Auditor:
    """Sampling auditor; ``sample`` bounds per-phase work so audits stay
    cheap enough to run continuously against production replicas."""

    def __init__(self, sample: int = 64, seed: int = 0):
        self.sample = sample
        self._rng = np.random.default_rng(seed)
        # decoded-node memo persists across audit rounds: an auditor
        # re-checking the same trees round after round hashes/decodes
        # only nodes it has never seen (content addressing keeps the
        # memo coherent for free)
        self.memo = VerifyMemo()

    def _sample(self, seq):
        seq = list(seq)
        if len(seq) <= self.sample:
            return seq
        idx = self._rng.choice(len(seq), size=self.sample, replace=False)
        return [seq[int(i)] for i in sorted(idx)]

    # -------------------------------------------------------- replicas
    def audit_replicas(self, backend) -> AuditReport:
        """Cross-replica audit of a ReplicatedBackend: each sampled cid
        must be present on every ring member and every copy must hash
        back to the cid (one batched hash over all copies)."""
        rep = AuditReport()
        cids = self._sample(backend.iter_cids())
        rep.chunks_checked = len(cids)
        copies: list[tuple[int, bytes, bytes]] = []   # (store idx, cid, raw)
        for cid in cids:
            for si in backend._ring(cid):
                store = backend.stores[si]
                # repro: allow(PERF001): audit probes per copy on purpose
                # — fault attribution needs to know WHICH replica lost the
                # chunk, and the walk continues past failures (a batched
                # has_many can't name the offender per ring member)
                if not store.has(cid):
                    rep.findings.append(AuditFinding(
                        f"replica{si}", "missing",
                        "ring member lost its copy", cid))
                    continue
                try:
                    # repro: allow(PERF001): per-copy get so one corrupt
                    # replica is named without masking the healthy ones
                    copies.append((si, cid, store.get(cid)))
                except ValueError as e:   # verify-enabled leaf caught it
                    rep.findings.append(AuditFinding(
                        f"replica{si}", "corrupt", str(e), cid))
                except KeyError:
                    rep.findings.append(AuditFinding(
                        f"replica{si}", "missing",
                        "copy vanished mid-audit", cid))
        digests = content_hash_many([raw for _, _, raw in copies])
        rep.copies_checked = len(copies)
        for (si, cid, _), digest in zip(copies, digests):
            if digest != cid:
                rep.findings.append(AuditFinding(
                    f"replica{si}", "corrupt",
                    "copy does not hash to its cid", cid))
        return rep

    # --------------------------------------------------------- servlets
    def audit_engine(self, db, node: str = "servlet",
                     secret: bytes | None = None) -> AuditReport:
        """One engine's branch state, end-to-end through the stateless
        verifiers, anchored on a fresh attestation."""
        rep = AuditReport()
        att = db.attest(context=node.encode(), secret=secret)
        heads: list[tuple[bytes, str, bytes]] = []
        for key in db.branches.keys():
            for tag, uid in db.branches.tagged(key).items():
                heads.append((key, tag, uid))
        heads = self._sample(heads)
        rep.heads_checked = len(heads)
        # 1) every sampled head is committed by the attestation; the
        # audit paths come straight off the engine's resident delta
        # attestation tree — no re-Merkle-ization per audit round
        committed: list[tuple[bytes, str, bytes]] = []
        for key, tag, uid in heads:
            try:
                verify_head(att, db.prove_head(key, tag), secret=secret)
                rep.proofs_verified += 1
                committed.append((key, tag, uid))
            except (InvalidProof, KeyError) as e:
                rep.findings.append(AuditFinding(
                    node, "bad-proof", f"head {key!r}@{tag}: {e}", uid))
        # 2) meta-chunk integrity, one hash batch for every head
        metas: list[tuple[bytes, str, bytes, bytes]] = []
        for key, tag, uid in committed:
            try:
                # repro: allow(PERF001): per-head get so a single tampered
                # meta chunk is attributed to its branch head, not the batch
                metas.append((key, tag, uid, db.store.get(uid)))
            except ValueError as e:     # TamperedChunk from a verify store
                rep.findings.append(AuditFinding(
                    node, "corrupt",
                    f"head meta chunk {key!r}@{tag}: {e}", uid))
            except KeyError:
                rep.findings.append(AuditFinding(
                    node, "missing", f"head meta chunk {key!r}@{tag}", uid))
        digests = content_hash_many([raw for *_, raw in metas])
        member_batch: list[tuple[bytes, object]] = []
        with_bases: list[tuple[bytes, str, bytes, bytes, bytes]] = []
        for (key, tag, uid, raw), digest in zip(metas, digests):
            if digest != uid:
                rep.findings.append(AuditFinding(
                    node, "corrupt", f"head meta chunk {key!r}@{tag}", uid))
                continue
            obj = FObject.deserialize(raw, uid)
            rep.chunks_checked += 1
            # 3) a sampled element of the value, by stateless proof
            if obj.type in CHUNKABLE_TYPES:
                try:
                    tree = POSTree.from_root(db.store, obj.type, obj.data,
                                             db.params)
                    if tree.total_count > 0:
                        pos = int(self._rng.integers(0, tree.total_count))
                        member_batch.append(
                            (obj.data, prove_member(tree, pos=pos)))
                except (KeyError, ValueError) as e:   # lost/tampered node
                    rep.findings.append(AuditFinding(
                        node, "corrupt",
                        f"value tree {key!r}@{tag}: {e}", obj.data))
            if obj.bases:
                with_bases.append((key, tag, uid, raw, obj.bases[0]))
        # 4) one step of history for every head: build each 1-link
        # lineage proof from the already-authenticated head raw + one
        # batched base fetch, then verify them all through ONE hash
        # dispatch (the lineage analogue of verify_member_many)
        base_raws: list[bytes | None]
        try:                        # optimistic: ONE get_many round-trip
            base_raws = list(db.store.get_many(
                [base for *_, base in with_bases])) if with_bases else []
        except (KeyError, ValueError):
            base_raws = []          # degrade per-item to name offenders
            for key, tag, _uid, _, base in with_bases:
                try:
                    # repro: allow(PERF001): deliberate degrade path — the
                    # batched get_many above already failed; re-walk per
                    # item to name the offending base uid(s)
                    base_raws.append(db.store.get(base))
                except (KeyError, ValueError) as e:
                    rep.findings.append(AuditFinding(
                        node, "missing" if isinstance(e, KeyError)
                        else "corrupt", f"base of {key!r}@{tag}: {e}",
                        base))
                    base_raws.append(None)
        lineage_items = [(hb, braw) for hb, braw in zip(with_bases,
                                                        base_raws)
                         if braw is not None]
        base_digests = content_hash_many(
            [braw for _, braw in lineage_items])
        for ((key, tag, uid, raw, base), braw), digest in zip(
                lineage_items, base_digests):
            try:
                if digest != base:
                    raise InvalidProof("base chunk hash mismatch")
                verify_lineage(uid, base, LineageProof((raw, braw)))
                rep.proofs_verified += 1
            except (InvalidProof, ValueError) as e:
                rep.findings.append(AuditFinding(
                    node, "bad-proof", f"lineage {key!r}@{tag}: {e}", uid))
        # batched membership verification: ONE hash dispatch for the
        # nodes this round sees for the first time (memo persists)
        results = verify_member_many(member_batch, strict=False,
                                     memo=self.memo)
        for (root, _), res in zip(member_batch, results):
            if isinstance(res, InvalidProof):
                rep.findings.append(AuditFinding(
                    node, "bad-proof", f"membership: {res}", root))
            else:
                rep.proofs_verified += 1
        return rep

    # ---------------------------------------------------------- cluster
    def audit_placement(self, cluster) -> AuditReport:
        """Sampled master-index placement checks: every sampled index
        entry must be held by the owning node and hash back to its cid
        (one batched hash over everything held)."""
        rep = AuditReport()
        lock = getattr(cluster, "_index_lock", None)
        if lock is not None:     # snapshot under the routing index lock
            with lock:
                entries = list(cluster.index.items())
        else:
            entries = cluster.index.items()
        placed = self._sample(entries)
        rep.chunks_checked += len(placed)
        held: list[tuple[int, bytes, bytes]] = []
        for cid, ni in placed:
            store = cluster.nodes[ni].store
            # repro: allow(PERF001): placement audit asks one node about
            # one cid — per-node attribution is the product, not an N+1
            # accident
            if not store.has(cid):
                rep.findings.append(AuditFinding(
                    f"node{ni}", "missing",
                    "master index points at a chunk the node lost", cid))
                continue
            try:
                # repro: allow(PERF001): per-chunk get keeps the audit
                # walking past a corrupt node instead of failing the sample
                held.append((ni, cid, store.get(cid)))
            except ValueError as e:       # verify-enabled node caught it
                rep.findings.append(AuditFinding(
                    f"node{ni}", "corrupt", str(e), cid))
            except KeyError:
                rep.findings.append(AuditFinding(
                    f"node{ni}", "missing", "chunk vanished mid-audit",
                    cid))
        rep.copies_checked += len(held)
        for (ni, cid, _), digest in zip(
                held, content_hash_many([raw for _, _, raw in held])):
            if digest != cid:
                rep.findings.append(AuditFinding(
                    f"node{ni}", "corrupt",
                    "stored bytes do not hash to the indexed cid", cid))
        return rep

    def audit_cluster(self, cluster,
                      secret: bytes | None = None) -> AuditReport:
        """Dispatcher-side audit: master-index placement, per-servlet
        engine audits, and key-routing divergence."""
        # 1) sampled placement checks against the owning node's store
        rep = self.audit_placement(cluster)
        # 2) key-routing divergence: branch state must live only on the
        # key's home servlet
        owner_of: dict[bytes, list[int]] = {}
        for ni, nd in enumerate(cluster.nodes):
            with nd.lock:
                keys = nd.servlet.branches.keys()
            for key in keys:
                owner_of.setdefault(key, []).append(ni)
        for key, nis in owner_of.items():
            home = cluster._home_index(key)
            for ni in nis:
                if ni != home:
                    rep.findings.append(AuditFinding(
                        f"node{ni}", "diverged",
                        f"branch state for key {key!r} belongs on "
                        f"node{home}"))
        # 3) per-servlet engine audits through the stateless verifiers
        for ni, nd in enumerate(cluster.nodes):
            with nd.lock:
                rep.merge(self.audit_engine(nd.servlet, node=f"node{ni}",
                                            secret=secret))
        return rep


# ------------------------------------------------------------------ daemon

class AuditDaemon:
    """Continuous audit loop for a cluster (ROADMAP "continuous audit
    daemon"): instead of on-demand ``Cluster.audit`` calls, the serving
    loop calls ``tick(budget)`` and the daemon spreads sampled audits
    over time —

      * per-node exponential backoff: a node that keeps auditing clean
        is re-audited at a doubling interval (capped at
        ``max_interval`` ticks), so steady-state audit load decays to a
        heartbeat;
      * a finding triggers an IMMEDIATE re-audit of the node (transient
        read races don't quarantine) and, if anything is still wrong,
        the node is quarantined: recorded in ``self.quarantined``,
        reported via the tick's AuditReport, and kept under base-rate
        audit so repair is observed;
      * the master-index placement/routing checks run as their own
        backoff target beside the per-node engine audits.

    The daemon's Auditor carries the persistent decoded-node memo, so
    successive ticks over unchanged trees skip re-hashing shared nodes.
    Target scheduling is tick-counted (the caller decides what a tick
    means — request batches, seconds, GC slices), keeping the daemon
    deterministic and testable."""

    PLACEMENT = "placement"
    MAX_FINDINGS = 1024       # retained findings (a quarantined node
                              # keeps auditing at base rate forever)

    def __init__(self, cluster, *, sample: int = 32, seed: int = 0,
                 secret: bytes | None = None, base_interval: int = 1,
                 max_interval: int = 64):
        self.cluster = cluster
        self.auditor = Auditor(sample=sample, seed=seed)
        self.secret = secret
        self.base_interval = max(1, base_interval)
        self.max_interval = max(self.base_interval, max_interval)
        self.ticks = 0
        self.audits = 0
        self.quarantined: set[str] = set()
        self.findings: list[AuditFinding] = []
        targets = [f"node{i}" for i in range(len(cluster.nodes))]
        targets.append(self.PLACEMENT)
        # stagger first-due ticks so a fresh daemon does not audit the
        # whole cluster in its first tick
        self._interval = {t: self.base_interval for t in targets}
        self._due = {t: 1 + i for i, t in enumerate(targets)}

    # ---------------------------------------------------------- internals
    def _audit_target(self, target: str) -> AuditReport:
        self.audits += 1
        obs.inc("audit_audits_total")
        if target == self.PLACEMENT:
            return self.auditor.audit_placement(self.cluster)
        ni = int(target[4:])
        nd = self.cluster.nodes[ni]
        # engine audits attest and walk the branch table — hold the
        # servlet lock so a daemon-thread audit can't race a foreground
        # put on the same servlet
        lock = getattr(nd, "lock", None)
        if lock is None:
            return self.auditor.audit_engine(nd.servlet, node=target,
                                             secret=self.secret)
        with lock:
            return self.auditor.audit_engine(nd.servlet, node=target,
                                             secret=self.secret)

    def _quarantine_of(self, report: AuditReport) -> set[str]:
        return {f.node for f in report.findings}

    def _record(self, findings) -> None:
        """Append to the findings log, keeping only the newest
        MAX_FINDINGS — an unrepaired node would grow it forever."""
        self.findings.extend(findings)
        for f in findings:
            obs.inc("audit_findings_total")
            obs.emit("audit.finding", node=f.node, finding_kind=f.kind,
                     detail=f.detail, cid=f.cid)
        if len(self.findings) > self.MAX_FINDINGS:
            del self.findings[:len(self.findings) - self.MAX_FINDINGS]

    # -------------------------------------------------------------- tick
    def tick(self, budget: int = 1) -> AuditReport:
        """Advance the daemon one tick: audit up to ``budget`` due
        targets (earliest-due first) and return the merged report of
        everything audited this tick."""
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.ticks += 1
        obs.inc("audit_ticks_total")
        rep = AuditReport()
        due = sorted((t for t, d in self._due.items() if d <= self.ticks),
                     key=lambda t: (self._due[t], t))
        for target in due[:budget]:
            r = self._audit_target(target)
            rep.merge(r)
            if r.ok:
                self._interval[target] = min(self.max_interval,
                                             self._interval[target] * 2)
            else:
                # immediate re-audit: only a repeatable finding
                # quarantines (a transient read race does not), but
                # either way the target drops back to the base rate
                r2 = self._audit_target(target)
                rep.merge(r2)
                self._record(r.findings)
                if not r2.ok:
                    self._record(r2.findings)
                    bad = self._quarantine_of(r2)
                    fresh = bad - self.quarantined
                    self.quarantined |= bad
                    for node in sorted(fresh):
                        reason = ",".join(sorted(
                            {f.kind for f in r2.findings
                             if f.node == node})) or "repeatable-finding"
                        obs.inc("audit_quarantines_total")
                        obs.emit("audit.quarantine", node=node,
                                 reason=reason, target=target,
                                 tick=self.ticks)
                        # ENFORCE at the routing layer: a direct call
                        # (not an event tap), so placement stops using
                        # the node and re-replication queues even with
                        # observability disabled
                        self._enforce(node, "quarantine", reason)
                    obs.set_gauge("audit_quarantined_nodes",
                                  len(self.quarantined))
                    # a quarantined node drops to base-rate auditing so
                    # repair is observed — even when the finding came
                    # from another target (e.g. the placement check)
                    for node in bad:
                        if node in self._interval:
                            self._interval[node] = self.base_interval
                            self._due[node] = min(self._due[node],
                                                  self.ticks + 1)
                self._interval[target] = self.base_interval
            self._due[target] = self.ticks + self._interval[target]
        return rep

    def _enforce(self, node: str, verb: str, reason: str = "") -> None:
        """Forward a quarantine/release decision to the cluster's
        routing-layer enforcement verbs.  Only ``nodeN`` names map to
        cluster nodes (replica/servlet findings from standalone audits
        have no placement to enforce against)."""
        if not (node.startswith("node") and node[4:].isdigit()):
            return
        ni = int(node[4:])
        if verb == "quarantine":
            fn = getattr(self.cluster, "quarantine_node", None)
            if fn is not None:
                fn(ni, reason=reason)
        else:
            fn = getattr(self.cluster, "release_node", None)
            if fn is not None:
                fn(ni)

    def release(self, node: str) -> None:
        """Operator verb: lift a quarantine after repair; the node
        re-enters the rotation at the base audit rate."""
        if node in self.quarantined:
            obs.inc("audit_releases_total")
            obs.emit("audit.release", node=node, reason="operator-release",
                     tick=self.ticks)
            self._enforce(node, "release")
        self.quarantined.discard(node)
        obs.set_gauge("audit_quarantined_nodes", len(self.quarantined))
        if node in self._interval:
            self._interval[node] = self.base_interval
            self._due[node] = self.ticks + 1
