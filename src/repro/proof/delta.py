"""Delta attestations: an incrementally-maintained Merkle tree over the
branch-table head entries (ROADMAP "incremental attestations under
concurrent GC"; UStore shows head-table commitments must be incremental
to serve heavy traffic).

``attest_heads`` re-Merkle-izes all n head entries on every call —
fine for an occasional epoch, ruinous at production attestation rates.
``DeltaAttestor`` keeps the whole tree (sorted entry list + every hash
level) resident and subscribes to branch-table mutation hooks, so an
attest after k head updates re-hashes only the touched leaves' O(log n)
paths:

  * a head *update* (same key, same tag, new uid) never changes the
    entry's sort position — entries are compared by their length-
    prefixed (key, tag) encoding before the uid is reached — so it is
    an in-place leaf replacement: one leaf hash + one path of node
    hashes;
  * an entry *insert/delete* (new branch, removed branch, untagged-head
    churn) shifts positions, so each upper level is recomputed from the
    first changed node — the unchanged prefix of every level is reused
    (appends near the end of the sort order stay O(log n));
  * the first attest, and any attest after the cid hash algorithm was
    swapped (``hashing.set_default_hash``), falls back to ONE full
    rebuild and resumes delta maintenance from there.

The produced ``Attestation`` is bit-identical to ``attest_heads``'s —
verifiers cannot tell (and must not care) how the root was maintained.

Attestation contexts carry the GC collector epoch (``pack_epoch`` /
``attestation_epoch``): the epoch handshake with the incremental
collector (gc.EpochFence) guarantees proofs against an attestation stay
servable until the second collection after its issue begins, so a
verifier can compare the attested epoch with the store's current one to
know whether its anchor is still fresh.
"""
from __future__ import annotations

import bisect
import struct
from dataclasses import dataclass

from .. import obs
from ..core.hashing import current_hash
from .attest import (Attestation, HeadProof, UB_TAG, EMPTY_ROOT,
                     encode_entry, entry_leaves, head_entries, leaf_hash,
                     node_hash, sign)

_EPOCH = struct.Struct("<Q")
EPOCH_MAGIC = b"\xfbE"        # context prefix: epoch-tagged attestation


def pack_epoch(epoch: int, context: bytes = b"") -> bytes:
    """Embed the GC collector epoch into an attestation context."""
    return EPOCH_MAGIC + _EPOCH.pack(epoch) + bytes(context)


def attestation_epoch(att: Attestation) -> int | None:
    """The GC epoch an engine-issued attestation was stamped with, or
    None for a context that does not carry one (foreign attester)."""
    ctx = att.context
    if len(ctx) < len(EPOCH_MAGIC) + 8 or not ctx.startswith(EPOCH_MAGIC):
        return None
    return _EPOCH.unpack_from(ctx, len(EPOCH_MAGIC))[0]


def unpack_epoch(context: bytes) -> bytes:
    """The caller-supplied part of an epoch-tagged context."""
    if context.startswith(EPOCH_MAGIC) and len(context) >= 10:
        return context[len(EPOCH_MAGIC) + 8:]
    return bytes(context)


@dataclass
class DeltaStats:
    leaf_hashes: int = 0      # leaf digests computed (full + delta)
    node_hashes: int = 0      # internal node hashes computed
    full_rebuilds: int = 0    # attests that rebuilt all n leaves
    delta_refreshes: int = 0  # attests served by path updates only
    updates: int = 0          # in-place leaf replacements applied
    inserts: int = 0          # entries added to the tree
    removes: int = 0          # entries dropped from the tree


def _key_entries(branches, key: bytes) -> dict:
    """Current committed entries of one key, keyed so a tagged head
    update (same tag, new uid) pairs with the entry it replaces."""
    out = {}
    tb = branches.tagged(key)
    for tag, uid in tb.items():
        out[("t", tag)] = encode_entry(key, tag, uid)
    aliased = set(tb.values())
    for uid in branches.untagged(key):
        if uid not in aliased:
            out[("u", uid)] = encode_entry(key, UB_TAG, uid)
    return out


class DeltaAttestor:
    """Persistent head-entry Merkle tree over one BranchTable.

    Construction subscribes to the table's mutation hooks; every
    ``attest()`` / ``root()`` first folds the accumulated dirty keys
    into the resident tree and then reads the root in O(1).
    """

    def __init__(self, branches):
        self.branches = branches
        self.stats = DeltaStats()
        self._entries: list[bytes] = []      # global sorted entry list
        self._levels: list[list[bytes]] = [[]]   # [leaf digests, ..., root]
        self._by_key: dict[bytes, dict] = {}     # key -> _key_entries view
        self._dirty: set[bytes] = set()
        self._built = False
        self._hash_fn = None
        branches.add_listener(self._on_mutate)

    # ------------------------------------------------------------ hooks
    def _on_mutate(self, key: bytes) -> None:
        self._dirty.add(bytes(key))

    # ------------------------------------------------------- public api
    def root(self) -> bytes:
        self._refresh()
        if not self._entries:
            return EMPTY_ROOT
        return self._levels[-1][0]

    @property
    def count(self) -> int:
        return len(self._entries)

    def attest(self, context: bytes = b"",
               secret: bytes | None = None) -> Attestation:
        """Bit-identical to ``attest_heads(self.branches, ...)``, at
        O(k log n) hash work for k head changes since the last call."""
        with obs.trace("proof.attest", heads=len(self._entries)):
            obs.inc("attests_total")
            att = Attestation(self.root(), len(self._entries),
                              bytes(context))
            return sign(att, secret) if secret is not None else att

    def prove(self, entry: bytes) -> HeadProof:
        """Audit path for one committed entry straight off the resident
        tree — O(log n) lookup + sibling collection, no re-hashing (the
        per-root proof-cache analogue for head proofs)."""
        self._refresh()
        idx = bisect.bisect_left(self._entries, entry)
        if idx >= len(self._entries) or self._entries[idx] != entry:
            raise KeyError(entry)
        sibs = []
        i = idx
        for level in self._levels[:-1] if len(self._levels) > 1 else []:
            sib = i ^ 1
            if sib < len(level):
                sibs.append(level[sib])
            i //= 2
        return HeadProof(idx, entry, tuple(sibs))

    # -------------------------------------------------------- internals
    def _leaf(self, entry: bytes) -> bytes:
        self.stats.leaf_hashes += 1
        return leaf_hash(entry)

    def _node(self, left: bytes, right: bytes) -> bytes:
        self.stats.node_hashes += 1
        return node_hash(left, right)

    def _refresh(self) -> None:
        cur = current_hash()
        if not self._built or cur is not self._hash_fn:
            self._rebuild()
            self._hash_fn = cur
            return
        if not self._dirty:
            return
        try:
            self._apply_dirty()
        except KeyError:
            # hooks and table diverged (a mutation bypassed the
            # listeners): fall back to one full rebuild — correctness
            # never depends on the delta bookkeeping
            self._rebuild()

    def _apply_dirty(self) -> None:
        self.stats.delta_refreshes += 1
        obs.inc("attest_delta_refreshes_total")
        updates: list[tuple[bytes, bytes]] = []
        inserts: list[bytes] = []
        removes: list[bytes] = []
        for key in sorted(self._dirty):
            new = _key_entries(self.branches, key)
            old = self._by_key.get(key, {})
            if new == old:
                continue
            for slot, e in old.items():
                if slot not in new:
                    removes.append(e)
                elif new[slot] != e:
                    updates.append((e, new[slot]))
            for slot, e in new.items():
                if slot not in old:
                    inserts.append(e)
            if new:
                self._by_key[key] = new
            else:
                self._by_key.pop(key, None)
        self._dirty.clear()
        # 1) in-place replacements: sort position is invariant, so each
        #    is one leaf hash + one root-ward path of node hashes
        for old_e, new_e in updates:
            i = self._find(old_e)
            self._entries[i] = new_e
            self._levels[0][i] = self._leaf(new_e)
            self._update_path(i)
            self.stats.updates += 1
        # 2) structural edits: apply to the leaf level, then recompute
        #    each upper level from its first changed node
        if not (inserts or removes):
            return
        old_lens = [len(level) for level in self._levels]
        first = len(self._entries)
        for e in removes:
            i = self._find(e)
            del self._entries[i]
            del self._levels[0][i]
            first = min(first, i)
            self.stats.removes += 1
        for e in sorted(inserts):
            i = bisect.bisect_left(self._entries, e)
            self._entries.insert(i, e)
            self._levels[0].insert(i, self._leaf(e))
            first = min(first, i)
            self.stats.inserts += 1
        self._recompute_from(first, old_lens)

    def _find(self, entry: bytes) -> int:
        i = bisect.bisect_left(self._entries, entry)
        if i >= len(self._entries) or self._entries[i] != entry:
            raise KeyError(entry)           # hooks and table diverged
        return i

    def _rebuild(self) -> None:
        """Full rebuild (first use / hash-algorithm change): one batched
        leaf-hash dispatch over every entry, levels built bottom-up."""
        self.stats.full_rebuilds += 1
        obs.inc("attest_full_rebuilds_total")
        entries = head_entries(self.branches)
        self._entries = entries
        self.stats.leaf_hashes += len(entries)
        self._levels = [entry_leaves(entries)]
        self._recompute_from(0, [])
        self._by_key = {key: _key_entries(self.branches, key)
                        for key in self.branches.keys()}
        self._by_key = {k: v for k, v in self._by_key.items() if v}
        self._dirty.clear()
        self._built = True

    def _update_path(self, i: int) -> None:
        """Re-hash the root-ward path above an in-place leaf change."""
        for lv in range(1, len(self._levels)):
            child = self._levels[lv - 1]
            p = i // 2
            if 2 * p + 1 < len(child):
                self._levels[lv][p] = self._node(child[2 * p],
                                                 child[2 * p + 1])
            else:                            # odd node promoted
                self._levels[lv][p] = child[2 * p]
            i = p

    def _recompute_from(self, i: int, old_lens: list[int]) -> None:
        """Rebuild the upper levels after leaf inserts/removes starting
        at index ``i``, reusing each level's unchanged prefix.  A node
        is reusable only if it was (and still is) a full pair whose
        children sit strictly below the first changed position — the
        min() guards the odd-promotion edge when level lengths change."""
        lv = 1
        while len(self._levels[lv - 1]) > 1:
            child = self._levels[lv - 1]
            old = self._levels[lv] if lv < len(self._levels) else []
            old_child = old_lens[lv - 1] if lv - 1 < len(old_lens) else 0
            safe = min(i // 2, old_child // 2, len(child) // 2)
            nxt = old[:safe]
            for j in range(safe, (len(child) + 1) // 2):
                if 2 * j + 1 < len(child):
                    nxt.append(self._node(child[2 * j], child[2 * j + 1]))
                else:
                    nxt.append(child[2 * j])
            if lv < len(self._levels):
                self._levels[lv] = nxt
            else:
                self._levels.append(nxt)
            i = safe
            lv += 1
        del self._levels[lv:]


__all__ = ["DeltaAttestor", "DeltaStats", "attestation_epoch",
           "pack_epoch", "unpack_epoch"]
