"""Lineage proofs: the Fig. 2 meta-chunk hash chain, externally
checkable (paper §3.2).

A version's uid is the content hash of its serialized meta chunk, which
embeds the uids it derives from (``bases``) — so the raw meta chunks
along a derivation path from a trusted head down to an ancestor ARE the
proof: ``verify_lineage`` re-hashes each chunk (one vectorized batch),
checks every link is named in its predecessor's ``bases``, and needs no
store.  The verifier learns each intermediate version's full, tamper-
evident record (type, value root, depth, context) for free — the storage
cannot splice in a version outside the history without breaking a hash.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

from ..core import chunk as ck
from ..core.fobject import FObject
from ..core.hashing import content_hash_many
from .membership import MAGIC, InvalidProof

_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")

LINEAGE = 4


@dataclass(frozen=True)
class LineageProof:
    raws: tuple[bytes, ...]        # meta chunk raws, head -> ancestor

    def to_bytes(self) -> bytes:
        parts = [bytes([MAGIC, LINEAGE]), _U16.pack(len(self.raws))]
        for raw in self.raws:
            parts.append(_U32.pack(len(raw)))
            parts.append(raw)
        return b"".join(parts)

    @classmethod
    def from_bytes(cls, data: bytes) -> "LineageProof":
        try:
            if data[0] != MAGIC or data[1] != LINEAGE:
                raise InvalidProof("bad magic")
            (n,) = _U16.unpack_from(data, 2)
            i = 4
            raws = []
            for _ in range(n):
                (ln,) = _U32.unpack_from(data, i); i += 4
                raws.append(bytes(data[i:i + ln])); i += ln
                if len(raws[-1]) != ln:
                    raise InvalidProof("truncated chunk")
            if i != len(data):
                raise InvalidProof("bad framing")
        except (struct.error, IndexError) as e:
            raise InvalidProof(f"unparseable proof: {e}") from e
        return cls(tuple(raws))

    @property
    def size(self) -> int:
        return len(self.to_bytes())

    @property
    def distance(self) -> int:
        return len(self.raws) - 1


def lineage_path(store, uid: bytes, ancestor: bytes,
                 max_depth: int = 1 << 30) -> list[bytes] | None:
    """Shortest uid path ``uid -> ... -> ancestor`` through ``bases``,
    walked with one batched ``get_many`` per DAG level (merge commits
    fan out); None when ancestor is not in the history."""
    uid, ancestor = bytes(uid), bytes(ancestor)
    parent: dict[bytes, bytes | None] = {uid: None}
    frontier = [uid]
    d = 0
    while frontier and d <= max_depth:
        if ancestor in parent:
            path = [ancestor]
            while parent[path[-1]] is not None:
                path.append(parent[path[-1]])
            return list(reversed(path))
        nxt: list[bytes] = []
        for u, raw in zip(frontier, store.get_many(frontier)):
            for b in FObject.deserialize(raw, u).bases:
                if b not in parent:
                    parent[b] = u
                    nxt.append(b)
        frontier = nxt
        d += 1
    return None


def prove_lineage(store, uid: bytes, ancestor: bytes) -> LineageProof:
    """Meta-chunk chain for ``ancestor`` in ``uid``'s history; raises
    KeyError when it is not an ancestor."""
    path = lineage_path(store, uid, ancestor)
    if path is None:
        raise KeyError(f"not an ancestor: {bytes(ancestor).hex()[:16]}")
    return LineageProof(tuple(store.get_many(path)))


def verify_lineage(head_uid: bytes, ancestor_uid: bytes,
                   proof) -> list[FObject]:
    """Stateless check that ``ancestor_uid`` is in ``head_uid``'s
    history.  Returns the authenticated FObjects head→ancestor (their
    count minus one is the derivation distance); raises InvalidProof."""
    p = (proof if isinstance(proof, LineageProof)
         else LineageProof.from_bytes(bytes(proof)))
    if not p.raws:
        raise InvalidProof("empty lineage")
    uids = content_hash_many(list(p.raws))
    if uids[0] != bytes(head_uid):
        raise InvalidProof("head uid mismatch")
    if uids[-1] != bytes(ancestor_uid):
        raise InvalidProof("ancestor uid mismatch")
    objs: list[FObject] = []
    for i, raw in enumerate(p.raws):
        try:
            if ck.chunk_type(raw) != ck.META:
                raise InvalidProof("not a meta chunk")
            obj = FObject.deserialize(raw, uids[i])
        except InvalidProof:
            raise
        except Exception as e:
            raise InvalidProof(f"malformed meta chunk: {e}") from e
        if i + 1 < len(p.raws) and uids[i + 1] not in obj.bases:
            raise InvalidProof("hash chain broken: link not in bases")
        objs.append(obj)
    return objs
