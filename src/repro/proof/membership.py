"""Merkle membership & absence proofs over the POS-Tree (paper §3.2,
§4.3; UStore's verifiable access made a first-class verb).

A proof carries the raw chunk chain root→leaf (full index nodes — their
pattern-split metadata *is* the audit path: child cids, subtree counts,
max keys) plus the claimed item.  ``verify_member`` recomputes every cid
bottom-up with **no store access**: a verifier holding only a trusted
root cid accepts the claim iff the hash chain closes and the claimed
item sits where the navigation metadata says it must.

Absence proofs (sorted kinds only) reuse the same chain: the verifier
re-derives the unique leaf that could contain the key (first max-key
covering it at every level) and checks neighbor-entry enclosure —
predecessor < key < successor inside that hash-authenticated leaf (the
reported enclosure is leaf-local; see Claim.enclosure).

Batch verification (``verify_member_many``) is where the Pallas path
pays off: distinct nodes across all proofs are hashed with ONE
``content_hash_many`` dispatch (one ``fphash`` launch), and shared index
nodes/leaves are decoded once — an auditor checking thousands of proofs
from the same tree does O(distinct nodes) work, not O(proofs x height).
"""
from __future__ import annotations

import bisect
import struct
from collections import OrderedDict
from dataclasses import dataclass

from ..core import chunk as ck
from ..core.hashing import content_hash_many, current_hash
from ..core.postree import SORTED_KINDS, child_by_key, child_by_pos
from ..errors import InvalidProof  # noqa: F401  re-exported: historical home

_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")

MAGIC = 0xFB
MEMBER_BY_POS = 1
MEMBER_BY_KEY = 2
ABSENCE = 3

_CHUNK_KINDS = (ck.BLOB, ck.LIST, ck.SET, ck.MAP)


@dataclass(frozen=True)
class Claim:
    """What a successfully verified proof establishes."""
    mode: int                 # MEMBER_BY_POS / MEMBER_BY_KEY / ABSENCE
    kind: int                 # chunk kind of the proven tree
    pos: int                  # item position (MEMBER_BY_POS)
    key: bytes                # item key (key modes)
    value: bytes              # item bytes (member modes)
    enclosure: tuple[bytes | None, bytes | None] | None = None
    # ABSENCE: the authenticated (predecessor, successor) neighbors
    # WITHIN the candidate leaf.  A None side means the absent key falls
    # beyond this leaf's key range — the global neighbor then lives in
    # an adjacent leaf the proof does not carry (range proofs are the
    # ROADMAP follow-on).  The absence claim itself is always global:
    # navigation pins the unique leaf that could hold the key.


@dataclass(frozen=True)
class MembershipProof:
    mode: int
    kind: int
    pos: int
    key: bytes
    value: bytes
    nodes: tuple[bytes, ...]   # index node raws, root-down
    leaf: bytes                # leaf chunk raw

    # ------------------------------------------------------------- wire
    def to_bytes(self) -> bytes:
        parts = [bytes([MAGIC, self.mode, self.kind]),
                 _U64.pack(self.pos),
                 _U32.pack(len(self.key)), self.key,
                 _U32.pack(len(self.value)), self.value,
                 _U16.pack(len(self.nodes))]
        for raw in self.nodes:
            parts.append(_U32.pack(len(raw)))
            parts.append(raw)
        parts.append(_U32.pack(len(self.leaf)))
        parts.append(self.leaf)
        return b"".join(parts)

    @classmethod
    def from_bytes(cls, data: bytes) -> "MembershipProof":
        try:
            if data[0] != MAGIC:
                raise InvalidProof("bad magic")
            mode, kind = data[1], data[2]
            i = 3
            (pos,) = _U64.unpack_from(data, i); i += 8
            (kl,) = _U32.unpack_from(data, i); i += 4
            key = bytes(data[i:i + kl]); i += kl
            if len(key) != kl:
                raise InvalidProof("truncated key")
            (vl,) = _U32.unpack_from(data, i); i += 4
            value = bytes(data[i:i + vl]); i += vl
            if len(value) != vl:
                raise InvalidProof("truncated value")
            (nn,) = _U16.unpack_from(data, i); i += 2
            nodes = []
            for _ in range(nn):
                (ln,) = _U32.unpack_from(data, i); i += 4
                nodes.append(bytes(data[i:i + ln])); i += ln
                if len(nodes[-1]) != ln:
                    raise InvalidProof("truncated node")
            (ln,) = _U32.unpack_from(data, i); i += 4
            leaf = bytes(data[i:i + ln]); i += ln
            if len(leaf) != ln or i != len(data):
                raise InvalidProof("bad framing")
        except (struct.error, IndexError) as e:
            raise InvalidProof(f"unparseable proof: {e}") from e
        return cls(mode, kind, pos, key, value, tuple(nodes), leaf)

    @property
    def size(self) -> int:
        return len(self.to_bytes())

    @property
    def height(self) -> int:
        return len(self.nodes) + 1


# ---------------------------------------------------------------- caching

class ProofCache:
    """Per-root audit-path cache (ROADMAP "proof caching"): a proof for
    (root cid, item) is immutable because the root is content-addressed
    — mutating the tree yields a NEW root, so a stale entry is
    unreachable by construction and invalidation is free.  Eviction is
    whole-root LRU: hot trees keep their paths resident, cold roots age
    out with every proof under them."""

    def __init__(self, max_roots: int = 128,
                 max_proofs_per_root: int = 4096):
        self.max_roots = max_roots
        self.max_proofs_per_root = max_proofs_per_root
        self._roots: OrderedDict[bytes, dict] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, root: bytes, req) -> "MembershipProof | None":
        entry = self._roots.get(root)
        if entry is None:
            self.misses += 1
            return None
        self._roots.move_to_end(root)
        proof = entry.get(req)
        if proof is None:
            self.misses += 1
        else:
            self.hits += 1
        return proof

    def store(self, root: bytes, req, proof: "MembershipProof") -> None:
        entry = self._roots.get(root)
        if entry is None:
            entry = self._roots[root] = {}
            while len(self._roots) > self.max_roots:
                self._roots.popitem(last=False)
        if len(entry) < self.max_proofs_per_root:
            entry[req] = proof
        self._roots.move_to_end(root)

    def clear(self) -> None:
        self._roots.clear()


class VerifyMemo:
    """Persistent decoded-node memo for ``verify_member_many`` across
    rounds (ROADMAP: the batched verifier's per-call dedup "could
    persist across audit rounds").  Content addressing makes the memo
    coherent: the digest/decoding of a raw chunk never changes — except
    when the active cid hash is swapped, which clears it wholesale.
    Bounded: when the node table outgrows ``max_nodes`` after a round
    it is reset (audit batches re-warm it in one dispatch)."""

    def __init__(self, max_nodes: int = 8192):
        self.max_nodes = max_nodes
        self.digest: dict[bytes, bytes] = {}
        self.index: dict[tuple[bytes, int], list] = {}
        self.leaf: dict[tuple[bytes, int], object] = {}
        self.hits = 0
        self.misses = 0
        self._hash_fn = current_hash()

    def refresh(self) -> None:
        cur = current_hash()
        if cur is not self._hash_fn:
            self.clear()
            self._hash_fn = cur

    def add_digests(self, raws: list[bytes]) -> None:
        """Hash the raws not yet memoized — ONE batched dispatch."""
        fresh = [r for r in raws if r not in self.digest]
        self.hits += len(raws) - len(fresh)
        self.misses += len(fresh)
        if fresh:
            self.digest.update(zip(fresh, content_hash_many(fresh)))

    def trim(self) -> None:
        if len(self.digest) > self.max_nodes:
            self.clear()

    def clear(self) -> None:
        self.digest.clear()
        self.index.clear()
        self.leaf.clear()


# ------------------------------------------------------------------ prove

def prove_member(tree, *, pos: int | None = None,
                 key: bytes | None = None) -> MembershipProof:
    """Audit path + claim for item ``pos`` (any kind) or sorted-kind
    ``key``.  The claimed value is the serialized element: a single byte
    for Blob, the element for List/Set, ``pack_kv(k, v)`` for Map by
    position, the mapped value for Map by key."""
    if (pos is None) == (key is None):
        raise ValueError("exactly one of pos/key")
    if key is not None:
        if tree.kind not in SORTED_KINDS:
            raise ValueError("key proofs need a sorted kind (Set/Map)")
        if key == b"":
            raise ValueError("empty keys must be proven by position")
        found, _, _, gpos = tree.find_key(key)
        if not found:
            raise KeyError(key)
        nodes, leaf = tree.audit_path(key=key)
        value = b""
        if tree.kind == ck.MAP:
            for k, v in ck.unpack_kv_stream(ck.chunk_payload(leaf)):
                if k == key:
                    value = v
                    break
        return MembershipProof(MEMBER_BY_KEY, tree.kind, 0, key, value,
                               tuple(nodes), leaf)
    if not (0 <= pos < tree.total_count):
        raise IndexError(pos)
    nodes, leaf = tree.audit_path(pos=pos)
    el = tree.get_item(pos)
    if tree.kind == ck.BLOB:
        value = bytes([int(el)])
    elif tree.kind == ck.MAP:
        value = ck.pack_kv(*el)
    else:
        value = bytes(el)
    return MembershipProof(MEMBER_BY_POS, tree.kind, pos, b"", value,
                           tuple(nodes), leaf)


def prove_absence(tree, key: bytes) -> MembershipProof:
    """Negative proof (sorted kinds): the unique leaf that could contain
    ``key``, with enclosure checked by the verifier."""
    if tree.kind not in SORTED_KINDS:
        raise ValueError("absence proofs need a sorted kind (Set/Map)")
    if key == b"":
        raise ValueError("cannot prove absence of the empty key")
    found, _, _, _ = tree.find_key(key)
    if found:
        raise KeyError(f"present: {key!r}")
    nodes, leaf = tree.audit_path(key=key)
    return MembershipProof(ABSENCE, tree.kind, 0, key, b"",
                           tuple(nodes), leaf)


# ----------------------------------------------------------------- verify

def _leaf_items(kind: int, leaf_raw: bytes):
    payload = ck.chunk_payload(leaf_raw)
    if kind == ck.BLOB:
        return payload
    if kind == ck.MAP:
        return ck.unpack_kv_stream(payload)
    return ck.unpack_lv_stream(payload)


def _decode_index(raw: bytes, kind: int):
    t = ck.chunk_type(raw)
    sorted_kind = kind in SORTED_KINDS
    if t != (ck.SINDEX if sorted_kind else ck.UINDEX):
        raise InvalidProof(f"wrong index node type {t}")
    dec = ck.decode_sindex if sorted_kind else ck.decode_uindex
    return dec(ck.chunk_payload(raw))


def _check_claim(p: MembershipProof, items, pos: int) -> Claim:
    """Leaf-level claim check; ``pos`` is local after navigation."""
    if p.mode == MEMBER_BY_POS:
        if not (0 <= pos < len(items)):
            raise InvalidProof("position outside leaf")
        el = items[pos]
        if p.kind == ck.BLOB:
            got = bytes([el])
        elif p.kind == ck.MAP:
            got = ck.pack_kv(*el)
        else:
            got = bytes(el)
        if got != p.value:
            raise InvalidProof("claimed element mismatch")
        return Claim(p.mode, p.kind, p.pos, b"", p.value)
    keys = [kv[0] for kv in items] if p.kind == ck.MAP else list(items)
    if p.mode == MEMBER_BY_KEY:
        if p.key not in keys:
            raise InvalidProof("key not in authenticated leaf")
        if p.kind == ck.MAP:
            got = dict(items)[p.key]
        else:
            got = b""
        if got != p.value:
            raise InvalidProof("claimed value mismatch")
        return Claim(p.mode, p.kind, 0, p.key, p.value)
    # ABSENCE: enclosure inside the unique candidate leaf
    if p.key in keys:
        raise InvalidProof("key present — not absent")
    j = bisect.bisect_left(keys, p.key)
    pred = keys[j - 1] if j > 0 else None
    succ = keys[j] if j < len(keys) else None
    return Claim(p.mode, p.kind, 0, p.key, b"", (pred, succ))


def _verify_one(root_cid: bytes, p: MembershipProof, hash_of,
                decode_index, leaf_items) -> Claim:
    """Shared chain walk; ``hash_of``/``decode_index``/``leaf_items``
    are injected so the batched verifier can memoize across proofs."""
    if p.mode not in (MEMBER_BY_POS, MEMBER_BY_KEY, ABSENCE):
        raise InvalidProof(f"unknown mode {p.mode}")
    if p.kind not in _CHUNK_KINDS:
        raise InvalidProof(f"not a chunkable kind: {p.kind}")
    if p.mode == MEMBER_BY_POS:
        if p.key != b"":
            raise InvalidProof("positional proof carries a key")
    else:
        if p.kind not in SORTED_KINDS:
            raise InvalidProof("key proof on an unsorted kind")
        if p.pos != 0 or p.key == b"":
            raise InvalidProof("key proof framing")
        if p.mode == ABSENCE and p.value != b"":
            raise InvalidProof("absence proof carries a value")
    try:
        expected = bytes(root_cid)
        pos = p.pos
        for raw in p.nodes:
            if hash_of(raw) != expected:
                raise InvalidProof("hash chain broken at index node")
            entries = decode_index(raw)
            if not entries:
                raise InvalidProof("empty index node")
            if p.mode == MEMBER_BY_POS:
                try:
                    child, base = child_by_pos(entries, pos)
                except IndexError:
                    raise InvalidProof("position outside subtree") from None
                pos -= base
            else:
                child = child_by_key(entries, p.key)
            expected = entries[child].cid
        if hash_of(p.leaf) != expected:
            raise InvalidProof("hash chain broken at leaf")
        if ck.chunk_type(p.leaf) != p.kind:
            raise InvalidProof("leaf kind mismatch")
        return _check_claim(p, leaf_items(p.leaf), pos)
    except InvalidProof:
        raise
    except Exception as e:          # malformed node/leaf payloads
        raise InvalidProof(f"malformed proof: {e}") from e


def _as_proof(proof) -> MembershipProof:
    return (proof if isinstance(proof, MembershipProof)
            else MembershipProof.from_bytes(bytes(proof)))


def verify_member(root_cid: bytes, proof) -> Claim:
    """Stateless single-proof verification: one vectorized hash batch
    over this proof's nodes.  Raises InvalidProof; returns the Claim."""
    p = _as_proof(proof)
    raws = list(p.nodes) + [p.leaf]
    digests = dict(zip(map(id, raws), content_hash_many(raws)))
    return _verify_one(root_cid, p, lambda r: digests[id(r)],
                       lambda r: _decode_index(r, p.kind),
                       lambda r: _leaf_items(p.kind, r))


def verify_member_many(items, *, strict: bool = True,
                       memo: VerifyMemo | None = None):
    """Batched stateless verification of ``[(root_cid, proof), ...]``.

    All *distinct* node/leaf raws across every proof are hashed with one
    ``content_hash_many`` call (one Pallas ``fphash`` launch on the TPU
    path) and decoded/parsed once — shared upper index nodes cost O(1)
    across the whole batch.  ``strict`` raises on the first bad proof;
    otherwise bad entries come back as the InvalidProof instance.

    ``memo`` (a VerifyMemo) persists the digest/decoded-node tables
    across calls: an auditor verifying round after round against the
    same trees only hashes nodes it has never seen."""
    proofs = [(bytes(rc), _as_proof(pr)) for rc, pr in items]
    distinct: dict[bytes, None] = {}
    for _, p in proofs:
        for raw in p.nodes:
            distinct[raw] = None
        distinct[p.leaf] = None
    raws = list(distinct)
    if memo is not None:
        memo.refresh()
        memo.add_digests(raws)
        digest = memo.digest
        index_cache = memo.index
        leaf_cache = memo.leaf
    else:
        digest = dict(zip(raws, content_hash_many(raws)))
        index_cache = {}
        leaf_cache = {}

    def decode_index_cached(kind):
        def dec(raw):
            k = (raw, kind)
            if k not in index_cache:
                index_cache[k] = _decode_index(raw, kind)
            return index_cache[k]
        return dec

    def leaf_items_cached(kind):
        def items_of(raw):
            k = (raw, kind)
            if k not in leaf_cache:
                leaf_cache[k] = _leaf_items(kind, raw)
            return leaf_cache[k]
        return items_of

    out = []
    for i, (rc, p) in enumerate(proofs):
        try:
            out.append(_verify_one(rc, p, digest.__getitem__,
                                   decode_index_cached(p.kind),
                                   leaf_items_cached(p.kind)))
        except InvalidProof as e:
            if strict:
                raise InvalidProof(f"proof {i}: {e}") from e
            out.append(e)
    if memo is not None:
        memo.trim()
    return out
