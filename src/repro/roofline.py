"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh) cell, TPU v5e constants:

    compute    = HLO_FLOPs_per_chip / 197e12        (bf16 peak / chip)
    memory     = HLO_bytes_per_chip / 819e9         (HBM bw / chip)
    collective = collective_bytes_per_chip / 50e9   (ICI bw / link)

XLA's ``compiled.cost_analysis()`` visits each computation ONCE, so
scan-over-layers while-loops are undercounted by their trip count.  This
module re-derives the terms with a loop-aware walk over the optimized
per-device HLO text:

  * dot FLOPs  = 2 * result_elements * contraction_size, from the dot's
    operand shapes + lhs_contracting_dims;
  * HBM bytes  ~ 2 * result bytes of every materializing op (fusion, dot,
    copy, convert, collective...) — a write+read proxy for traffic;
  * collective bytes = result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute ops;
  * while bodies multiply by the trip count recovered from the loop
    condition's comparison constant (scan lowers to counted whiles);
    nesting multiplies.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) gives the "useful
compute" yardstick; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat
recompute, attention-flash double-counting and dispatch overhead.
"""
from __future__ import annotations

import argparse
import json
import re
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 / chip, TPU v5e
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
               "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
               "u64": 8, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_SKIP_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "iota(")


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return None
    shape = [int(d) for d in dims.split(",") if d]
    return dt, shape


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


class HLOCost:
    """Loop-aware flops/bytes/collective census of one HLO module."""

    def __init__(self, hlo: str):
        self.comps: dict[str, dict] = {}
        self._parse(hlo)
        self.entry = self._find_entry(hlo)

    def _parse(self, hlo: str) -> None:
        cur = None
        symtab: dict[str, tuple] = {}
        for raw in hlo.splitlines():
            if raw and not raw[0].isspace() and "{" in raw and "(" in raw:
                head = raw.split("(")[0].strip()
                name = head.replace("ENTRY", "").strip().lstrip("%")
                cur = name
                self.comps[cur] = {"flops": 0, "bytes": 0, "coll": 0,
                                   "coll_ops": {}, "whiles": [],
                                   "calls": [], "max_const": 0,
                                   "fusion_calls": [],
                                   "root_dus_update": None,
                                   "consts": {}, "root_ops": []}
                # computation params carry shapes in the header
                symtab = {}
                for pm in re.finditer(r"%?([\w\.\-]+):\s*(\w+\[[\d,]*\])",
                                      raw):
                    sh = _first_shape(pm.group(2))
                    if sh:
                        symtab[pm.group(1)] = sh
                continue
            if cur is None:
                continue
            line = raw.strip()
            if not line or line.startswith("//") or line.startswith("ROOT %tuple"):
                pass
            c = self.comps[cur]
            mcn = re.match(r"%?([\w\.\-]+) = s32\[\] constant\((\d+)\)",
                           line)
            if mcn:
                c["consts"][mcn.group(1)] = int(mcn.group(2))
            mc = re.findall(r"s32\[\] constant\((\d+)\)", line)
            for v in mc:
                c["max_const"] = max(c["max_const"], int(v))
            if line.startswith("ROOT"):
                c["root_ops"] = re.findall(r"%([\w\.\-]+)[,)]", line)
            mw = re.search(r"while\(.*?condition=%?([\w\.\-]+), "
                           r"body=%?([\w\.\-]+)", line)
            if mw:
                c["whiles"].append((mw.group(1), mw.group(2)))
                continue
            mcall = re.search(r"\b(?:call|async-start)\(.*?to_apply=%?"
                              r"([\w\.\-]+)", line)
            if mcall:
                c["calls"].append(mcall.group(1))
            mcond = re.findall(r"(?:true_computation|false_computation|"
                               r"branch_computations=\{)%?([\w\.\-]+)", line)
            for t in mcond:
                c["calls"].append(t.rstrip("},"))
            if "=" not in line:
                continue
            lhs, rhs = line.split("=", 1)
            rhs = rhs.strip()
            opname = lhs.strip().lstrip("%")
            # result shape opens the rhs: "f32[512,50304]{1,0} dot(..."
            shape_txt = rhs.split("(")[0]
            res = _first_shape(shape_txt)
            if res is not None:
                symtab[opname] = res
            if any(f" {s}" in f" {rhs}" for s in _SKIP_OPS):
                continue  # shapes already recorded in symtab above
            res_bytes = _all_shapes_bytes(shape_txt)
            for op in COLLECTIVES:
                if re.search(rf"\b{op}(-start)?\(", rhs):
                    c["coll"] += res_bytes
                    c["coll_ops"][op] = c["coll_ops"].get(op, 0) + res_bytes
                    break
            if " dot(" in f" {rhs}":
                c["flops"] += self._dot_flops(res, rhs, symtab)
            # in-place buffer updates: count the update, not the buffer
            mdus = re.search(r"dynamic-update-slice\(%?[\w\.\-]+, "
                             r"%?([\w\.\-]+)", rhs)
            if mdus is not None:
                upd = symtab.get(mdus.group(1))
                if upd is not None:
                    n = 1
                    for d in upd[1]:
                        n *= d
                    res_bytes = n * DTYPE_BYTES[upd[0]]
                if line.startswith("ROOT"):
                    c["root_dus_update"] = res_bytes
            mfus = re.search(r"fusion\(.*?calls=%?([\w\.\-]+)", rhs)
            if mfus is not None:
                c["fusion_calls"].append((mfus.group(1), res_bytes))
            c["bytes"] += 2 * res_bytes  # write + read-back proxy

    @staticmethod
    def _dot_flops(res, rhs: str, symtab: dict) -> int:
        if res is None:
            return 0
        out_elems = 1
        for d in res[1]:
            out_elems *= d
        # contraction size from the lhs OPERAND's recorded shape
        mops = re.search(r"dot\(%?([\w\.\-]+),", rhs)
        mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
        if not mops or not mdims or mops.group(1) not in symtab:
            return 2 * out_elems  # unresolvable operand: K=1 fallback
        lhs_shape = symtab[mops.group(1)][1]
        k = 1
        for i in [int(x) for x in mdims.group(1).split(",") if x]:
            if i < len(lhs_shape):
                k *= lhs_shape[i]
        return 2 * out_elems * k

    def _trip_count(self, cond: str) -> int:
        """Constant operand of the condition's ROOT comparison; fallback
        to the max constant in the condition computation."""
        c = self.comps.get(cond)
        if c is None:
            return 1
        for op in c.get("root_ops", []):
            if op in c["consts"]:
                return c["consts"][op]
        return c.get("max_const", 1)

    def _find_entry(self, hlo: str) -> str:
        m = re.search(r"^ENTRY %?([\w\.\-]+)", hlo, re.M)
        return m.group(1) if m else next(iter(self.comps))

    def totals(self) -> dict:
        memo: dict[str, dict] = {}

        def walk(name: str) -> dict:
            if name in memo:
                return memo[name]
            c = self.comps.get(name)
            if c is None:
                return {"flops": 0, "bytes": 0, "coll": 0, "coll_ops": {}}
            memo[name] = {"flops": 0, "bytes": 0, "coll": 0, "coll_ops": {}}
            tot = {"flops": c["flops"], "bytes": c["bytes"],
                   "coll": c["coll"], "coll_ops": dict(c["coll_ops"])}
            # fusions whose root is an in-place DUS: swap buffer-size bytes
            # for update-size bytes
            for called, res_b in c["fusion_calls"]:
                upd = self.comps.get(called, {}).get("root_dus_update")
                if upd is not None:
                    tot["bytes"] += 2 * (upd - res_b)
            for callee in c["calls"]:
                sub = walk(callee)
                tot["flops"] += sub["flops"]
                tot["bytes"] += sub["bytes"]
                tot["coll"] += sub["coll"]
                for k, v in sub["coll_ops"].items():
                    tot["coll_ops"][k] = tot["coll_ops"].get(k, 0) + v
            for cond, body in c["whiles"]:
                trip = max(self._trip_count(cond), 1)
                for sub_name in (cond, body):
                    sub = walk(sub_name)
                    tot["flops"] += trip * sub["flops"]
                    tot["bytes"] += trip * sub["bytes"]
                    tot["coll"] += trip * sub["coll"]
                    for k, v in sub["coll_ops"].items():
                        tot["coll_ops"][k] = (tot["coll_ops"].get(k, 0)
                                              + trip * v)
            memo[name] = tot
            return tot

        return walk(self.entry)


def analyze_hlo(hlo: str) -> dict:
    t = HLOCost(hlo).totals()
    return {"flops_per_device": t["flops"],
            "hbm_bytes_per_device": t["bytes"],
            "collective_bytes_per_device": t["coll"],
            "collective_bytes_by_op": t["coll_ops"]}


def roofline_terms(cell: dict) -> dict:
    """cell: one dry-run JSON record (launch/dryrun.py)."""
    la = cell.get("loop_aware", {})
    flops = la.get("flops_per_device") or cell["cost"]["flops_per_device"]
    bts = la.get("hbm_bytes_per_device") or cell["cost"]["bytes_per_device"]
    coll = la.get("collective_bytes_per_device",
                  cell["collectives"]["total_bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    # MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D for inference
    n = (cell["params_active"] if cell["params_active"] else
         cell["params_total"])
    D = cell["tokens_per_step"]
    mf = (6 if cell["kind"] == "train" else 2) * n * D
    mf_per_dev = mf / cell["n_chips"]
    return dict(terms, dominant=dom.replace("_s", ""),
                model_flops_per_device=mf_per_dev,
                useful_ratio=(mf_per_dev / flops) if flops else 0.0,
                roofline_fraction=(mf_per_dev / PEAK_FLOPS)
                / max(compute_s, memory_s, coll_s)
                if max(compute_s, memory_s, coll_s) > 0 else 0.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(Path(__file__).resolve().parents[2]
                                         / "experiments" / "dryrun"))
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()
    rows = []
    for f in sorted(Path(args.dir).glob("*.json")):
        cell = json.loads(f.read_text())
        if cell["mesh"] != args.mesh or cell.get("variant",
                                                 "base") != args.variant:
            continue
        t = roofline_terms(cell)
        rows.append((cell, t))
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'dom':>7s} {'useful':>7s} {'roofline':>9s}"
           f" {'peakGB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for cell, t in rows:
        print(f"{cell['arch']:22s} {cell['shape']:12s} "
              f"{t['compute_s']:10.4g} {t['memory_s']:10.4g} "
              f"{t['collective_s']:10.4g} {t['dominant']:>7s} "
              f"{t['useful_ratio']:7.3f} {t['roofline_fraction']:9.3f} "
              f"{cell['memory']['peak_per_device_gb']:7.2f}")


if __name__ == "__main__":
    main()
