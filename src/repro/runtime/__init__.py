from .controller import (SimulatedFailure, TrainController, run_resilient)

__all__ = ["TrainController", "SimulatedFailure", "run_resilient"]
