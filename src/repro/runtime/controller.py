"""Fault-tolerant training controller.

Production behaviors, all exercised by tests on CPU-scale configs:

  * periodic ForkBase checkpoints (cheap: chunk-dedup makes the marginal
    checkpoint cost proportional to what actually changed);
  * failure injection + restart: on any step failure the controller
    restores the last committed version and replays — the data pipeline is
    positioned from the checkpoint's step, so training is bit-deterministic
    across restarts;
  * fork-on-conflict resolution: when several pod controllers race commits
    of the same run (elastic events, partitioned DCN), the UB-table holds
    every head; the controller resolves by data progress and continues on
    the merged head;
  * elastic restarts: the checkpoint is mesh-agnostic; `remesh` restores
    onto whatever devices survive;
  * straggler mitigation for checkpoint construction: POS-Tree chunking is
    delegated to the least-loaded host (paper §4.6.1) via cluster.Cluster.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..ckpt import CheckpointStore


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailurePlan:
    """Inject failures at the given global steps (once each)."""
    at_steps: set = field(default_factory=set)
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class TrainController:
    def __init__(self, step_fn, init_state, dataset, ckpt: CheckpointStore,
                 branch: str = "run", ckpt_every: int = 10,
                 failure_plan: FailurePlan | None = None):
        self.step_fn = step_fn
        self.state = init_state
        self.dataset = dataset
        self.ckpt = ckpt
        self.branch = branch
        self.ckpt_every = ckpt_every
        self.failures = failure_plan or FailurePlan()
        self.step = 0
        self.restarts = 0
        self.metrics_log: list = []
        # initial commit so restarts always have a base
        self.ckpt.save(self.state, branch, step=0)

    # ------------------------------------------------------------ loop
    def run(self, n_steps: int, max_restarts: int = 10):
        while self.step < n_steps:
            try:
                self._run_segment(n_steps)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                self._restore()
        return self.state

    def _run_segment(self, n_steps: int):
        import jax.numpy as jnp
        while self.step < n_steps:
            self.failures.maybe_fail(self.step)
            batch = {k: jnp.asarray(v)
                     for k, v in self.dataset.batch_at(self.step).items()}
            self.state, m = self.step_fn(self.state, batch)
            self.metrics_log.append((self.step, float(m["loss"])))
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self.ckpt.save(self.state, self.branch, step=self.step)

    def _restore(self):
        self.state = self.ckpt.restore(self.state, self.branch)
        head = self.ckpt.db.get(self.ckpt.key, self.branch)
        self.step = self.ckpt.step_of(head.uid)

    # ------------------------------------------------ elastic / forking
    def remesh(self, mesh, specs):
        """Elastic restart: reload the current branch head onto a new
        mesh/sharding (device count changed)."""
        self.state = self.ckpt.restore(self.state, self.branch, mesh=mesh,
                                       specs=specs)
        return self.state

    def fork_experiment(self, new_branch: str, from_step: int | None = None):
        """FoD: warm-start a new experiment branch from any version."""
        if from_step is None:
            self.ckpt.fork(self.branch, new_branch)
        else:
            for uid, meta in self.ckpt.history(self.branch, 1 << 20):
                if meta.get("step") == from_step:
                    self.ckpt.fork(uid, new_branch)
                    return
            raise KeyError(f"no checkpoint at step {from_step}")


def run_resilient(step_fn, init_state, dataset, *, n_steps: int,
                  fail_at=(), ckpt_every: int = 10,
                  db=None) -> TrainController:
    ckpt = CheckpointStore(db) if db is not None else CheckpointStore()
    ctl = TrainController(step_fn, init_state, dataset, ckpt,
                          ckpt_every=ckpt_every,
                          failure_plan=FailurePlan(set(fail_at)))
    ctl.run(n_steps)
    return ctl
