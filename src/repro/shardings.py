"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP mapping onto the
production mesh (launch/mesh.py).

Policy (DESIGN.md §6):
  * batch       -> ('pod','data')  (DP; dropped if batch doesn't divide)
  * heads/ff/
    dinner/...  -> 'model'          (TP; dropped when the dim doesn't
                                     divide — e.g. xlstm's 4 heads stay
                                     replicated and only the vocab is TP)
  * experts     -> 'model'          (EP via shard_map, models/moe.py)
  * cache_seq   -> 'model'          (decode KV caches shard on sequence so
                                     GQA archs with few KV heads still
                                     distribute; GSPMD turns the cache
                                     update into a masked local write and
                                     the softmax reductions into psums)
  * vocab       -> 'model'          (embed d-dim + unembed vocab-dim; vocab
                                     padded up to a multiple of the axis)
  * FSDP (qwen1.5-110b): parameter d_model dim additionally sharded over
    'data' (ZeRO-3); XLA all-gathers per layer inside the scan.
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .models.layers import padded_heads


class Sharding:
    """Resolves logical axis names to mesh axes for one (cfg, batch)."""

    def __init__(self, mesh: Mesh | None, cfg, global_batch: int | None = None):
        self.mesh = mesh
        self.cfg = cfg
        if mesh is None:
            self.dp_axes: tuple = ()
            self.tp = 1
            self.dp_size = 1
            self.rules: dict = {}
            return
        names = mesh.axis_names
        self.dp_axes = tuple(a for a in ("pod", "data") if a in names)
        self.tp = mesh.shape["model"]
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp_axes]))
        batch_ok = (global_batch is None
                    or global_batch % max(1, self.dp_size) == 0)
        tp = self.tp

        def tp_if(n):  # shard over model iff divisible
            return "model" if n and n % tp == 0 else None

        cfg_hp = padded_heads(cfg, tp)
        # xlstm: no weight dim divides the model axis, so 'model' would sit
        # idle — fold it into the batch axes (pure DP over all chips).
        batch_axes: tuple | None = self.dp_axes if batch_ok else None
        self.batch_uses_model = False
        if cfg.family == "ssm" and global_batch is not None:
            for cand in (self.dp_axes + ("model",), self.dp_axes):
                n = int(np.prod([mesh.shape[a] for a in cand]))
                if cand and global_batch % n == 0:
                    batch_axes = cand
                    self.batch_uses_model = "model" in cand
                    break
        self.rules = {
            "batch": batch_axes if batch_axes else None,
            "seq": None,
            "cache_seq": "model",
            "heads": tp_if(cfg_hp),
            "kv_heads": tp_if(cfg.n_kv_heads),
            "heads_flat": tp_if(cfg_hp * cfg.dh) if tp_if(cfg_hp) else None,
            "kv_flat": tp_if(cfg.n_kv_heads * cfg.dh)
            if tp_if(cfg.n_kv_heads) else None,
            "ff": tp_if(cfg.d_ff),
            "shared_ff": tp_if(cfg.n_shared_experts * cfg.moe_d_ff),
            "vocab": None if self.batch_uses_model else "model",
            "dmodel_tp": None if self.batch_uses_model
            else tp_if(cfg.d_model),
            "dinner": tp_if(cfg.d_inner) if cfg.ssm_state else None,
            "ssm_heads": tp_if(cfg.n_ssm_heads) if cfg.ssm_state else None,
            "experts": tp_if(cfg.n_experts),
            "fsdp": "data" if cfg.fsdp else None,
            # Megatron-style sequence parallelism: FSDP archs keep the
            # residual stream sequence-sharded over 'model' between layers
            # (norms/residual adds run sharded; GSPMD gathers at qkv/mlp
            # entry and reduce-scatters after the row-parallel matmuls) —
            # scan carries shrink 16x, enabling small microbatch counts
            "seq_res": "model" if cfg.fsdp else None,
        }

    @property
    def padded_vocab(self) -> int:
        v = self.cfg.vocab
        tp = self.tp if self.mesh is not None else 16
        return -(-v // tp) * tp

    def spec(self, *names) -> P:
        return P(*[self.rules.get(n, None) if isinstance(n, str) else n
                   for n in names])

    def constrain(self, x, *names):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*names)))

    # ---------------- parameter specs ----------------
    def _leaf_spec(self, path: str, leaf) -> P:
        r = self.rules
        fsdp = r["fsdp"]
        parts = path.split("/")
        name = parts[-1]
        stacked = parts[0] in ("layers", "groups")
        pre = (None,) if stacked else ()
        nd = getattr(leaf, "ndim", 0) - len(pre)

        def sp(*axes):
            return P(*pre, *axes)

        if name == "embed":
            return P(fsdp, r["dmodel_tp"])
        if name == "unembed":
            return P(fsdp, r["vocab"])
        if self.cfg.family == "ssm":       # xlstm: DP + vocab TP only
            return sp(*([None] * nd))
        if nd == 3 and name in ("w_in", "w_gate", "w_out"):
            # routed expert stacks: EP over model, FSDP over data on the
            # contracted dim (all-gathered inside the shard_map body)
            return sp(r["experts"], fsdp, None)
        col = {"wq": r["heads_flat"], "wk": r["kv_flat"], "wv": r["kv_flat"],
               "w_in": r["ff"], "w_gate": r["ff"],
               "shared_w_in": r["shared_ff"], "shared_w_gate": r["shared_ff"],
               "in_z": r["dinner"], "in_x": r["dinner"],
               "in_dt": r["ssm_heads"]}
        if name in col:
            return sp(fsdp, col[name])
        row = {"wo": r["heads_flat"], "w_out": r["ff"],
               "shared_w_out": r["shared_ff"], "out_proj": r["dinner"]}
        if name in row:
            return sp(row[name], fsdp)
        if name == "bq":
            return sp(r["heads_flat"])
        if name == "conv_w":
            return sp(None, r["dinner"])
        if name in ("A_log", "D", "dt_bias"):
            return sp(r["ssm_heads"])
        if name == "norm" and self.cfg.ssm_state:
            return sp(r["dinner"])
        return sp(*([None] * nd))          # norms, router, biases, stubs

    def param_specs(self, params):
        def walk(tree, path=""):
            if isinstance(tree, dict):
                return {k: walk(v, f"{path}/{k}" if path else k)
                        for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                t = [walk(v, f"{path}/{i}") for i, v in enumerate(tree)]
                return type(tree)(t)
            return self._leaf_spec(path, tree)
        return walk(params)

    def batch_specs(self, batch_tree):
        """Inputs: dim0 = global batch over DP axes."""
        return jax.tree.map(
            lambda x: self.spec("batch", *([None] * (x.ndim - 1))),
            batch_tree)

    def cache_specs(self, cache_tree):
        """Decode caches: KV caches shard (layer, batch, seq->model, ...);
        recurrent states shard batch only."""
        r = self.rules

        cfg = self.cfg

        def leaf(path, x):
            name = path.split("/")[-1]
            if name in ("k", "v", "attn_k", "attn_v"):
                return P(None, r.get("batch"), "model", None, None)
            if name in ("ks", "vs"):
                return P(None, r.get("batch"), "model", None)
            if name == "conv":
                return P(None, r.get("batch"), None, r.get("dinner"))
            if name == "ssd":
                return P(None, r.get("batch"), r.get("ssm_heads"),
                         None, None)
            nd = getattr(x, "ndim", 0)
            if nd == 0:
                return P()
            # xlstm stacked recurrent states: leading stack dims precede B
            if "mlstm" in path:
                lead = 2 if cfg.slstm_at else 1
                return P(*([None] * lead), r.get("batch"),
                         *([None] * (nd - lead - 1)))
            if "slstm" in path:
                return P(None, r.get("batch"), *([None] * (nd - 2)))
            return P(r.get("batch"), *([None] * (nd - 1)))

        def walk(tree, path=""):
            if isinstance(tree, dict):
                return {k: walk(v, f"{path}/{k}" if path else k)
                        for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                t = [walk(v, f"{path}/{i}") for i, v in enumerate(tree)]
                return type(tree)(t)
            return leaf(path, tree)
        return walk(cache_tree)

    def state_specs(self, state_tree):
        """Train state: params/master/mu/nu share the param specs."""
        pspec = self.param_specs(state_tree["params"])
        return {"params": pspec,
                "opt": {"mu": pspec, "nu": pspec, "master": pspec,
                        "step": P()}}

    # ---------------- MoE shard_map ----------------
    def moe_shard_map(self, local_fn, xt, p):
        """Run the gather-EP MoE body per (dp shard, model shard); the
        token payload crosses the ICI once, in the combine psum
        (models/moe.py)."""
        E = self.cfg.n_experts
        e_local = E // self.tp
        dp = self.rules["batch"]
        routed = {k: p[k] for k in ("router", "w_in", "w_gate", "w_out")
                  if k in p}
        fsdp = self.rules["fsdp"]
        pspec = {"router": P(None, None),
                 "w_in": P(self.rules["experts"], fsdp, None),
                 "w_gate": P(self.rules["experts"], fsdp, None),
                 "w_out": P(self.rules["experts"], fsdp, None)}
        pspec = {k: pspec[k] for k in routed}

        def body(x_l, p_l):
            if fsdp:   # ZeRO-3: re-assemble expert weights for the GEMMs
                for k in ("w_in", "w_gate", "w_out"):
                    if k in p_l:
                        p_l[k] = jax.lax.all_gather(p_l[k], fsdp, axis=1,
                                                    tiled=True)
            m_idx = jax.lax.axis_index("model")
            out, lb, z = local_fn(x_l, p_l, e_start=m_idx * e_local,
                                  e_local=e_local, axis_name="model")
            if self.dp_axes:
                lb = jax.lax.pmean(lb, self.dp_axes)
                z = jax.lax.pmean(z, self.dp_axes)
            return out, lb, z

        fn = jax.shard_map(body, mesh=self.mesh,
                           in_specs=(P(dp, None), pspec),
                           out_specs=(P(dp, None), P(), P()),
                           check_vma=False)
        return fn(xt, routed)


def make_sharding(mesh, cfg, global_batch=None) -> Sharding:
    return Sharding(mesh, cfg, global_batch)
