"""Unified storage-backend layer (paper §4.4, §4.6).

One protocol — ``StorageBackend`` — with a batched core surface
(``put_many`` / ``get_many`` / ``has_many`` + stats), and composable
implementations:

  MemoryBackend     in-memory dict, optional log-structured file
  SegmentBackend    durable log-structured segment files (storage.durable)
  TieredBackend     memory hot tier + durable cold tier (storage.durable)
  LRUCacheBackend   LRU read cache over any backend
  ReplicatedBackend k-way replication with read failover
  ShardedBackend    cid-hash partitioning across in-process shards
  WriteBuffer       write-behind batch: one put_many per value commit

``cluster._RoutingStore`` (meta-pinned two-layer partitioning) is the
sixth implementation; it lives with the cluster because it routes
through cluster state.

Select or stack backends with ``make_backend``:

    make_backend("memory")
    make_backend("log", log_path="/tmp/chunks.log")
    make_backend("lru+sharded", shards=8)          # cache over shards
    make_backend("replicated", n=4, k=2)
    make_backend("segment", root="/data/chunks")   # durable segments
    make_backend("tiered", root="/data/chunks")    # hot tier over them
"""
from __future__ import annotations

from .backend import (BackendBase, ChunkMissing, StorageBackend, StoreStats,
                      TamperedChunk, resolve_cids)
from .buffer import WriteBuffer
from .cache import LRUCacheBackend
from .durable import SegmentBackend, TieredBackend, open_durable
from .memory import MemoryBackend
from .replicated import ReplicatedBackend
from .sharded import ShardedBackend

__all__ = [
    "StorageBackend", "BackendBase", "StoreStats", "ChunkMissing",
    "TamperedChunk", "MemoryBackend", "LRUCacheBackend",
    "ReplicatedBackend", "ShardedBackend", "SegmentBackend",
    "TieredBackend", "WriteBuffer", "make_backend", "open_durable",
    "resolve_cids",
]


def make_backend(spec: str = "memory", *, log_path: str | None = None,
                 root: str | None = None, n: int = 4, k: int = 2,
                 shards: int = 4, capacity_bytes: int = 64 << 20,
                 segment_bytes: int = 4 << 20, verify: bool = False):
    """Build a backend from a ``+``-separated layer spec, outermost first.

    Base layers: ``memory`` | ``log`` (requires log_path) | ``segment``
    / ``tiered`` (require root) | ``sharded`` | ``replicated``.
    Wrapper layers: ``lru``.
    """
    layers = spec.split("+")
    base = layers[-1]
    if base == "memory":
        backend = MemoryBackend(verify=verify)
    elif base == "log":
        if not log_path:       # must survive -O: silent memory fallback
            raise ValueError("log backend needs log_path")
        backend = MemoryBackend(log_path=log_path, verify=verify)
    elif base in ("segment", "tiered"):
        if not root:
            raise ValueError(f"{base} backend needs root")
        if base == "segment":
            backend = SegmentBackend(root, segment_bytes=segment_bytes,
                                     verify=verify)
        else:
            backend = open_durable(root, hot_bytes=capacity_bytes,
                                   segment_bytes=segment_bytes,
                                   verify=verify)
    elif base == "sharded":
        backend = ShardedBackend(
            shards, factory=lambda: MemoryBackend(verify=verify))
    elif base == "replicated":
        backend = ReplicatedBackend([MemoryBackend(verify=verify)
                                     for _ in range(n)], k=k)
    else:
        raise ValueError(f"unknown base backend: {base!r}")
    for layer in reversed(layers[:-1]):
        if layer == "lru":
            backend = LRUCacheBackend(backend, capacity_bytes=capacity_bytes,
                                      verify=verify)
        else:
            raise ValueError(f"unknown wrapper layer: {layer!r}")
    return backend
