"""StorageBackend — the single pluggable chunk-storage abstraction.

Every store in the engine (memory, log-structured file, LRU cache,
replication, sharding, cluster routing) implements one protocol whose
core surface is *batched*: ``put_many``/``get_many``/``has_many``.
Batching is what keeps POS-Tree construction off the critical path
(paper §4.6.1): a value with N chunks commits with one ``put_many``
call, whose cid computation routes through the vectorized hash entry
point (``core.hashing.content_hash_many``) and can dispatch to the
Pallas ``fphash`` kernel — one kernel launch per value, many chunks per
launch — instead of N serial host hashes.

Singular ``put``/``get``/``has`` are thin wrappers over the batched
calls (``BackendBase``), so legacy call sites keep working and count as
batches of one.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from time import perf_counter as _perf
from typing import Iterator, Protocol, Sequence, runtime_checkable

from ..errors import ChunkMissing, TamperedChunk
from ..obs import REGISTRY as _OBS
from ..obs import trace as _trace

__all__ = [
    "BackendBase", "ChunkMissing", "StorageBackend", "StoreStats",
    "TamperedChunk", "delete_via", "group_by", "overlay_get_many",
    "overlay_has_many", "put_via", "resolve_cids",
]


@dataclass
class StoreStats:
    puts: int = 0                 # Put-Chunk requests (per chunk)
    put_batches: int = 0          # put_many calls (the batching win metric)
    dedup_hits: int = 0           # Puts acknowledged via existing cid
    gets: int = 0                 # Get-Chunk requests (per chunk)
    get_batches: int = 0          # get_many calls
    cache_hits: int = 0           # reads served by a cache layer
    deletes: int = 0              # chunks actually removed (per chunk)
    verifies: int = 0             # chunk-hash integrity checks performed
    verify_failures: int = 0      # checks that caught tampering/corruption
    logical_bytes: int = 0        # sum of bytes across all Puts
    physical_bytes: int = 0       # bytes actually stored (post-dedup)
    reclaimed_bytes: int = 0      # physical bytes freed by deletes
    tier_hits: int = 0            # reads served by the hot (memory) tier
    tier_misses: int = 0          # reads that fell through to the cold tier
    tier_demotions: int = 0       # chunks written back to the cold tier
    tier_promotions: int = 0      # cold chunks re-admitted hot on read
    compactions: int = 0          # segment rewrites (log-structured stores)
    compacted_bytes: int = 0      # file bytes reclaimed by those rewrites

    @property
    def dedup_ratio(self) -> float:
        return self.logical_bytes / max(1, self.physical_bytes)

    @property
    def tier_hit_rate(self) -> float:
        return self.tier_hits / max(1, self.tier_hits + self.tier_misses)

    def as_dict(self) -> dict:
        """Every counter plus the derived ratios — the one exhaustive
        export surface, so a newly added field reaches every consumer
        (benches, snapshots) without another hand-picked list."""
        out = {f.name: getattr(self, f.name) for f in fields(self)}
        out["dedup_ratio"] = self.dedup_ratio
        out["tier_hit_rate"] = self.tier_hit_rate
        return out

    def merge(self, other: "StoreStats") -> "StoreStats":
        """Accumulate another stats block into this one (cluster-wide
        rollups).  Returns self for chaining."""
        for f in fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other,
                                                                  f.name))
        return self


@runtime_checkable
class StorageBackend(Protocol):
    """What every chunk store implements.  Content-addressed, immutable
    chunks; dedup on Put (existing cids are acknowledged, not rewritten);
    missing reads raise ChunkMissing.  ``delete_many`` is the GC sweep
    verb: it removes chunks everywhere they are materialized (every
    replica, the owning shard, cache entries) and is a no-op for absent
    cids; ``iter_cids`` enumerates the distinct stored cids (the sweep
    inventory)."""

    stats: StoreStats

    def put_many(self, raws: Sequence[bytes],
                 cids: Sequence[bytes | None] | None = None) -> list[bytes]:
        ...

    def get_many(self, cids: Sequence[bytes]) -> list[bytes]:
        ...

    def has_many(self, cids: Sequence[bytes]) -> list[bool]:
        ...

    def delete_many(self, cids: Sequence[bytes]) -> int:
        ...

    def iter_cids(self) -> "Iterator[bytes]":
        ...

    def put(self, raw: bytes, cid: bytes | None = None) -> bytes:
        ...

    def get(self, cid: bytes) -> bytes:
        ...

    def has(self, cid: bytes) -> bool:
        ...

    def delete(self, cid: bytes) -> int:
        ...

    def __len__(self) -> int:
        ...

    def flush(self) -> None:
        ...


def resolve_cids(raws: Sequence[bytes],
                 cids: Sequence[bytes | None] | None) -> list[bytes]:
    """Fill in missing cids with one vectorized hash batch."""
    # Imported lazily: core imports storage (chunkstore shim), so a
    # module-scope import here would cycle through repro.core.__init__.
    from ..core.hashing import content_hash_many

    if cids is None:
        return content_hash_many(raws)
    out = list(cids)
    missing = [i for i, c in enumerate(out) if c is None]
    if missing:
        hashed = content_hash_many([raws[i] for i in missing])
        for i, h in zip(missing, hashed):
            out[i] = h
    return out


def group_by(owner_of, cids: Sequence[bytes],
             payloads: Sequence[bytes] | None = None
             ) -> "dict[int, tuple[list[int], list[bytes], list[bytes]]]":
    """Partition a batch by owner for scatter/gather routing: returns
    {owner: (original indices, cids, payloads)}.  ``owner_of(i, cid)``
    lets the caller pin by payload too (e.g. meta chunks -> home node)."""
    groups: dict[int, tuple[list[int], list[bytes], list[bytes]]] = {}
    for i, cid in enumerate(cids):
        g = groups.setdefault(owner_of(i, cid), ([], [], []))
        g[0].append(i)
        g[1].append(cid)
        if payloads is not None:
            g[2].append(payloads[i])
    return groups


def overlay_get_many(local: dict, cids: Sequence[bytes], fetch,
                     on_hit=None, on_fetch=None) -> list[bytes]:
    """Serve a read batch from a local dict overlay, forwarding only the
    misses to ``fetch`` in one call (shared by WriteBuffer pending reads
    and the LRU cache)."""
    out: list[bytes | None] = []
    miss_idx: list[int] = []
    miss_cids: list[bytes] = []
    for i, cid in enumerate(cids):
        raw = local.get(cid)
        out.append(raw)
        if raw is None:
            miss_idx.append(i)
            miss_cids.append(cid)
        elif on_hit is not None:
            on_hit(cid)
    if miss_cids:
        for i, cid, raw in zip(miss_idx, miss_cids, fetch(miss_cids)):
            out[i] = raw
            if on_fetch is not None:
                on_fetch(cid, raw)
    return out  # type: ignore[return-value]


def overlay_has_many(local: dict, cids: Sequence[bytes],
                     inner_has_many) -> list[bool]:
    """has_many against a local overlay + inner backend, batching the
    inner probe."""
    in_local = [cid in local for cid in cids]
    if all(in_local):
        return in_local
    rest = iter(inner_has_many([c for c, hit in zip(cids, in_local)
                                if not hit]))
    return [hit or next(rest) for hit in in_local]


def delete_via(stats: StoreStats, child, cids: Sequence[bytes], *,
               count_deletes: bool = True) -> int:
    """Forward one group of deletes to a child backend and absorb its
    reclaimed-bytes delta into ``stats`` (the sweep-side twin of
    ``put_via``).  Returns the child's removed-chunk count."""
    d0 = child.stats.deletes
    r0 = child.stats.reclaimed_bytes
    n = child.delete_many(cids)
    freed = child.stats.reclaimed_bytes - r0
    if count_deletes:
        stats.deletes += child.stats.deletes - d0
    stats.physical_bytes -= freed
    stats.reclaimed_bytes += freed
    return n


def put_via(stats: StoreStats, child, raws: Sequence[bytes],
            cids: Sequence[bytes | None] | None, *,
            count_dedup: bool = True) -> tuple[list[bytes], int, int]:
    """Forward one group of chunks to a child backend and absorb its
    dedup/physical deltas into ``stats`` (the shared bookkeeping of every
    composite backend: cache, sharded, replicated, routing).  Returns
    (cids, newly stored chunk count, newly stored bytes)."""
    c0 = len(child)
    d0 = child.stats.dedup_hits
    p0 = child.stats.physical_bytes
    out = child.put_many(raws, cids)
    new_bytes = child.stats.physical_bytes - p0
    if count_dedup:
        stats.dedup_hits += child.stats.dedup_hits - d0
    stats.physical_bytes += new_bytes
    return out, len(child) - c0, new_bytes


class BackendBase:
    """Common plumbing: stats + singular ops as batches of one, plus the
    put-notification hook every backend fires for the GC write barrier.

    The batched verbs are *instrumented dispatchers*: ``put_many`` /
    ``get_many`` / ``delete_many`` check the global observability flag
    and delegate to the subclass ``_put_many_impl`` / ``_get_many_impl``
    / ``_delete_many_impl``.  When enabled, writes and deletes open a
    ``store.put`` / ``store.delete`` span (nesting under whatever layer
    called them — engine, routing, tiered — via the trace contextvar)
    and reads record into a per-backend latency histogram; when
    disabled the whole cost is one flag check.  ``WriteBuffer``
    deliberately overrides the batched verbs directly: its per-chunk
    accumulation during tree build is too hot to instrument, and its
    flush lands on an instrumented inner ``put_many`` anyway."""

    #: Label used for span attrs and histogram labels; subclasses set it
    #: (falls back to the class name).
    OBS_NAME = ""

    def __init__(self) -> None:
        self.stats = StoreStats()
        self._put_listeners: list = []
        #: While an incremental collection is in flight the collector
        #: parks its RLock here (see gc.incremental), making one put
        #: batch — store write, index update, barrier notification —
        #: atomic against mark/freeze/sweep slices.  None between
        #: collections: zero cost on the common path.
        self._barrier_lock = None
        self._obs_hists: dict = {}
        self._obs_tick = 7           # 1-in-8 read sampling; first sampled

    # ---- GC write barrier (incremental collection) ----
    def add_put_listener(self, fn) -> None:
        """Register ``fn(cids)`` to fire after every put batch lands.
        Dedup acks are included: a put that merely re-references an
        existing chunk must still shade it, or an in-flight collection
        could sweep a chunk a brand-new version just adopted."""
        self._put_listeners.append(fn)

    def remove_put_listener(self, fn) -> None:
        try:
            self._put_listeners.remove(fn)
        except ValueError:
            pass

    def _notify_put(self, cids) -> None:
        for fn in list(self._put_listeners):
            fn(cids)

    # ---- observability plumbing ----
    def _obs_label(self) -> str:
        return self.OBS_NAME or type(self).__name__

    def _obs_hist(self, verb: str):
        h = self._obs_hists.get(verb)
        if h is None:
            # repro: allow(OBS001): only reached from dispatchers that
            # already checked _OBS.enabled; the handle is memoized so
            # this runs once per (backend, verb), not per operation
            h = _OBS.histogram(f"store_{verb}_us",
                               {"backend": self._obs_label()})
            self._obs_hists[verb] = h
        return h

    # ---- instrumented batched dispatchers ----
    def put_many(self, raws: Sequence[bytes],
                 cids: Sequence[bytes | None] | None = None) -> list[bytes]:
        # GC write/sweep exclusion: without the barrier lock a sweep
        # slice can delete a dedup re-put's chunk in the window between
        # its store write and its _notify_put barrier — the put path
        # takes the collector lock FIRST (order: servlet ≺ collector ≺
        # {index, store}), so either the whole put lands before the
        # slice (the barrier rescues the cid) or after it (the put
        # re-stores the swept chunk; content addressing makes that safe)
        lk = self._barrier_lock
        if lk is not None:
            with lk:
                return self._put_many_timed(raws, cids)
        return self._put_many_timed(raws, cids)

    def _put_many_timed(self, raws, cids=None) -> list[bytes]:
        if not _OBS.enabled:
            return self._put_many_impl(raws, cids)
        with _trace("store.put", _hist=self._obs_hist("put"),
                    backend=self._obs_label(), chunks=len(raws)) as sp:
            out = self._put_many_impl(raws, cids)
            sp.set(bytes=sum(map(len, raws)))
        return out

    def get_many(self, cids: Sequence[bytes]) -> list[bytes]:
        # reads are histogram-only (no span), single-cid batches skip the
        # timer entirely (index walks issue one tiny get per tree level),
        # and multi-cid batches are timed at a 1-in-8 sample: a uniform
        # sample keeps the latency distribution honest while the per-call
        # tax the obs-overhead gate guards stays at one counter bump.
        # StoreStats still counts every get inside the impl.
        if not _OBS.enabled or len(cids) < 2:
            return self._get_many_impl(cids)
        self._obs_tick = tick = (self._obs_tick + 1) & 7
        if tick:
            return self._get_many_impl(cids)
        t0 = _perf()
        out = self._get_many_impl(cids)
        self._obs_hist("get").observe(_perf() - t0)
        return out

    def delete_many(self, cids: Sequence[bytes]) -> int:
        if not _OBS.enabled:
            return self._delete_many_impl(cids)
        with _trace("store.delete", _hist=self._obs_hist("delete"),
                    backend=self._obs_label(), chunks=len(cids)):
            return self._delete_many_impl(cids)

    def put(self, raw: bytes, cid: bytes | None = None) -> bytes:
        return self.put_many([raw], [cid])[0]

    def get(self, cid: bytes) -> bytes:
        return self.get_many([cid])[0]

    def has(self, cid: bytes) -> bool:
        return self.has_many([cid])[0]

    def delete(self, cid: bytes) -> int:
        return self.delete_many([cid])

    def flush(self) -> None:
        pass

    # subclasses implement _put_many_impl / _get_many_impl / has_many /
    # _delete_many_impl / iter_cids / __len__ (WriteBuffer overrides the
    # batched verbs themselves — see class docstring)
