"""WriteBuffer — the batched chunk pipeline (paper §4.6.1).

A write-behind layer that accumulates every chunk of one logical value
(POS-Tree leaves, index nodes, the meta chunk) and commits them to the
inner backend with a *single* ``put_many`` call on ``flush()``.  cids
are computed eagerly in vectorized batches (``content_hash_many``), so
tree construction can keep linking nodes by cid while no per-chunk
store round-trip happens; reads see pending chunks.

The duplicate-preserving raw list means the inner backend observes the
same logical Put stream it would have seen unbatched — dedup counters
and logical/physical byte stats are unchanged.

After ``flush()`` the buffer *closes* and becomes a transparent
pass-through, so a long-lived handle that kept a reference to it (e.g.
a POSTree whose ``store`` was a buffer during construction) continues
to read and write correctly against the inner backend.

Buffers nest: flushing an inner buffer into an outer one just moves the
batch up a level; only the outermost flush touches the real store.
"""
from __future__ import annotations

from .backend import (BackendBase, overlay_get_many, overlay_has_many,
                      resolve_cids)


class WriteBuffer(BackendBase):
    def __init__(self, inner):
        super().__init__()
        self.inner = inner
        self._raws: list[bytes] = []
        self._cids: list[bytes] = []
        self._pending: dict[bytes, bytes] = {}
        self._closed = False

    # ------------------------------------------------------------ batched
    def put_many(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        if self._closed:
            out = self.inner.put_many(raws, cids)
            self._notify_put(out)
            return out
        out = resolve_cids(raws, cids)
        st = self.stats
        st.put_batches += 1
        for raw, cid in zip(raws, out):
            st.puts += 1
            st.logical_bytes += len(raw)
            # keep one canonical bytes object per cid: duplicate puts
            # append a reference, so peak memory is O(physical), while
            # flush still replays the full logical stream for stats
            self._raws.append(self._pending.setdefault(cid, raw))
            self._cids.append(cid)
        # a buffered put is not yet durable, but it IS visible to reads,
        # so a listener attached to the buffer hears about it now; the
        # inner store's listeners fire on flush (the real commit)
        self._notify_put(out)
        return out

    def get_many(self, cids) -> list[bytes]:
        if self._closed:
            return self.inner.get_many(cids)
        st = self.stats
        st.get_batches += 1
        st.gets += len(cids)
        return overlay_get_many(self._pending, cids, self.inner.get_many)

    def has_many(self, cids) -> list[bool]:
        if self._closed:
            return self.inner.has_many(cids)
        return overlay_has_many(self._pending, cids, self.inner.has_many)

    def delete_many(self, cids) -> int:
        """Open buffer: retract matching pending chunks (they will never
        reach the inner store) and pass the delete through; closed buffer:
        transparent pass-through.  A cid pending here AND already stored
        inner (dedup re-put) is one logical chunk — counted once."""
        if self._closed:
            return self.inner.delete_many(cids)
        cids = list(dict.fromkeys(cids))
        in_inner = self.inner.has_many(cids)
        drop = {cid for cid in cids if cid in self._pending}
        if drop:
            for cid in drop:
                del self._pending[cid]
            kept = [(r, c) for r, c in zip(self._raws, self._cids)
                    if c not in drop]
            self._raws = [r for r, _ in kept]
            self._cids = [c for _, c in kept]
        # the open buffer's stats never credited physical bytes (flush
        # hands the batch to inner), so only the delete count is ours to
        # track — inner's stats carry the physical reclaim
        self.inner.delete_many(cids)
        removed = sum(1 for cid, p in zip(cids, in_inner)
                      if p or cid in drop)
        self.stats.deletes += removed
        return removed

    def iter_cids(self):
        if self._closed:
            return self.inner.iter_cids()

        def chain():
            # snapshot only the (small) pending overlay; the inner
            # stream is consumed lazily so a segment/sharded inner can
            # keep yielding per-partition without one store-wide copy
            pending = list(self._pending)
            seen = set(pending)
            yield from pending
            for cid in self.inner.iter_cids():
                if cid not in seen:
                    yield cid

        return chain()

    # ------------------------------------------------------------- flush
    def flush(self) -> None:
        """Commit all pending chunks in one inner ``put_many`` and close."""
        if self._closed:
            self.inner.flush()
            return
        if self._raws:
            self.inner.put_many(self._raws, self._cids)
        self._raws = []
        self._cids = []
        self._pending = {}
        self._closed = True

    @property
    def pending_chunks(self) -> int:
        return len(self._pending)

    def __len__(self) -> int:
        if self._closed:
            return len(self.inner)
        extra = sum(not p for p in self.inner.has_many(list(self._pending)))
        return len(self.inner) + extra

    @property
    def stats(self):
        # closed buffers are transparent: report the inner backend's stats
        return self.inner.stats if self._closed else self._stats

    @stats.setter
    def stats(self, value):
        self._stats = value
