"""LRU read-cache layer: composes over any StorageBackend, serving hot
chunk reads from memory (the paper's servlets keep hot tree nodes
resident; this is that layer made explicit and stackable)."""
from __future__ import annotations

from collections import OrderedDict

from .backend import (BackendBase, delete_via, overlay_get_many,
                      overlay_has_many, put_via)


class LRUCacheBackend(BackendBase):
    """Write-through LRU over ``inner``, bounded by ``capacity_bytes``.

    With ``verify=True`` cache HITS are re-hashed before being served:
    without it a flipped bit in the resident copy would be returned with
    no integrity check at all, because verified leaf stores only see the
    misses (the tamper-evidence conformance suite covers this)."""

    OBS_NAME = "lru"

    def __init__(self, inner, capacity_bytes: int = 64 << 20,
                 verify: bool = False):
        super().__init__()
        self.inner = inner
        self.capacity_bytes = capacity_bytes
        self.verify = verify
        self._cache: OrderedDict[bytes, bytes] = OrderedDict()
        self._cache_bytes = 0

    def _admit(self, cid: bytes, raw: bytes) -> None:
        if cid in self._cache:
            self._cache.move_to_end(cid)
            return
        self._cache[cid] = raw
        self._cache_bytes += len(raw)
        while self._cache_bytes > self.capacity_bytes and len(self._cache) > 1:
            _, old = self._cache.popitem(last=False)
            self._cache_bytes -= len(old)

    # ------------------------------------------------------------ batched
    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        st = self.stats
        st.put_batches += 1
        out, _, _ = put_via(st, self.inner, raws, cids)
        for raw, cid in zip(raws, out):
            st.puts += 1
            st.logical_bytes += len(raw)
            self._admit(cid, raw)
        self._notify_put(out)
        return out

    def _get_many_impl(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        st.gets += len(cids)

        def on_hit(cid):
            self._cache.move_to_end(cid)
            st.cache_hits += 1
            if self.verify:
                from ..core.chunk import cid_of
                st.verifies += 1
                if cid_of(self._cache[cid]) != cid:
                    st.verify_failures += 1
                    from .backend import TamperedChunk
                    raise TamperedChunk(cid, "cache hit")

        return overlay_get_many(self._cache, cids, self.inner.get_many,
                                on_hit=on_hit, on_fetch=self._admit)

    def has_many(self, cids) -> list[bool]:
        return overlay_has_many(self._cache, cids, self.inner.has_many)

    def _delete_many_impl(self, cids) -> int:
        # invalidate cache entries first so a concurrent read can't serve
        # a deleted chunk from the overlay
        for cid in cids:
            raw = self._cache.pop(cid, None)
            if raw is not None:
                self._cache_bytes -= len(raw)
        return delete_via(self.stats, self.inner, cids)

    def iter_cids(self):
        return self.inner.iter_cids()

    @property
    def hit_rate(self) -> float:
        return self.stats.cache_hits / max(1, self.stats.gets)

    def __len__(self) -> int:
        return len(self.inner)

    def flush(self) -> None:
        self.inner.flush()
