"""Durable tiered storage: log-structured segments + hot/cold tiering.

``open_durable(root)`` is the one-call production stack — a memory hot
tier over a segment-file cold tier rooted at ``root/segments`` — used
by ``ForkBase(durable_root=...)`` and ``Cluster(durable_root=...)``.
"""
from __future__ import annotations

import os

from .fsutil import fsync_dir, replace_durably, write_durably
from .segment import FOOTER_CID, SegmentBackend
from .tiered import TieredBackend

__all__ = [
    "SegmentBackend", "TieredBackend", "open_durable",
    "fsync_dir", "replace_durably", "write_durably", "FOOTER_CID",
]


def open_durable(root: str, *, hot_bytes: int = 64 << 20,
                 segment_bytes: int = 4 << 20, compact_ratio: float = 0.5,
                 verify: bool = False) -> TieredBackend:
    """Open (or create) the durable tiered stack under ``root``."""
    os.makedirs(root, exist_ok=True)
    cold = SegmentBackend(os.path.join(root, "segments"),
                          segment_bytes=segment_bytes,
                          compact_ratio=compact_ratio, verify=verify)
    return TieredBackend(cold, hot_bytes=hot_bytes, verify=verify)
