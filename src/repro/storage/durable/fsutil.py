"""Crash-durability primitives shared by every on-disk store.

The one sequence that makes a file replacement atomic AND durable on a
POSIX filesystem is: write the new content to a sibling temp file,
fsync the temp file, rename over the destination, then fsync the
*parent directory* — without the final dirsync a crash after the rename
can lose the new file's directory entry, resurrecting the old content
(or nothing at all).  ``MemoryBackend.compact_log`` and the segment
compactor both route through ``replace_durably``/``write_durably`` so
the sequence exists exactly once.
"""
from __future__ import annotations

import os


def fsync_dir(path: str) -> None:
    """fsync a directory so a just-renamed entry inside it survives a
    crash.  Best-effort on filesystems that refuse O_RDONLY dir fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic fs
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def replace_durably(tmp: str, dst: str) -> None:
    """Atomically replace ``dst`` with the already-written-and-fsynced
    ``tmp``: rename + parent-dir fsync.  ``tmp`` must live in the same
    directory as ``dst`` (same-filesystem rename)."""
    os.replace(tmp, dst)
    fsync_dir(os.path.dirname(os.path.abspath(dst)))


def write_durably(dst: str, data: bytes) -> None:
    """The full write + fsync + rename + dirsync sequence for a whole
    small file (head snapshots, manifests)."""
    tmp = dst + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    replace_durably(tmp, dst)
