"""Disk-backed log-structured segment store — the durable leaf backend
(UStore/ForkBase production shape; ROADMAP item 1).

Chunks are appended to bounded *segment files* named ``seg-<gen>.seg``:

  record     cid(32) | u32 len | payload          (same framing as the
  tombstone  cid(32) | u32 0xFFFFFFFF             MemoryBackend log)
  footer     FOOTER_CID(32) | u32 plen | plen bytes:
                 u64 generation | u32 count | count * (u64 off|u32 len|cid)
  trailer    u64 footer_offset | b"SEGTRLR1"      (last 16 bytes)

The *active* segment takes appends; when it crosses ``segment_bytes``
it is sealed — footer + trailer written and fsynced — and a new
generation starts.  On open the in-memory ``cid -> (segment, offset,
len)`` index is rebuilt from footers alone (no payload reads); the
active segment has no footer yet and falls back to a record scan that
truncates any torn tail, exactly like the MemoryBackend log replay.
Replay also restores the replay-recoverable StoreStats, so dedup and
space ratios survive a reopen (delete counters are recovered only while
the dead records still exist on disk — compaction removes the evidence
together with the bytes, exactly like ``compact_log``).

Deletes (the GC sweep verb) append a tombstone to the active segment
and account the dead record's bytes against the segment that holds it.
Sealed segments whose dead ratio crosses ``compact_ratio`` are
rewritten live-chunks-only by ``compact()`` and atomically swapped in
(write + fsync + rename + parent-dir fsync via ``fsutil``) — per
segment, not the all-or-nothing ``compact_log`` rewrite.  ``flush()``
runs eligible compactions by default, so the GC sweep's post-delete
flush *is* the compaction feed.  A tombstone survives its segment's
rewrite only while an earlier segment still holds a (dead) record for
its cid — dropping it sooner would resurrect that record on replay.

``iter_cids`` streams the live cids one segment at a time, so the
incremental-GC inventory freeze never materializes one store-wide
pointer copy.
"""
from __future__ import annotations

import os
import struct

from ...obs import emit as obs_emit
from ..backend import (BackendBase, ChunkMissing, TamperedChunk,
                       resolve_cids)
from .fsutil import fsync_dir, replace_durably

_CID = 32
_LEN = struct.Struct("<I")
_HEAD = _CID + _LEN.size                 # bytes before a record's payload
_TOMBSTONE = 0xFFFFFFFF

FOOTER_CID = b"\xffSEGFOOT" * 4          # 32 bytes; collides with a real
#   cid with probability 2^-256 — the footer pseudo-record is framed
#   exactly like a chunk so a plain record scan steps over it safely
_FOOT_HEAD = struct.Struct("<QI")        # generation, entry count
_FOOT_ENTRY = struct.Struct("<QI32s")    # record offset, len, cid
_TRAILER = struct.Struct("<Q8s")         # footer record offset, magic
_TRAILER_MAGIC = b"SEGTRLR1"

# cid_of lives in repro.core, which imports repro.storage back through
# the chunkstore facade — a module-scope import would cycle, so the
# binding is resolved once on first use instead of once per call
_cid_of = None


def _chunk_cid_of():
    global _cid_of
    if _cid_of is None:
        from ...core.chunk import cid_of
        _cid_of = cid_of
    return _cid_of


class _Segment:
    """In-memory face of one segment file."""

    __slots__ = ("gen", "path", "live", "dead", "tombs", "records",
                 "data_bytes", "dead_bytes", "size", "sealed")

    def __init__(self, gen: int, path: str):
        self.gen = gen
        self.path = path
        self.live: dict[bytes, tuple[int, int]] = {}  # cid -> (payload off, len)
        self.dead: dict[bytes, int] = {}     # cid -> dead record payload bytes
        self.tombs: set[bytes] = set()       # cids tombstoned IN this segment
        # append-ordered (record offset, len|TOMBSTONE, cid) — the future
        # footer; kept for the active segment only (None once sealed)
        self.records: list[tuple[int, int, bytes]] | None = []
        self.data_bytes = 0                  # payload bytes of all chunk records
        self.dead_bytes = 0                  # payload bytes of dead records
        self.size = 0                        # file bytes (records + footer)
        self.sealed = False

    @property
    def dead_ratio(self) -> float:
        return self.dead_bytes / max(1, self.data_bytes)


def _pack_footer(gen: int, records) -> bytes:
    body = _FOOT_HEAD.pack(gen, len(records)) + b"".join(
        _FOOT_ENTRY.pack(off, ln, cid) for off, ln, cid in records)
    return FOOTER_CID + _LEN.pack(len(body)) + body


class SegmentBackend(BackendBase):
    OBS_NAME = "segment"
    """Durable log-structured StorageBackend over a directory of bounded
    segment files.  Conforms to the full protocol (batched verbs, put
    listeners, streamed ``iter_cids``) so it slots under the cache /
    replication / sharding / cluster-routing layers and the GC, proof
    and live subsystems unchanged."""

    def __init__(self, root: str, *, segment_bytes: int = 4 << 20,
                 compact_ratio: float = 0.5, auto_compact: bool = True,
                 verify: bool = False):
        super().__init__()
        self.root = root
        self.segment_bytes = segment_bytes
        self.compact_ratio = compact_ratio
        self.auto_compact = auto_compact
        self.verify = verify
        self._segments: dict[int, _Segment] = {}
        self._index: dict[bytes, int] = {}   # cid -> owning generation
        self._rfds: dict[int, int] = {}      # per-segment O_RDONLY fds
        self._active: _Segment | None = None
        self._wf = None                      # active append handle
        os.makedirs(root, exist_ok=True)
        self._open_all()

    # ------------------------------------------------------------- open
    def _path(self, gen: int) -> str:
        return os.path.join(self.root, f"seg-{gen:08d}.seg")

    def _open_all(self) -> None:
        gens = sorted(
            int(name[4:-4]) for name in os.listdir(self.root)
            if name.startswith("seg-") and name.endswith(".seg"))
        for gen in gens:
            path = self._path(gen)
            entries = self._load_footer(path)
            if entries is None:
                entries = self._scan(path)   # active / torn / footerless
                sealed = gen != gens[-1]     # only the newest may append
            else:
                sealed = True
            seg = _Segment(gen, path)
            seg.size = os.path.getsize(path)
            seg.sealed = sealed
            seg.records = None if sealed else list(entries)
            self._segments[gen] = seg
            self._apply(seg, entries)
            if not sealed:
                self._active = seg
        if self._active is None:
            self._roll(gens[-1] + 1 if gens else 1)
        else:
            self._wf = open(self._active.path, "ab")

    def _apply(self, seg: _Segment, entries) -> None:
        """Replay one segment's records into the global index and the
        replay-recoverable stats (replay == re-execution, like the
        MemoryBackend log)."""
        st = self.stats
        for off, ln, cid in entries:
            if ln == _TOMBSTONE:
                seg.tombs.add(cid)
                owner = self._index.pop(cid, None)
                if owner is not None:
                    oseg = self._segments[owner]
                    _, oln = oseg.live.pop(cid)
                    oseg.dead[cid] = oseg.dead.get(cid, 0) + oln
                    oseg.dead_bytes += oln
                    st.deletes += 1
                    st.physical_bytes -= oln
                    st.reclaimed_bytes += oln
                continue
            st.puts += 1
            st.logical_bytes += ln
            owner = self._index.get(cid)
            if owner is not None:            # duplicate record: old dies
                oseg = self._segments[owner]
                _, oln = oseg.live.pop(cid)
                oseg.dead[cid] = oseg.dead.get(cid, 0) + oln
                oseg.dead_bytes += oln
                st.physical_bytes -= oln
            seg.live[cid] = (off + _HEAD, ln)
            seg.data_bytes += ln
            st.physical_bytes += ln
            self._index[cid] = seg.gen

    def _load_footer(self, path: str):
        """Footer-indexed open: no payload reads.  Returns the ordered
        record entries, or None when the footer is absent/torn (fall
        back to a scan)."""
        try:
            size = os.path.getsize(path)
            if size < _TRAILER.size:
                return None
            with open(path, "rb") as f:
                f.seek(size - _TRAILER.size)
                foff, magic = _TRAILER.unpack(f.read(_TRAILER.size))
                if magic != _TRAILER_MAGIC or foff + _HEAD > size:
                    return None
                f.seek(foff)
                head = f.read(_HEAD)
                if head[:_CID] != FOOTER_CID:
                    return None
                (plen,) = _LEN.unpack(head[_CID:])
                if foff + _HEAD + plen + _TRAILER.size != size:
                    return None
                body = f.read(plen)
            _, count = _FOOT_HEAD.unpack_from(body, 0)
            if _FOOT_HEAD.size + count * _FOOT_ENTRY.size != plen:
                return None
            return [_FOOT_ENTRY.unpack_from(body, _FOOT_HEAD.size
                                            + i * _FOOT_ENTRY.size)
                    for i in range(count)]
        except (OSError, struct.error):
            return None

    def _scan(self, path: str):
        """Record scan for a footer-less (active) segment: parse records
        sequentially, truncating any torn tail ON DISK so post-crash
        appends land at a parseable offset."""
        size = os.path.getsize(path)
        entries: list[tuple[int, int, bytes]] = []
        good = 0
        verify = self.verify
        cid_of = _chunk_cid_of() if verify else None
        with open(path, "rb") as f:
            while True:
                off = f.tell()
                head = f.read(_HEAD)
                if len(head) < _HEAD:
                    break
                cid = head[:_CID]
                (ln,) = _LEN.unpack(head[_CID:])
                if cid == FOOTER_CID:
                    # sealed segment whose trailer was damaged: trust the
                    # records scanned so far and stop at the footer
                    if off + _HEAD + ln > size:
                        break
                    good = size
                    break
                if ln == _TOMBSTONE:
                    entries.append((off, _TOMBSTONE, cid))
                    good = f.tell()
                    continue
                if off + _HEAD + ln > size:
                    break                    # torn tail write
                if verify:
                    raw = f.read(ln)
                    self.stats.verifies += 1
                    if cid_of(raw) != cid:
                        self.stats.verify_failures += 1
                        raise TamperedChunk(cid, "segment replay")
                else:
                    f.seek(ln, 1)
                entries.append((off, ln, cid))
                good = f.tell()
        if good < size:
            os.truncate(path, good)
            obs_emit("storage.torn_tail", backend="segment", path=path,
                     dropped_bytes=size - good, offset=good)
        return entries

    # ------------------------------------------------------------- append
    def _roll(self, gen: int) -> None:
        if self._wf is not None:
            self._wf.close()
        seg = _Segment(gen, self._path(gen))
        self._segments[gen] = seg
        self._active = seg
        self._wf = open(seg.path, "ab")

    def _seal_active(self) -> None:
        """Footer + trailer + fsync: the segment becomes immutable and
        rebuildable without a scan."""
        seg = self._active
        footer = _pack_footer(seg.gen, seg.records)
        self._wf.write(footer + _TRAILER.pack(seg.size, _TRAILER_MAGIC))
        self._wf.flush()
        os.fsync(self._wf.fileno())
        seg.size += len(footer) + _TRAILER.size
        seg.sealed = True
        seg.records = None
        self._roll(seg.gen + 1)
        fsync_dir(self.root)                 # the new file's dir entry

    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        provided = ([] if cids is None else
                    [i for i, c in enumerate(cids) if c is not None])
        out = resolve_cids(raws, cids)
        st = self.stats
        if self.verify and provided:
            cid_of = _chunk_cid_of()
            for i in provided:
                st.verifies += 1
                if out[i] != cid_of(raws[i]):
                    st.verify_failures += 1
                    raise TamperedChunk(out[i], "Put-Chunk")
        st.put_batches += 1
        for raw, cid in zip(raws, out):
            st.puts += 1
            st.logical_bytes += len(raw)
            if cid in self._index:
                st.dedup_hits += 1           # immediate ack (§4.4)
                continue
            seg = self._active
            off = seg.size
            self._wf.write(cid + _LEN.pack(len(raw)) + raw)
            seg.records.append((off, len(raw), cid))
            seg.live[cid] = (off + _HEAD, len(raw))
            seg.data_bytes += len(raw)
            seg.size += _HEAD + len(raw)
            self._index[cid] = seg.gen
            st.physical_bytes += len(raw)
            if seg.size >= self.segment_bytes:
                self._seal_active()
        self._notify_put(out)
        return out

    # ------------------------------------------------------------- read
    def _rfd(self, gen: int) -> int:
        fd = self._rfds.get(gen)
        if fd is None:
            fd = self._rfds[gen] = os.open(self._segments[gen].path,
                                           os.O_RDONLY)
        return fd

    def _get_many_impl(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        if self._wf is not None:
            self._wf.flush()                 # active appends visible to pread
        verify = self.verify
        cid_of = _chunk_cid_of() if verify else None
        out = []
        for cid in cids:
            st.gets += 1
            gen = self._index.get(cid)
            if gen is None:
                raise ChunkMissing(cid)
            off, ln = self._segments[gen].live[cid]
            raw = os.pread(self._rfd(gen), ln, off)
            if verify:
                st.verifies += 1
                if cid_of(raw) != cid:
                    st.verify_failures += 1
                    raise TamperedChunk(cid, "Get-Chunk")
            out.append(raw)
        return out

    def has_many(self, cids) -> list[bool]:
        return [cid in self._index for cid in cids]

    # ------------------------------------------------------------ delete
    def _delete_many_impl(self, cids) -> int:
        st = self.stats
        n = 0
        for cid in cids:
            gen = self._index.pop(cid, None)
            if gen is None:
                continue                     # absent cids are a no-op
            seg = self._segments[gen]
            _, ln = seg.live.pop(cid)
            seg.dead[cid] = seg.dead.get(cid, 0) + ln
            seg.dead_bytes += ln
            act = self._active
            act.records.append((act.size, _TOMBSTONE, cid))
            act.tombs.add(cid)
            self._wf.write(cid + _LEN.pack(_TOMBSTONE))
            act.size += _HEAD
            n += 1
            st.deletes += 1
            st.physical_bytes -= ln
            st.reclaimed_bytes += ln
            if act.size >= self.segment_bytes:
                self._seal_active()
        return n

    def iter_cids(self):
        """Sweep inventory, streamed one segment at a time — a snapshot
        per segment generation, never one store-wide copy."""
        for gen in sorted(self._segments):
            seg = self._segments.get(gen)
            if seg is not None:
                yield from list(seg.live)

    def __len__(self) -> int:
        return len(self._index)

    def flush(self) -> None:
        """Durability point: fsync the active segment, then feed any
        GC-sweep output to the compactor (sealed segments past the dead
        threshold are rewritten)."""
        if self._wf is not None:
            self._wf.flush()
            os.fsync(self._wf.fileno())
        if self.auto_compact:
            self.maybe_compact()

    # -------------------------------------------------------- compaction
    def _tomb_needed(self, gen: int, cid: bytes) -> bool:
        """A tombstone must survive its segment's rewrite while any
        EARLIER segment still physically holds a record for its cid —
        dropping it would resurrect that record on the next replay."""
        return any(g < gen and cid in s.dead
                   for g, s in self._segments.items())

    def compactable(self):
        """Generations of sealed segments past the dead-ratio threshold
        (the compaction work queue the GC sweep feeds)."""
        return sorted(
            gen for gen, seg in self._segments.items()
            if seg.sealed and seg.dead_bytes > 0
            and (seg.dead_ratio >= self.compact_ratio
                 or not seg.live))

    def compact(self, gen: int) -> tuple[int, int]:
        """Rewrite one sealed segment live-chunks-only (plus still-needed
        tombstones) and atomically swap it in; returns (file bytes
        before, after).  A rewrite that leaves no records at all deletes
        the segment file instead."""
        seg = self._segments[gen]
        if not seg.sealed:
            raise ValueError(f"segment {gen} is active")
        before = seg.size
        keep_tombs = sorted(c for c in seg.tombs
                            if self._tomb_needed(gen, c))
        lives = sorted(seg.live.items(), key=lambda kv: kv[1][0])
        fd = self._rfd(gen)
        if not keep_tombs and not lives:     # fully dead: drop the file
            self._drop_segment(gen)
            self.stats.compactions += 1
            self.stats.compacted_bytes += before
            obs_emit("segment.compaction", gen=gen, bytes_before=before,
                     bytes_after=0, dropped=True)
            return before, 0
        tmp = seg.path + ".compact"
        records: list[tuple[int, int, bytes]] = []
        new_live: dict[bytes, tuple[int, int]] = {}
        off = 0
        with open(tmp, "wb") as f:
            # tombstones FIRST: a kept tombstone targets an earlier
            # segment, and a live re-put of the same cid in this segment
            # must replay after it, not be killed by it
            for cid in keep_tombs:
                f.write(cid + _LEN.pack(_TOMBSTONE))
                records.append((off, _TOMBSTONE, cid))
                off += _HEAD
            for cid, (poff, ln) in lives:
                f.write(cid + _LEN.pack(ln) + os.pread(fd, ln, poff))
                records.append((off, ln, cid))
                new_live[cid] = (off + _HEAD, ln)
                off += _HEAD + ln
            footer = _pack_footer(gen, records)
            f.write(footer + _TRAILER.pack(off, _TRAILER_MAGIC))
            f.flush()
            os.fsync(f.fileno())
        replace_durably(tmp, seg.path)
        self._close_rfd(gen)
        seg.live = new_live
        seg.dead = {}
        seg.tombs = set(keep_tombs)
        seg.data_bytes = sum(ln for _, ln in new_live.values())
        seg.dead_bytes = 0
        seg.size = off + len(footer) + _TRAILER.size
        self.stats.compactions += 1
        self.stats.compacted_bytes += before - seg.size
        obs_emit("segment.compaction", gen=gen, bytes_before=before,
                 bytes_after=seg.size, dropped=False)
        return before, seg.size

    def compact_step(self):
        """Compact the single most-dead eligible segment (one bounded
        unit of background maintenance work); returns (gen, bytes
        before, bytes after) or None when nothing is eligible."""
        todo = self.compactable()
        if not todo:
            return None
        gen = max(todo, key=lambda g: self._segments[g].dead_bytes)
        before, after = self.compact(gen)
        return gen, before, after

    def maybe_compact(self) -> int:
        """Drain the compaction queue; returns file bytes reclaimed."""
        freed = 0
        while True:
            step = self.compact_step()
            if step is None:
                return freed
            _, before, after = step
            freed += before - after

    def _drop_segment(self, gen: int) -> None:
        seg = self._segments.pop(gen)
        self._close_rfd(gen)
        os.remove(seg.path)
        fsync_dir(self.root)

    def _close_rfd(self, gen: int) -> None:
        fd = self._rfds.pop(gen, None)
        if fd is not None:
            os.close(fd)

    # ------------------------------------------------------ introspection
    def disk_bytes(self) -> int:
        """Total on-disk segment bytes (the durable footprint)."""
        if self._wf is not None:
            self._wf.flush()
        return sum(os.path.getsize(s.path)
                   for s in self._segments.values()
                   if os.path.exists(s.path))

    def segment_count(self) -> int:
        return len(self._segments)

    def dead_bytes(self) -> int:
        return sum(s.dead_bytes for s in self._segments.values())

    def close(self) -> None:
        """Release file handles (reopen by constructing a new backend)."""
        if self._wf is not None:
            self._wf.flush()
            os.fsync(self._wf.fileno())
            self._wf.close()
            self._wf = None
        for gen in list(self._rfds):
            self._close_rfd(gen)
