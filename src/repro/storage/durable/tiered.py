"""Hot/cold tiered store: an in-memory LRU hot tier over a durable cold
backend (LiveDB/ArchiveDB split from "Efficient Forkless Blockchain
Databases"; the durable counterpart of PR 6's in-memory LiveTable).

New chunks land *hot and dirty* — memory-only, not yet in the cold
tier.  When the hot tier overflows ``hot_bytes`` the least-recently-used
chunks are evicted: dirty ones are first demoted (written back to the
cold tier in one batch) so a live chunk is never dropped from its last
copy; clean ones — already durable below — are simply forgotten.  Reads
hit hot first; misses fetch from cold and promote (admitted clean).
``flush()`` demotes every remaining dirty chunk and then flushes the
cold tier, so after a flush the full store contents are durable and a
reopen over the same cold backend sees everything.

Deletes are the GC sweep verb: a dirty chunk dies entirely in memory
(it never reached disk), anything else is forwarded to the cold tier;
either way the chunk leaves both tiers.  The GC write barrier fires via
``_notify_put`` on this composite, exactly like every other stack.

Tier traffic is observable through the ``tier_hits`` / ``tier_misses``
/ ``tier_demotions`` / ``tier_promotions`` StoreStats counters, and the
cold tier's compaction activity (GC-fed) is absorbed into this store's
``compactions``/``compacted_bytes`` on flush so one stats object tells
the whole story.
"""
from __future__ import annotations

from collections import OrderedDict

from ...obs import emit as obs_emit
from ..backend import (BackendBase, StorageBackend, TamperedChunk,
                       delete_via, overlay_get_many, overlay_has_many,
                       resolve_cids)

_cid_of = None


def _chunk_cid_of():
    global _cid_of
    if _cid_of is None:
        from ...core.chunk import cid_of
        _cid_of = cid_of
    return _cid_of

# StoreStats fields the cold tier recovers by log/footer replay on open;
# a freshly constructed TieredBackend adopts them as its own baseline so
# stats survive a restart the same way MemoryBackend's replay does.
_REPLAYED_FIELDS = ("puts", "dedup_hits", "deletes", "logical_bytes",
                    "physical_bytes", "reclaimed_bytes")


class TieredBackend(BackendBase):
    """LRU memory hot tier + durable cold tier, GC-liveness aware."""

    OBS_NAME = "tiered"

    def __init__(self, cold: StorageBackend, *, hot_bytes: int = 64 << 20,
                 verify: bool = False):
        super().__init__()
        self.cold = cold
        self.hot_bytes = hot_bytes
        self.verify = verify
        self._hot: OrderedDict[bytes, bytes] = OrderedDict()
        self._hot_size = 0
        self._dirty: set[bytes] = set()      # hot-only, not yet durable
        for field in _REPLAYED_FIELDS:
            setattr(self.stats, field, getattr(cold.stats, field))

    # ------------------------------------------------------------- write
    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        provided = ([] if cids is None else
                    [i for i, c in enumerate(cids) if c is not None])
        out = resolve_cids(raws, cids)
        st = self.stats
        if self.verify and provided:
            cid_of = _chunk_cid_of()
            for i in provided:
                st.verifies += 1
                if out[i] != cid_of(raws[i]):
                    st.verify_failures += 1
                    raise TamperedChunk(out[i], "Put-Chunk")
        st.put_batches += 1
        # one batched existence probe against the cold tier for dedup
        unknown = [c for c in dict.fromkeys(out) if c not in self._hot]
        in_cold = (dict(zip(unknown, self.cold.has_many(unknown)))
                   if unknown else {})
        for raw, cid in zip(raws, out):
            st.puts += 1
            st.logical_bytes += len(raw)
            if cid in self._hot:
                st.dedup_hits += 1
                self._hot.move_to_end(cid)
                continue
            if in_cold.get(cid):
                st.dedup_hits += 1
                continue
            self._admit(cid, raw, dirty=True)
            in_cold[cid] = False             # later dups hit the hot branch
            st.physical_bytes += len(raw)
        self._evict()
        self._notify_put(out)
        return out

    def _admit(self, cid: bytes, raw: bytes, *, dirty: bool) -> None:
        self._hot[cid] = raw
        self._hot_size += len(raw)
        if dirty:
            self._dirty.add(cid)

    def _evict(self) -> None:
        """Shed LRU chunks past ``hot_bytes``; dirty evictees are demoted
        (written back) in ONE cold put batch before they leave memory."""
        demote_cids: list[bytes] = []
        demote_raws: list[bytes] = []
        while self._hot_size > self.hot_bytes and len(self._hot) > 1:
            cid, raw = self._hot.popitem(last=False)
            self._hot_size -= len(raw)
            if cid in self._dirty:
                self._dirty.discard(cid)
                demote_cids.append(cid)
                demote_raws.append(raw)
        if demote_cids:
            self.stats.tier_demotions += len(demote_cids)
            # direct child call, not put_via: these bytes are already in
            # this store's physical_bytes — demotion moves, not adds
            self.cold.put_many(demote_raws, demote_cids)
            obs_emit("tier.demote", chunks=len(demote_cids),
                     bytes=sum(map(len, demote_raws)), cause="overflow")

    def demote(self, target_bytes: int = 0) -> int:
        """Age-out policy hook: write back + evict LRU chunks until the
        hot tier holds at most ``target_bytes``.  Returns chunks shed."""
        before = len(self._hot)
        keep, self.hot_bytes = self.hot_bytes, target_bytes
        try:
            self._evict()
            if self._hot_size > target_bytes and self._hot:
                cid, raw = self._hot.popitem(last=False)  # the >1 guard's last
                self._hot_size -= len(raw)
                if cid in self._dirty:
                    self._dirty.discard(cid)
                    self.stats.tier_demotions += 1
                    self.cold.put_many([raw], [cid])
        finally:
            self.hot_bytes = keep
        return before - len(self._hot)

    # -------------------------------------------------------------- read
    def _get_many_impl(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        st.gets += len(cids)
        promoted0 = st.tier_promotions
        verify = self.verify
        cid_of = _chunk_cid_of() if verify else None

        def on_hit(cid):
            self._hot.move_to_end(cid)
            st.cache_hits += 1
            st.tier_hits += 1
            if verify:
                st.verifies += 1
                if cid_of(self._hot[cid]) != cid:
                    st.verify_failures += 1
                    raise TamperedChunk(cid, "hot-tier hit")

        def fetch(miss):
            st.tier_misses += len(miss)
            return self.cold.get_many(miss)

        def promote(cid, raw):
            st.tier_promotions += 1
            self._admit(cid, raw, dirty=False)

        out = overlay_get_many(self._hot, cids, fetch,
                               on_hit=on_hit, on_fetch=promote)
        self._evict()
        if st.tier_promotions > promoted0:
            obs_emit("tier.promote", chunks=st.tier_promotions - promoted0)
        return out

    def has_many(self, cids) -> list[bool]:
        return overlay_has_many(self._hot, cids, self.cold.has_many)

    # ------------------------------------------------------------ delete
    def _delete_many_impl(self, cids) -> int:
        st = self.stats
        n = 0
        cold_cids: list[bytes] = []
        for cid in cids:
            raw = self._hot.pop(cid, None)
            if raw is not None:
                self._hot_size -= len(raw)
                if cid in self._dirty:       # never reached disk: done
                    self._dirty.discard(cid)
                    n += 1
                    st.deletes += 1
                    st.physical_bytes -= len(raw)
                    st.reclaimed_bytes += len(raw)
                    continue
            cold_cids.append(cid)
        if cold_cids:
            n += delete_via(st, self.cold, cold_cids)
        return n

    def iter_cids(self):
        """Dirty (hot-only) cids, then the cold tier's stream — the two
        sets are disjoint by construction (a chunk becomes clean the
        moment it is demoted)."""
        yield from list(self._dirty)
        yield from self.cold.iter_cids()

    def __len__(self) -> int:
        return len(self._dirty) + len(self.cold)

    # --------------------------------------------------------- lifecycle
    def flush(self) -> None:
        """Durability point: demote every dirty chunk in one batch, then
        flush the cold tier (fsync + GC-fed compaction below)."""
        if self._dirty:
            cids = list(self._dirty)
            raws = [self._hot[c] for c in cids]
            self.stats.tier_demotions += len(cids)
            self.cold.put_many(raws, cids)
            self._dirty.clear()
            obs_emit("tier.demote", chunks=len(cids),
                     bytes=sum(map(len, raws)), cause="flush")
        n0 = self.cold.stats.compactions
        b0 = self.cold.stats.compacted_bytes
        self.cold.flush()
        self.stats.compactions += self.cold.stats.compactions - n0
        self.stats.compacted_bytes += self.cold.stats.compacted_bytes - b0

    def close(self) -> None:
        self.flush()
        if hasattr(self.cold, "close"):
            self.cold.close()

    @property
    def hot_count(self) -> int:
        return len(self._hot)

    @property
    def dirty_count(self) -> int:
        return len(self._dirty)
