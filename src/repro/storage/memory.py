"""In-memory content-addressed backend with optional append-only log
(paper §4.4).  This is the leaf store every composite backend (cache,
replication, sharding, routing) eventually bottoms out in."""
from __future__ import annotations

import os
import struct

from .backend import BackendBase, ChunkMissing, resolve_cids

_LEN = struct.Struct("<I")


class MemoryBackend(BackendBase):
    """dict-backed store; with ``log_path`` every new chunk is appended to
    a log-structured file and replayed on open (torn tails recovered)."""

    def __init__(self, log_path: str | None = None, verify: bool = False):
        super().__init__()
        self._data: dict[bytes, bytes] = {}
        self.verify = verify
        self._log = open(log_path, "ab") if log_path else None
        if log_path and os.path.getsize(log_path) > 0:
            self._replay(log_path)

    # ------------------------------------------------------------ batched
    def put_many(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        provided = ([] if cids is None else
                    [i for i, c in enumerate(cids) if c is not None])
        out = resolve_cids(raws, cids)
        if self.verify and provided:
            # only caller-supplied cids can mismatch; self-computed ones
            # would just re-hash the same bytes
            from ..core.chunk import cid_of
            for i in provided:
                assert out[i] == cid_of(raws[i]), \
                    "cid/content mismatch on Put-Chunk"
        st = self.stats
        st.put_batches += 1
        for raw, cid in zip(raws, out):
            st.puts += 1
            st.logical_bytes += len(raw)
            if cid in self._data:
                st.dedup_hits += 1     # immediate ack, chunk reused (§4.4)
                continue
            self._data[cid] = raw
            st.physical_bytes += len(raw)
            if self._log is not None:
                self._log.write(cid + _LEN.pack(len(raw)) + raw)
        return out

    def get_many(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        out = []
        for cid in cids:
            st.gets += 1
            raw = self._data.get(cid)
            if raw is None:
                raise ChunkMissing(cid)
            if self.verify:
                from ..core.chunk import cid_of
                assert cid_of(raw) == cid, "tampered chunk detected"
            out.append(raw)
        return out

    def has_many(self, cids) -> list[bool]:
        return [cid in self._data for cid in cids]

    def __len__(self) -> int:
        return len(self._data)

    def flush(self) -> None:
        if self._log is not None:
            self._log.flush()
            os.fsync(self._log.fileno())

    # ---------------------------------------------------------------- log
    def _replay(self, path: str) -> None:
        from ..core.hashing import CID_LEN
        with open(path, "rb") as f:
            while True:
                head = f.read(CID_LEN + 4)
                if len(head) < CID_LEN + 4:
                    break
                cid = head[:CID_LEN]
                (ln,) = _LEN.unpack(head[CID_LEN:])
                raw = f.read(ln)
                if len(raw) < ln:
                    break  # torn tail write: recover prefix
                self._data[cid] = raw
                self.stats.physical_bytes += ln
