"""In-memory content-addressed backend with optional append-only log
(paper §4.4).  This is the leaf store every composite backend (cache,
replication, sharding, routing) eventually bottoms out in.

The log is a record stream ``cid | u32 len | payload``; a delete appends
a *tombstone* record (``len == 0xFFFFFFFF``, no payload), so replay of an
uncompacted log converges to the live set and a crash between a GC sweep
and compaction cannot resurrect dead chunks.  ``compact_log`` rewrites
only the live chunks to a fresh file and atomically replaces the old one
(the space-reclamation half of the GC subsystem)."""
from __future__ import annotations

import os
import struct

from ..obs import emit as obs_emit
from .backend import (BackendBase, ChunkMissing, TamperedChunk,
                      resolve_cids)
from .durable.fsutil import replace_durably

_LEN = struct.Struct("<I")
_TOMBSTONE = 0xFFFFFFFF

# cid_of lives in repro.core, which imports repro.storage back through
# the chunkstore facade — a module-scope import would cycle, so the
# binding is resolved once on first use and cached here instead of being
# re-imported on every put_many/get_many/_replay call
_cid_of = None


def _chunk_cid_of():
    global _cid_of
    if _cid_of is None:
        from ..core.chunk import cid_of
        _cid_of = cid_of
    return _cid_of


class MemoryBackend(BackendBase):
    """dict-backed store; with ``log_path`` every new chunk is appended to
    a log-structured file and replayed on open (torn tails recovered,
    tombstones applied; with ``verify=True`` every replayed chunk is
    re-hashed and tampering raises TamperedChunk)."""

    OBS_NAME = "memory"

    def __init__(self, log_path: str | None = None, verify: bool = False):
        super().__init__()
        self._data: dict[bytes, bytes] = {}
        self.verify = verify
        self._log_path = log_path
        self._log = None
        if log_path:
            # replay (truncating any torn tail) BEFORE opening for
            # append, so post-crash records land at a parseable offset
            if os.path.exists(log_path) and os.path.getsize(log_path) > 0:
                self._replay(log_path)
            self._log = open(log_path, "ab")

    # ------------------------------------------------------------ batched
    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        provided = ([] if cids is None else
                    [i for i, c in enumerate(cids) if c is not None])
        out = resolve_cids(raws, cids)
        if self.verify and provided:
            # only caller-supplied cids can mismatch; self-computed ones
            # would just re-hash the same bytes
            cid_of = _chunk_cid_of()
            for i in provided:
                self.stats.verifies += 1
                if out[i] != cid_of(raws[i]):
                    self.stats.verify_failures += 1
                    raise TamperedChunk(out[i], "Put-Chunk")
        st = self.stats
        st.put_batches += 1
        for raw, cid in zip(raws, out):
            st.puts += 1
            st.logical_bytes += len(raw)
            if cid in self._data:
                st.dedup_hits += 1     # immediate ack, chunk reused (§4.4)
                continue
            self._data[cid] = raw
            st.physical_bytes += len(raw)
            if self._log is not None:
                self._log.write(cid + _LEN.pack(len(raw)) + raw)
        self._notify_put(out)
        return out

    def _get_many_impl(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        cid_of = _chunk_cid_of() if self.verify else None
        out = []
        for cid in cids:
            st.gets += 1
            raw = self._data.get(cid)
            if raw is None:
                raise ChunkMissing(cid)
            if self.verify:
                st.verifies += 1
                if cid_of(raw) != cid:
                    st.verify_failures += 1
                    raise TamperedChunk(cid, "Get-Chunk")
            out.append(raw)
        return out

    def has_many(self, cids) -> list[bool]:
        return [cid in self._data for cid in cids]

    def _delete_many_impl(self, cids) -> int:
        st = self.stats
        n = 0
        for cid in cids:
            raw = self._data.pop(cid, None)
            if raw is None:
                continue               # absent cids are a no-op
            n += 1
            st.deletes += 1
            st.physical_bytes -= len(raw)
            st.reclaimed_bytes += len(raw)
            if self._log is not None:
                self._log.write(cid + _LEN.pack(_TOMBSTONE))
        return n

    def iter_cids(self):
        return iter(list(self._data))

    def __len__(self) -> int:
        return len(self._data)

    def flush(self) -> None:
        if self._log is not None:
            self._log.flush()
            os.fsync(self._log.fileno())

    # ---------------------------------------------------------------- log
    def _replay(self, path: str) -> None:
        """Rebuild ``_data`` AND the replay-recoverable StoreStats from
        the record stream.  Every chunk record restores ``puts`` /
        ``logical_bytes`` (the log only ever holds first-time puts, so
        a record is exactly one counted put) and every tombstone counts
        in ``deletes`` / ``reclaimed_bytes`` — without this, dedup and
        space ratios are wrong after every reopen (puts/logical reset
        to zero, deletes invisible)."""
        cid_of = _chunk_cid_of()
        from ..core.hashing import CID_LEN
        st = self.stats
        good = 0                       # offset after the last whole record
        with open(path, "rb") as f:
            while True:
                head = f.read(CID_LEN + 4)
                if len(head) < CID_LEN + 4:
                    break
                cid = head[:CID_LEN]
                (ln,) = _LEN.unpack(head[CID_LEN:])
                if ln == _TOMBSTONE:   # deleted later in the stream
                    old = self._data.pop(cid, None)
                    if old is not None:
                        st.physical_bytes -= len(old)
                        st.deletes += 1
                        st.reclaimed_bytes += len(old)
                    good = f.tell()
                    continue
                raw = f.read(ln)
                if len(raw) < ln:
                    break  # torn tail write: recover prefix
                if self.verify:
                    st.verifies += 1
                    if cid_of(raw) != cid:
                        st.verify_failures += 1
                        raise TamperedChunk(cid, "log replay")
                st.puts += 1
                st.logical_bytes += ln
                if cid not in self._data:
                    st.physical_bytes += ln
                self._data[cid] = raw
                good = f.tell()
        size = os.path.getsize(path)
        if good < size:
            # drop the torn tail ON DISK too: appending after unparseable
            # bytes would corrupt every later record (replay would read
            # them as the torn record's payload — tombstones and new
            # chunks silently lost)
            os.truncate(path, good)
            obs_emit("storage.torn_tail", backend="memory", path=path,
                     dropped_bytes=size - good, offset=good)

    def log_size(self) -> int:
        """Current on-disk log size in bytes (0 without a log)."""
        if self._log is None:
            return 0
        self._log.flush()
        return os.path.getsize(self._log_path)

    def compact_log(self) -> tuple[int, int]:
        """Rewrite the log with only the live chunks — dead records and
        tombstones drop out — then atomically replace it (write + fsync +
        rename + parent-dir fsync via ``replace_durably``; without the
        dirsync a crash after the rename could lose the new file's
        directory entry).  Returns (bytes_before, bytes_after)."""
        if self._log is None:
            return (0, 0)
        before = self.log_size()
        tmp = self._log_path + ".compact"
        with open(tmp, "wb") as f:
            for cid, raw in self._data.items():
                f.write(cid + _LEN.pack(len(raw)) + raw)
            f.flush()
            os.fsync(f.fileno())
        self._log.close()
        replace_durably(tmp, self._log_path)
        self._log = open(self._log_path, "ab")
        return before, os.path.getsize(self._log_path)
