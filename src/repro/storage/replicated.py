"""k-way replication over several backends (paper §4.4): dedup is
preserved globally — at most k copies of any chunk exist — and reads
fail over across the replica ring."""
from __future__ import annotations

from ..errors import ConfigError
from .backend import (BackendBase, ChunkMissing, delete_via, group_by,
                      put_via, resolve_cids)


class ReplicatedBackend(BackendBase):
    OBS_NAME = "replicated"

    def __init__(self, stores: list, k: int = 2):
        super().__init__()
        if not stores:
            raise ConfigError("ReplicatedBackend needs at least one store")
        self.stores = list(stores)
        self.k = min(k, len(stores))
        self._known: set[bytes] = set()   # distinct cids (for __len__)

    def _ring(self, cid: bytes) -> list[int]:
        h = int.from_bytes(cid[:8], "little")
        n = len(self.stores)
        return [(h + i) % n for i in range(self.k)]

    # ------------------------------------------------------------ batched
    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        out = resolve_cids(raws, cids)
        st = self.stats
        st.put_batches += 1
        groups: dict[int, tuple[list[bytes], list[bytes]]] = {}
        for raw, cid in zip(raws, out):
            st.puts += 1
            st.logical_bytes += len(raw)
            if cid in self._known:
                st.dedup_hits += 1
            else:
                self._known.add(cid)
            for si in self._ring(cid):
                g = groups.setdefault(si, ([], []))
                g[0].append(raw)
                g[1].append(cid)
        for si, (rs, cs) in groups.items():
            # dedup counted once via _known, not per replica copy
            put_via(st, self.stores[si], rs, cs, count_dedup=False)
        self._notify_put(out)
        return out

    def _get_many_impl(self, cids) -> list[bytes]:
        """Batched read: group cids by primary replica, one get_many per
        store; only lost replicas fail over per-cid around the ring."""
        st = self.stats
        st.get_batches += 1
        st.gets += len(cids)
        out: list[bytes | None] = [None] * len(cids)
        primary = lambda i, c: self._ring(c)[0]  # noqa: E731
        for si, (idx, cs, _) in group_by(primary, cids).items():
            present = self.stores[si].has_many(cs)
            hit_i = [i for i, p in zip(idx, present) if p]
            hit_c = [c for c, p in zip(cs, present) if p]
            if hit_c:
                for i, raw in zip(hit_i, self.stores[si].get_many(hit_c)):
                    out[i] = raw
            for i, cid in zip(idx, cs):
                if out[i] is not None:
                    continue
                for ri in self._ring(cid)[1:]:  # replica lost -> fail over
                    # repro: allow(PERF001): failover path, off the batched
                    # fast path — walk the ring and stop at the first live
                    # copy; a batch per replica would read chunks it is
                    # about to discard
                    if self.stores[ri].has(cid):
                        # repro: allow(PERF001): single fetch of the one
                        # surviving copy found by the probe above
                        out[i] = self.stores[ri].get(cid)
                        break
                else:
                    raise ChunkMissing(cid)
        return out  # type: ignore[return-value]

    def has_many(self, cids) -> list[bool]:
        out = [False] * len(cids)
        primary = lambda i, c: self._ring(c)[0]  # noqa: E731
        for si, (idx, cs, _) in group_by(primary, cids).items():
            for i, cid, p in zip(idx, cs, self.stores[si].has_many(cs)):
                # repro: allow(PERF001): ring-walk short-circuits at the
                # first replica that holds the cid; misses are rare
                out[i] = p or any(self.stores[ri].has(cid)
                                  for ri in self._ring(cid)[1:])
        return out

    def _delete_many_impl(self, cids) -> int:
        """All-replica delete: a swept chunk leaves every copy in the ring
        (deletes counted once per distinct chunk, like dedup on Put)."""
        st = self.stats
        n = 0
        groups: dict[int, list[bytes]] = {}
        for cid in cids:
            if cid not in self._known:
                continue
            self._known.discard(cid)
            n += 1
            st.deletes += 1
            for si in self._ring(cid):
                groups.setdefault(si, []).append(cid)
        for si, cs in groups.items():
            delete_via(st, self.stores[si], cs, count_deletes=False)
        return n

    def iter_cids(self):
        return iter(list(self._known))

    def audit(self, sample: int = 64, seed: int = 0):
        """Sampled cross-replica tamper audit (proof subsystem): every
        ring copy of each sampled cid must exist and hash back to the
        cid; returns an ``AuditReport`` naming offending replicas."""
        from ..proof import Auditor
        return Auditor(sample=sample, seed=seed).audit_replicas(self)

    def __len__(self) -> int:
        return len(self._known)

    def flush(self) -> None:
        for s in self.stores:
            s.flush()
