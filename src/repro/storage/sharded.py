"""cid-hash sharded in-process backend: the cluster's layer-2 chunk
partitioning (§4.6) as a standalone composable store.  Because cids are
cryptographic hashes, chunks spread uniformly across shards even under
severely skewed key workloads (Fig. 15)."""
from __future__ import annotations

from ..errors import ConfigError
from .backend import (BackendBase, delete_via, group_by, put_via,
                      resolve_cids)
from .memory import MemoryBackend


class ShardedBackend(BackendBase):
    OBS_NAME = "sharded"

    def __init__(self, shards=4, factory=MemoryBackend):
        super().__init__()
        if isinstance(shards, int):
            shards = [factory() for _ in range(shards)]
        if not shards:
            raise ConfigError("ShardedBackend needs at least one shard")
        self.shards = list(shards)

    def _owner(self, cid: bytes) -> int:
        return int.from_bytes(cid[:8], "little") % len(self.shards)

    # ------------------------------------------------------------ batched
    def _put_many_impl(self, raws, cids=None) -> list[bytes]:
        raws = [bytes(r) for r in raws]
        out = resolve_cids(raws, cids)
        st = self.stats
        st.put_batches += 1
        st.puts += len(raws)
        st.logical_bytes += sum(len(r) for r in raws)
        for si, (_, cs, rs) in group_by(lambda i, c: self._owner(c),
                                        out, raws).items():
            put_via(st, self.shards[si], rs, cs)
        self._notify_put(out)
        return out

    def _get_many_impl(self, cids) -> list[bytes]:
        st = self.stats
        st.get_batches += 1
        st.gets += len(cids)
        out: list[bytes | None] = [None] * len(cids)
        for si, (idx, cs, _) in group_by(lambda i, c: self._owner(c),
                                         cids).items():
            for i, raw in zip(idx, self.shards[si].get_many(cs)):
                out[i] = raw
        return out  # type: ignore[return-value]

    def has_many(self, cids) -> list[bool]:
        return [self.shards[self._owner(cid)].has(cid) for cid in cids]

    def _delete_many_impl(self, cids) -> int:
        """Sweep fan-out: one delete_many per owning shard."""
        n = 0
        for si, (_, cs, _) in group_by(lambda i, c: self._owner(c),
                                       cids).items():
            n += delete_via(self.stats, self.shards[si], cs)
        return n

    def iter_cids(self):
        for s in self.shards:
            yield from s.iter_cids()

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def flush(self) -> None:
        for s in self.shards:
            s.flush()

    def distribution(self) -> list[int]:
        """Physical bytes per shard (uniformity check, Fig. 15)."""
        return [s.stats.physical_bytes for s in self.shards]
