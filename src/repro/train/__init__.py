from .adamw import AdamWConfig, apply_adamw, init_opt_state, schedule
from .step import TrainState, make_train_step, init_train_state
from .data import SyntheticLM, shard_batch

__all__ = ["AdamWConfig", "apply_adamw", "init_opt_state", "schedule",
           "TrainState", "make_train_step", "init_train_state",
           "SyntheticLM", "shard_batch"]
