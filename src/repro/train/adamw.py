"""AdamW from scratch (no optax): bf16 compute params + fp32 master copy,
fp32 moments, decoupled weight decay, global-norm clipping, cosine LR with
linear warmup.  All state is a pytree sharded exactly like the params, so
FSDP shards optimizer state too (ZeRO)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # moment storage dtype: 'f32' or 'bf16' (8-bit-Adam-style compression
    # for the 100B-class archs; math still runs in fp32)
    moment_dtype: str = "f32"


def schedule(opt: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, opt.warmup_steps))
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(1, opt.total_steps - opt.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = opt.min_lr_frac + (1 - opt.min_lr_frac) * cos
    return opt.lr * warm * frac


def init_opt_state(params, moment_dtype: str = "f32"):
    mdt = jnp.bfloat16 if moment_dtype == "bf16" else jnp.float32
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "master": master,
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def _decay_mask(path_leaf) -> bool:
    """No weight decay on norms / biases / 1-D params."""
    return path_leaf.ndim >= 2


def apply_adamw(opt: AdamWConfig, params, opt_state, grads):
    step = opt_state["step"]
    lr = schedule(opt, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - opt.b1 ** t
    bc2 = 1 - opt.b2 ** t

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mdt = mu.dtype
        mu2 = opt.b1 * mu.astype(jnp.float32) + (1 - opt.b1) * g
        nu2 = opt.b2 * nu.astype(jnp.float32) + (1 - opt.b2) * g * g
        update = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + opt.eps)
        wd = opt.weight_decay if m.ndim >= 2 else 0.0
        m2 = m - lr * (update + wd * m)
        return mu2.astype(mdt), nu2.astype(mdt), m2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    flat_m = jax.tree.leaves(opt_state["master"])
    out = [upd(g, mu, nu, m)
           for g, mu, nu, m in zip(flat_g, flat_mu, flat_nu, flat_m)]
    mu2 = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu2 = jax.tree.unflatten(treedef, [o[1] for o in out])
    m2 = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), m2, params)
    return new_params, {"mu": mu2, "nu": nu2, "master": m2,
                        "step": step + 1}, {"lr": lr, "gnorm": gnorm}
