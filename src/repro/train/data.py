"""Deterministic synthetic LM data pipeline.

Tokens are a seeded per-step stream (reproducible across restarts — the
data position is part of the checkpoint, so failure recovery resumes at
the exact batch).  ``shard_batch`` places a host batch onto the mesh with
the training input sharding.  A small background prefetcher overlaps host
generation with device steps.
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding


class SyntheticLM:
    """Markov-ish synthetic tokens: correlated (so loss is learnable),
    deterministic in (seed, step)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frontend: str = "none",
                 n_patches: int = 0, d_model: int = 0):
        self.vocab = vocab
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.frontend = frontend
        self.n_patches = n_patches
        self.d_model = d_model

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        S = self.seq - (self.n_patches if self.frontend == "vision" else 0)
        base = rng.integers(0, self.vocab, size=(self.batch, S + 1),
                            dtype=np.int32)
        # correlate neighbours so next-token prediction is learnable
        rep = rng.random((self.batch, S + 1)) < 0.5
        shifted = np.roll(base, 1, axis=1)
        tokens = np.where(rep, shifted, base).astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.frontend == "vision":
            out["patch_embeds"] = rng.standard_normal(
                (self.batch, self.n_patches, self.d_model),
                dtype=np.float32).astype(np.float32)
        return out

    def prefetch(self, start_step: int, depth: int = 2):
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch_at(s)))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()

        class It:
            def __iter__(self):
                return self

            def __next__(self):
                return q.get()

            def close(self):
                stop.set()
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass
        return It()


def shard_batch(batch: dict, mesh, shd) -> dict:
    """Host numpy batch -> sharded device arrays."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        spec = shd.spec("batch", *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
