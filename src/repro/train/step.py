"""train_step / jit wiring: value_and_grad over the model loss, AdamW
update, optional microbatch gradient accumulation and bf16 gradient
all-reduce compression.

The returned step function is pure (state, batch) -> (state, metrics) and
is jit-compiled with explicit in/out shardings so XLA GSPMD lays out DP /
FSDP / TP / EP collectives (see shardings.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..models import model as model_mod
from .adamw import AdamWConfig, apply_adamw, init_opt_state


@dataclass
class TrainState:
    params: Any
    opt: Any

    def tree(self):
        return {"params": self.params, "opt": self.opt}


def init_train_state(cfg, key, shards: int = 16):
    params = model_mod.init_params(cfg, key, shards)
    return {"params": params,
            "opt": init_opt_state(params,
                                  getattr(cfg, "opt_moments", "f32"))}


def loss_fn(params, batch, cfg, shd):
    loss, metrics = model_mod.train_loss(params, batch, cfg, shd)
    return loss, metrics


def make_train_step(cfg, shd, opt_cfg: AdamWConfig | None = None,
                    microbatch: int = 1, grad_dtype=jnp.bfloat16):
    """microbatch > 1 scans over batch slices accumulating fp32 grads —
    trades time for activation memory; grad_dtype=bf16 keeps the DP
    all-reduce compressed (fp32 accumulation happens in AdamW)."""
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(state, batch):
        params = state["params"]
        if microbatch == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg, shd)
        else:
            def mb_slice(b, i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatch),
                        x.shape[0] // microbatch, 0), b)

            def acc(carry, i):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_slice(batch, i), cfg, shd)
                g = jax.tree.map(lambda a, b: a + b.astype(grad_dtype),
                                 g_acc, g)
                return (g, l_acc + l), m
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype),
                              params)
            (grads, loss_sum), ms = jax.lax.scan(
                acc, (g0, 0.0), jnp.arange(microbatch))
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss_sum / microbatch
            metrics = jax.tree.map(lambda x: x[-1], ms)
        new_params, new_opt, om = apply_adamw(opt_cfg, params,
                                              state["opt"], grads)
        metrics = dict(metrics, loss=loss, **om)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg, shd):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg, shd)
        return dict(metrics, loss=loss)
    return eval_step
