import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see 1 device (the 512-device
# override belongs exclusively to launch/dryrun.py).


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def small_params():
    from repro.core.chunker import ChunkParams
    return ChunkParams(q=8)   # 256 B chunks: many leaves at test sizes


@pytest.fixture(autouse=True)
def _lock_witness_guard():
    """Under REPRO_LOCK_WITNESS=1 every test doubles as a lock-order
    check: the global witness is reset before and asserted clean after.
    (Tests that construct deliberate inversions use a private
    LockWitness, so they stay green here.)  No-op when the witness is
    off — the common local case."""
    from repro.core import locking
    if not locking.witness_enabled():
        yield
        return
    locking.WITNESS.reset()
    yield
    locking.WITNESS.assert_clean()
