import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see 1 device (the 512-device
# override belongs exclusively to launch/dryrun.py).


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def small_params():
    from repro.core.chunker import ChunkParams
    return ChunkParams(q=8)   # 256 B chunks: many leaves at test sizes
