"""Rule engine (repro.analysis): every rule fires on a known-bad
fixture, respects ``# repro: allow``, and the META rules keep the
suppressions honest."""
import pytest

from repro.analysis import run_paths, scan_file
from repro.analysis.engine import RULES, parse_allows, rule_in_scope
from repro.analysis.__main__ import main as cli_main


def _scan(tmp_path, source, rel="src/repro/mod.py"):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    return scan_file(str(p))


def _codes(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------- one bad fixture
# per rule: the snippet must FIRE, and the allow-annotated variant must
# not (parametrized below).

FIXTURES = {
    "LOCK001": """\
class C:
    def f(self):
        with self._collector_lock:
            with self.lock:
                pass
""",
    "LOCK002": """\
import os
class C:
    def f(self):
        with self._collector_lock:
            os.fsync(3)
""",
    "CONTRACT001": """\
def f(x):
    assert x > 0
""",
    "CONTRACT002": """\
import time
def f():
    return time.time()
""",
    "PERF001": """\
def f(store, cids):
    for c in cids:
        store.get(c)
""",
    "OBS001": """\
def f(_OBS):
    _OBS.counter("x", {})
""",
}

# line (1-based) the finding lands on, per fixture — where an allow
# comment must go
FLAGGED_LINE = {"LOCK001": 4, "LOCK002": 5, "CONTRACT001": 2,
                "CONTRACT002": 3, "PERF001": 3, "OBS001": 2}


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_fires(tmp_path, code):
    findings = _scan(tmp_path, FIXTURES[code])
    assert code in _codes(findings), findings


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_allow_suppresses(tmp_path, code):
    lines = FIXTURES[code].splitlines()
    i = FLAGGED_LINE[code] - 1
    indent = lines[i][:len(lines[i]) - len(lines[i].lstrip())]
    lines.insert(i, f"{indent}# repro" f": allow({code}): fixture says so")
    findings = _scan(tmp_path, "\n".join(lines) + "\n")
    assert code not in _codes(findings), findings
    assert "META001" not in _codes(findings)   # justified
    assert "META002" not in _codes(findings)   # used


def test_allow_in_comment_block_above(tmp_path):
    src = (
        "def f(x):\n"
        "    # repro" ": allow(CONTRACT001): the justification starts here\n"
        "    # and continues on a second comment line — still one block\n"
        "    assert x > 0\n")
    findings = _scan(tmp_path, src)
    assert findings == []


def test_allow_trailing_on_flagged_line(tmp_path):
    src = ("def f(x):\n"
           "    assert x  # repro" ": allow(CONTRACT001): checked elsewhere\n")
    assert _scan(tmp_path, src) == []


def test_bare_allow_suppresses_but_raises_meta001(tmp_path):
    src = ("def f(x):\n"
           "    # repro" ": allow(CONTRACT001)\n"
           "    assert x > 0\n")
    findings = _scan(tmp_path, src)
    codes = _codes(findings)
    assert "CONTRACT001" not in codes
    assert codes == ["META001"]


def test_stale_allow_raises_meta002(tmp_path):
    src = ("def f(x):\n"
           "    # repro" ": allow(PERF001): nothing here triggers it\n"
           "    return x\n")
    findings = _scan(tmp_path, src)
    assert _codes(findings) == ["META002"]


def test_removing_allow_resurfaces_finding(tmp_path):
    """The acceptance property: an allow is load-bearing — delete it and
    the gate fails again."""
    src_ok = ("def f(x):\n"
              "    # repro" ": allow(CONTRACT001): why not\n"
              "    assert x\n")
    src_bad = "def f(x):\n    assert x\n"
    assert _scan(tmp_path, src_ok) == []
    assert "CONTRACT001" in _codes(_scan(tmp_path, src_bad))


def test_multi_rule_allow(tmp_path):
    src = ("import time\n"
           "def f(store, cids):\n"
           "    for c in cids:\n"
           "        # repro" ": allow(PERF001, CONTRACT002): demo of a list\n"
           "        t = time.time()\n")
    # only CONTRACT002 fires on that line; PERF001 half is stale -> META002
    findings = _scan(tmp_path, src)
    assert _codes(findings) == []


# ----------------------------------------------------------- rule details

def test_lock001_unranked_under_ranked(tmp_path):
    src = ("class C:\n"
           "    def f(self):\n"
           "        with self.lock:\n"
           "            with self._segment_lock:\n"
           "                pass\n")
    findings = _scan(tmp_path, src)
    assert "LOCK001" in _codes(findings)
    assert "unranked" in findings[0].message


def test_lock001_ascending_order_clean(tmp_path):
    src = ("class C:\n"
           "    def f(self):\n"
           "        with self.lock:\n"
           "            with self._collector_lock:\n"
           "                with self.store_lock:\n"
           "                    pass\n")
    assert _scan(tmp_path, src) == []


def test_lock002_transitive_self_call(tmp_path):
    src = ("import os\n"
           "class C:\n"
           "    def outer(self):\n"
           "        with self._collector_lock:\n"
           "            self.mid()\n"
           "    def mid(self):\n"
           "        self.leaf()\n"
           "    def leaf(self):\n"
           "        os.fsync(3)\n")
    findings = _scan(tmp_path, src)
    assert _codes(findings) == ["LOCK002"]
    assert "self.mid()" in findings[0].message


def test_lock002_ignores_store_rank_and_after_release(tmp_path):
    src = ("import os\n"
           "class C:\n"
           "    def f(self):\n"
           "        with self.store_lock:\n"      # store rank: not hot
           "            os.fsync(3)\n"
           "    def g(self):\n"
           "        with self._collector_lock:\n"
           "            x = 1\n"
           "        os.fsync(3)\n")               # after release: fine
    assert _scan(tmp_path, src) == []


def test_lock002_str_join_not_flagged(tmp_path):
    src = ("class C:\n"
           "    def f(self, parts):\n"
           "        with self._collector_lock:\n"
           "            return ','.join(parts)\n")
    assert _scan(tmp_path, src) == []


def test_perf001_dict_get_with_default_not_flagged(tmp_path):
    src = ("def f(store_meta, ks):\n"
           "    for k in ks:\n"
           "        store_meta.get(k, None)\n")
    assert _scan(tmp_path, src) == []


def test_perf001_single_element_batch(tmp_path):
    src = ("def f(store, cids):\n"
           "    for c in cids:\n"
           "        store.put_many([c])\n")
    findings = _scan(tmp_path, src)
    assert _codes(findings) == ["PERF001"]
    assert "single-element" in findings[0].message


def test_obs001_guard_patterns_accepted(tmp_path):
    src = ("def f(REGISTRY):\n"
           "    if REGISTRY.enabled:\n"
           "        REGISTRY.counter('x', {}).inc()\n"
           "def g(REGISTRY):\n"
           "    if not REGISTRY.enabled:\n"
           "        return\n"
           "    REGISTRY.histogram('y', {})\n")
    assert _scan(tmp_path, src) == []


def test_contract001_typed_raise_clean(tmp_path):
    src = ("from repro.errors import InvariantViolation\n"
           "def f(x):\n"
           "    if not x:\n"
           "        raise InvariantViolation('x must be set')\n")
    assert _scan(tmp_path, src) == []


# --------------------------------------------------------------- scoping

def test_contract_rules_are_src_only():
    assert rule_in_scope("CONTRACT001", "src/repro/core/db.py")
    assert not rule_in_scope("CONTRACT001", "tests/test_api.py")
    assert not rule_in_scope("CONTRACT001", "src/repro/models/model.py")
    assert not rule_in_scope("CONTRACT002", "src/repro/obs/export.py")
    assert rule_in_scope("CONTRACT002", "src/repro/obs/events.py")
    assert not rule_in_scope("OBS001", "src/repro/obs/metrics.py")
    assert rule_in_scope("LOCK001", "tests/test_runtime.py")
    assert rule_in_scope("PERF001", "benchmarks/bench_store.py")


def test_asserts_fine_in_tests(tmp_path):
    assert _scan(tmp_path, "def test_x():\n    assert 1\n",
                 rel="tests/test_x.py") == []


# ------------------------------------------------------------------- CLI

def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(x):\n    assert x\n")
    assert cli_main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "CONTRACT001" in out and "1 finding" in out

    good = bad.parent / "good.py"
    good.write_text("def f(x):\n    return x\n")
    assert cli_main([str(good)]) == 0

    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in RULES:
        assert code in out


def test_cli_json(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(x):\n    assert x\n")
    assert cli_main(["--json", str(bad)]) == 1
    import json
    data = json.loads(capsys.readouterr().out)
    assert data[0]["rule"] == "CONTRACT001"
    assert data[0]["line"] == 2


def test_allow_parser_targets():
    allows = parse_allows([
        "x = 1  # repro" ": allow(PERF001): trailing",
        "# repro" ": allow(LOCK001): block comment",
        "# continuation of the block",
        "y = 2",
        "# repro" ": allow(OBS001): dangling at EOF",
    ])
    assert (allows[0].target, allows[0].rules) == (1, ("PERF001",))
    assert allows[0].justification == "trailing"
    assert (allows[1].target, allows[1].rules) == (4, ("LOCK001",))
    assert allows[2].target is None          # dangles past EOF


def test_repo_tree_is_clean():
    """The gate itself: the shipped tree has zero unsuppressed findings
    and zero stale/bare allows."""
    findings = run_paths(["src", "tests", "benchmarks"])
    assert findings == [], "\n".join(f.render() for f in findings)
