"""ForkBase API semantics (Table 1, M1-M17) + fork/merge behaviour."""
import numpy as np
import pytest

from repro.core import (ChunkParams, Cluster, FBlob, FInt, FList, FMap,
                        FSet, FString, ForkBase, GuardFailed, MergeConflict,
                        aggregate_resolver, choose_one)

P8 = ChunkParams(q=8)


@pytest.fixture
def db():
    return ForkBase(params=P8)


def test_basic_kv_compliance(db):
    db.put("k", FString(b"v1"))
    assert db.get("k").string().value == b"v1"
    db.put("k", FString(b"v2"))
    assert db.get("k").string().value == b"v2"
    assert db.list_keys() == [b"k"]


def test_fig4_flow(db):
    db.put("my key", FBlob(b"my value" * 50))
    db.fork("my key", "master", "new branch")
    v = db.get("my key", "new branch")
    b = v.blob()
    b.remove(0, 10)
    b.append(b"some more")
    db.put("my key", b, "new branch")
    assert db.get("my key", "new branch").blob().read() == \
        (b"my value" * 50)[10:] + b"some more"
    assert db.get("my key", "master").blob().read() == b"my value" * 50


def test_track_and_lca(db):
    uids = [db.put("k", FInt(i)) for i in range(5)]
    hist = db.track("k", "master")
    assert [o.uid for o in hist] == uids[::-1]
    hist2 = db.track("k", "master", (1, 3))
    assert [o.uid for o in hist2] == uids[::-1][1:3]
    db.fork("k", uids[2], "side")
    u_side = db.put("k", FInt(99), "side")
    assert db.lca("k", uids[4], u_side) == uids[2]


def test_foc_untagged_branches(db):
    base = db.put("s", FMap({b"x": b"0"}))
    m1 = db.get("s", uid=base).map()
    m1.set(b"x", b"1")
    u1 = db.put("s", m1, base_uid=base)
    m2 = db.get("s", uid=base).map()
    m2.set(b"x", b"2")
    u2 = db.put("s", m2, base_uid=base)
    heads = db.list_untagged_branches("s")
    assert u1 in heads and u2 in heads and base not in heads
    with pytest.raises(MergeConflict):
        db.merge("s", u1, u2)
    merged = db.merge("s", u1, u2, resolver=choose_one(1))
    assert db.get("s", uid=merged).map().get(b"x") == b"2"
    assert set(db.list_untagged_branches("s")) >= {merged}


def test_merge_branches_m5(db):
    db.put("k", FMap({b"a": b"1", b"b": b"2"}))
    db.fork("k", "master", "dev")
    md = db.get("k", "dev").map()
    md.set(b"a", b"10")
    db.put("k", md, "dev")
    mm = db.get("k", "master").map()
    mm.set(b"b", b"20")
    db.put("k", mm, "master")
    db.merge("k", "master", "dev")
    final = db.get("k", "master").map()
    assert final.get(b"a") == b"10" and final.get(b"b") == b"20"


def test_guarded_put(db):
    db.put("g", FString(b"v1"))
    h = db.get("g").uid
    db.put("g", FString(b"v2"), guard_uid=h)
    with pytest.raises(GuardFailed):
        db.put("g", FString(b"v3"), guard_uid=h)


def test_branch_ops(db):
    db.put("k", FString(b"x"))
    db.fork("k", "master", "b1")
    db.rename("k", "b1", "b2")
    assert "b2" in db.list_tagged_branches("k")
    db.remove("k", "b2")
    assert "b2" not in db.list_tagged_branches("k")


def test_primitive_merges(db):
    base = db.put("n", FInt(10))
    c1 = db.get("n", uid=base).integer()
    c1.add(5)
    u1 = db.put("n", c1, base_uid=base)
    c2 = db.get("n", uid=base).integer()
    c2.add(7)
    u2 = db.put("n", c2, base_uid=base)
    m = db.merge("n", u1, u2, resolver=aggregate_resolver)
    assert db.get("n", uid=m).integer().value == 22


def test_list_and_set_types(db):
    l = FList([b"a", b"b", b"c"])
    db.put("l", l)
    ll = db.get("l").list()
    ll.insert(1, b"x")
    ll.delete(3)
    db.put("l", ll)
    assert list(db.get("l").list()) == [b"a", b"x", b"b"]
    s = FSet([b"p", b"q"])
    db.put("st", s)
    ss = db.get("st").set()
    ss.add(b"r")
    ss.remove(b"p")
    db.put("st", ss)
    assert set(db.get("st").set()) == {b"q", b"r"}


def test_verify_lineage(db):
    u1 = db.put("k", FString(b"a"))
    u2 = db.put("k", FString(b"b"))
    u3 = db.put("k", FString(b"c"))
    assert db.verify_lineage(u3, u1)
    assert not db.verify_lineage(u1, u3)


def test_cluster_balance(rng):
    counts = {}
    for mode in ("1LP", "2LP"):
        cl = Cluster(8, mode, P8)
        r = np.random.default_rng(1)
        for i in range(40):
            cl.put(f"hot{i % 2}", FBlob(r.bytes(16000)), branch=f"b{i}")
        dist = cl.storage_distribution()
        counts[mode] = (max(dist) + 1) / (min(dist) + 1)
    assert counts["2LP"] < counts["1LP"]


def test_cluster_api_roundtrip():
    cl = Cluster(4, "2LP", P8)
    cl.put("k", FBlob(b"hello world" * 100))
    assert cl.get("k").blob().read() == b"hello world" * 100
    cl.fork("k", "master", "dev")
    b = cl.get("k", "dev").blob()
    b.append(b"!")
    cl.put("k", b, "dev")
    assert cl.get("k", "dev").blob().read().endswith(b"!")
    assert len(cl.track("k", "dev")) == 2
