"""Application-level tests: blockchain, wiki, analytics vs baselines."""

from repro.apps import (ColumnTable, ForkBaseLedger, ForkBaseWiki,
                        KVLedger, OrpheusLite, RedisWiki, RowTable)
from repro.core import ChunkParams, ForkBase

P8 = ChunkParams(q=8)


def test_blockchain_equivalence(rng):
    fb, kv = ForkBaseLedger(ForkBase(params=P8)), KVLedger("bucket", 64)
    for blk in range(5):
        for i in range(8):
            k, v = f"k{(blk * 8 + i) % 12}", f"v{blk}.{i}".encode()
            fb.write("c", k, v)
            kv.write("c", k, v)
        fb.commit()
        kv.commit()
    idx = kv.build_scan_index()
    for key in ["k0", "k5", "k11"]:
        h_fb = [v for _, v in fb.state_scan("c", key)]
        h_kv = kv.state_scan("c", key, idx)
        assert h_fb == h_kv, key
    s_fb, s_kv = fb.block_scan(2), kv.block_scan(2)
    for (c, k), v in s_fb.items():
        assert s_kv[f"{c}/{k}".encode()] == v
    assert fb.verify_block(0) and fb.verify_block(4)


def test_blockchain_tamper_detection(rng):
    fb = ForkBaseLedger(ForkBase(params=P8))
    fb.write("c", "k", b"v1")
    u1 = fb.commit()
    fb.write("c", "k", b"v2")
    u2 = fb.commit()
    # a block not on the chain cannot be proven part of it
    other = ForkBaseLedger(ForkBase(params=P8))
    other.write("c", "k", b"evil")
    u_evil = other.commit()
    assert not fb.db.verify_lineage(u2, u_evil)


def test_wiki_vs_redis(rng):
    w, r = ForkBaseWiki(ForkBase(params=P8)), RedisWiki()
    text = rng.bytes(15000)
    w.create("p", text)
    r.create("p", text)
    cur = text
    for _ in range(10):
        pos = int(rng.integers(0, len(cur) - 100))
        ins = rng.bytes(64)
        cur = cur[:pos] + ins + cur[pos:]
        w.edit("p", lambda b, q=pos, s=ins: b.insert(q, s))
        r.edit("p", cur)
    assert w.load("p") == r.load("p") == cur
    for back in range(3):
        v, _, _ = w.read_version("p", back, None)
        assert v == r.read_version("p", back)
    assert w.storage_bytes() < 0.5 * sum(
        len(v) for vs in [[text] * 11] for v in vs), "dedup should win"


def test_wiki_chunk_cache(rng):
    w = ForkBaseWiki(ForkBase(params=P8))
    text = rng.bytes(20000)
    w.create("p", text)
    w.edit("p", lambda b: b.insert(100, b"xyz"))
    cache: set = set()
    _, f0, c0 = w.read_version("p", 0, cache)
    _, f1, c1 = w.read_version("p", 1, cache)
    # consecutive version mostly cache-hits (only the edited chunk differs)
    assert c1 >= 0.6 * (f1 + c1), (f1, c1)
    assert f1 <= 2


def test_analytics_row_col_orpheus(rng):
    db = ForkBase(params=P8)
    n = 1500
    recs = [[f"pk{i:06d}".encode(), str(i % 97).encode(),
             str(i % 13).encode(), rng.bytes(30)] for i in range(n)]
    rt = RowTable(db, "ds")
    u0 = rt.load({r[0]: r for r in recs})
    ol = OrpheusLite()
    v0 = ol.load(recs)
    ct = ColumnTable(db, "dsc", ["pk", "a", "b", "pay"])
    ct.load(recs)
    want = sum(i % 97 for i in range(n))
    assert rt.aggregate(1) == ol.aggregate(v0, 1) == ct.aggregate("a") \
        == want
    ups = {recs[i][0]: [recs[i][0], b"0", b"0", b"u"]
           for i in range(0, n, 50)}
    u1 = rt.update(ups)
    v1 = ol.commit(v0, {i: ups[recs[i][0]] for i in range(0, n, 50)})
    a, r, c = rt.diff(u1, u0)
    assert len(c) == len(ol.diff(v0, v1)) == len(ups)
    # fork isolation
    rt.fork("branchA")
    rtA = RowTable(db, "ds", "branchA")
    rtA.update({recs[0][0]: [recs[0][0], b"777", b"0", b"x"]})
    assert rt.get(recs[0][0])[1] == b"0"
    assert rtA.get(recs[0][0])[1] == b"777"
