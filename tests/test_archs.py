"""Per-arch smoke tests (deliverable f): every assigned architecture, at a
reduced same-family config, runs one forward/train step + one decode step
on CPU with shape and finiteness asserts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # ~minutes of model/train work

from repro.configs import ARCHS, smoke
from repro.models import model as M
from repro.shardings import Sharding

B, S = 2, 64


def _batch(sc, key):
    toks = jax.random.randint(key, (B, S), 0, sc.vocab)
    batch = {"tokens": toks, "labels": toks}
    if sc.frontend == "vision":
        batch["tokens"] = toks[:, :S - sc.n_patches]
        batch["labels"] = batch["tokens"]
        batch["patch_embeds"] = jnp.ones((B, sc.n_patches, sc.d_model),
                                         jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    cfg = ARCHS[arch]
    sc = smoke(cfg)
    shd = Sharding(None, sc)
    key = jax.random.PRNGKey(0)
    params = M.init_params(sc, key, shards=4)
    batch = _batch(sc, key)

    loss, metrics = jax.jit(
        lambda p, b: M.train_loss(p, b, sc, shd))(params, batch)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 2 * np.log(sc.vocab)

    grads = jax.jit(jax.grad(
        lambda p, b: M.train_loss(p, b, sc, shd)[0]))(params, batch)
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0

    cache = M.init_cache(sc, B, S)
    dec = {"tokens": batch["tokens"][:, :1],
           "pos": jnp.zeros((B,), jnp.int32)}
    nc, logits = jax.jit(
        lambda p, c, b: M.decode_step(p, c, b, sc, shd))(params, cache, dec)
    V = M.padded_vocab(sc, 4)
    assert logits.shape == (B, V)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # padded vocab entries must never win sampling
    assert int(np.argmax(np.asarray(logits, np.float32), -1).max()) \
        < sc.vocab


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "zamba2-2.7b",
                                  "xlstm-125m"])
def test_prefill_decode_consistency(arch):
    """Greedy continuation after prefill must be finite & in-vocab."""
    sc = smoke(ARCHS[arch])
    shd = Sharding(None, sc)
    key = jax.random.PRNGKey(1)
    params = M.init_params(sc, key, shards=4)
    toks = jax.random.randint(key, (B, S), 0, sc.vocab)
    cache, logits = jax.jit(
        lambda p, b: M.prefill(p, b, sc, shd))(params, {"tokens": toks})
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_params_count_sanity():
    """Config-derived parameter counts near published sizes."""
    approx = {"tinyllama-1.1b": 1.1e9, "qwen2-7b": 7.6e9,
              "qwen1.5-110b": 111e9, "olmoe-1b-7b": 6.9e9,
              "internlm2-1.8b": 1.9e9, "musicgen-large": 3.3e9,
              "deepseek-moe-16b": 16.4e9, "zamba2-2.7b": 2.7e9,
              "internvl2-2b": 1.9e9, "xlstm-125m": 125e6}
    for name, want in approx.items():
        got = ARCHS[name].params_count()
        assert 0.55 * want < got < 1.6 * want, (name, got, want)
