"""Audit fuzz: the auditor / audit daemon against randomly corrupted
replicas and cluster nodes.

Each episode builds a small deployment, lets the daemon reach a clean
steady state, injects a random corruption (bit flip, truncation, chunk
loss, head-meta tamper — on a random replica/node), and requires the
auditor to (a) report a finding naming the offending node and (b)
quarantine it, within a bounded number of ticks.  Sound reporting is
checked throughout: a clean deployment must never produce findings.

The deep, env-scaled variant (AUDIT_FUZZ_EPISODES) runs in the
scheduled ``audit-fuzz`` CI job beside the nightly gc-fuzz; the fast
variant keeps the machinery exercised in tier-1.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import Cluster, FBlob, FMap, ForkBase
from repro.core.chunk import encode_chunk
from repro.core.chunker import ChunkParams
from repro.storage import MemoryBackend, ReplicatedBackend

PARAMS = ChunkParams(q=8)


def _flip(raw: bytes, rng) -> bytes:
    i = int(rng.integers(0, len(raw)))
    return raw[:i] + bytes([raw[i] ^ (1 << int(rng.integers(0, 8)))]) \
        + raw[i + 1:]


# ------------------------------------------------------------- replicas

def _replica_episode(rng) -> None:
    rb = ReplicatedBackend([MemoryBackend() for _ in range(3)], k=2)
    db = ForkBase(rb, PARAMS)
    for i in range(int(rng.integers(1, 4))):
        db.put(b"k%d" % i, FBlob(rng.bytes(int(rng.integers(500, 8000)))))
    rb.put(encode_chunk(3, rng.bytes(int(rng.integers(64, 512)))))
    assert rb.audit(sample=10_000).ok            # clean: no findings
    # corrupt ONE ring copy of one random cid on one random replica
    cid = sorted(rb.iter_cids())[int(rng.integers(0, len(rb)))]
    holders = [si for si, s in enumerate(rb.stores) if s.has(cid)]
    victim = holders[int(rng.integers(0, len(holders)))]
    mode = int(rng.integers(0, 3))
    store = rb.stores[victim]
    if mode == 0:                                # bit flip
        store._data[cid] = _flip(store._data[cid], rng)
        want_kind = "corrupt"
    elif mode == 1:                              # truncation
        store._data[cid] = store._data[cid][:max(1, len(store._data[cid])
                                                 // 2)]
        want_kind = "corrupt"
    else:                                        # silent loss
        del store._data[cid]
        want_kind = "missing"
    rep = rb.audit(sample=10_000)
    assert not rep.ok
    assert any(f.kind == want_kind and f.node == f"replica{victim}"
               and f.cid == cid for f in rep.findings), rep


def _run_replica_fuzz(episodes: int, seed: int) -> None:
    rng = np.random.default_rng(seed)
    for _ in range(episodes):
        _replica_episode(rng)


def test_replica_audit_fuzz_fast(rng):
    _run_replica_fuzz(episodes=5, seed=10)


@pytest.mark.slow
def test_replica_audit_fuzz_deep():
    _run_replica_fuzz(
        episodes=int(os.environ.get("AUDIT_FUZZ_EPISODES", "50")),
        seed=11)


# -------------------------------------------------------- cluster daemon

def _daemon_episode(rng) -> None:
    cl = Cluster(int(rng.integers(2, 5)), params=PARAMS)
    keys = [b"key%d" % i for i in range(int(rng.integers(3, 9)))]
    for k in keys:
        cl.put(k, FMap({b"e%02d" % j: rng.bytes(12)
                        for j in range(int(rng.integers(5, 40)))}))
    daemon = cl.audit_daemon(sample=10_000, secret=b"s", max_interval=8)
    for _ in range(int(rng.integers(3, 12))):    # clean warm-up ticks
        assert cl.audit_tick(budget=2).ok
    assert not daemon.quarantined
    # corrupt a random head meta chunk (always covered by the engine
    # audit) on its home node
    k = keys[int(rng.integers(0, len(keys)))]
    ni = cl._home_index(k)
    uid = cl.nodes[ni].servlet.branches.head(k, "master")
    if int(rng.integers(0, 2)):
        cl.nodes[ni].store._data[uid] = _flip(cl.nodes[ni].store._data[uid],
                                              rng)
    else:
        del cl.nodes[ni].store._data[uid]
    # detection within one full backoff cycle of ticks
    for _ in range(daemon.max_interval + len(cl.nodes) + 2):
        rep = cl.audit_tick(budget=2)
        if not rep.ok:
            break
    assert f"node{ni}" in daemon.quarantined, (ni, daemon.quarantined)
    assert any(f.node == f"node{ni}" for f in daemon.findings)


def _run_daemon_fuzz(episodes: int, seed: int) -> None:
    rng = np.random.default_rng(seed)
    for _ in range(episodes):
        _daemon_episode(rng)


def test_daemon_audit_fuzz_fast(rng):
    _run_daemon_fuzz(episodes=3, seed=20)


@pytest.mark.slow
def test_daemon_audit_fuzz_deep():
    _run_daemon_fuzz(
        episodes=int(os.environ.get("AUDIT_FUZZ_EPISODES", "50")),
        seed=21)
