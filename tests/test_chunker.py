"""Rolling hash + content-defined chunking invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import rolling
from repro.core.chunker import (ChunkParams, boundary_bitmap, cut_bytes,
                                cut_elements, index_cuts)

P8 = ChunkParams(q=8)


def test_vectorized_matches_serial(rng):
    data = rng.integers(0, 256, 3000, dtype=np.uint8)
    for w in (4, 16, 48):
        a = rolling.rolling_hash(data, w)
        b = rolling.rolling_hash_serial(data.tobytes(), w)
        np.testing.assert_array_equal(a[w - 1:], b[w - 1:])


def test_expected_chunk_size(rng):
    data = rng.integers(0, 256, 500_000, dtype=np.uint8)
    cuts = cut_bytes(data, P8)
    mean = len(data) / len(cuts)
    assert 150 < mean < 420, mean     # E[chunk] = 2^8 = 256


def test_boundaries_are_content_local(rng):
    """Edit at position p only moves boundaries in [p, p+window+max)."""
    data = rng.integers(0, 256, 100_000, dtype=np.uint8)
    b1 = boundary_bitmap(data, P8)
    data2 = data.copy()
    data2[50_000] ^= 0xFF
    b2 = boundary_bitmap(data2, P8)
    np.testing.assert_array_equal(b1[:50_000], b2[:50_000])
    np.testing.assert_array_equal(b1[50_000 + P8.window:],
                                  b2[50_000 + P8.window:])


@given(st.binary(min_size=0, max_size=5000), st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_cut_bytes_partition(data, seed):
    arr = np.frombuffer(data, dtype=np.uint8)
    cuts = cut_bytes(arr, P8)
    if len(arr) == 0:
        assert cuts == []
        return
    assert cuts[-1] == len(arr)
    assert all(0 < a < b for a, b in zip(cuts, cuts[1:]))
    assert max(np.diff([0] + cuts)) <= P8.max_size


@given(st.lists(st.binary(min_size=1, max_size=300), min_size=1,
                max_size=60))
@settings(max_examples=30, deadline=None)
def test_cut_elements_alignment(elements):
    stream = np.frombuffer(b"".join(elements), dtype=np.uint8)
    bitmap = boundary_bitmap(stream, P8)
    cuts = cut_elements([len(e) for e in elements], bitmap, P8)
    assert cuts[-1] == len(elements)
    assert all(a < b for a, b in zip(cuts, cuts[1:]))
    # forced split cannot break a single element
    sizes = np.diff([0] + cuts)
    assert all(s >= 1 for s in sizes)


def test_index_cuts_fanout(rng):
    cids = [rng.bytes(32) for _ in range(5000)]
    cuts = index_cuts(cids, P8)
    assert cuts[-1] == len(cids)
    fan = np.diff([0] + cuts)
    assert fan.max() <= P8.index_max_fanout
    assert 20 < fan.mean() < 200      # E[fanout] = 2^6 = 64
