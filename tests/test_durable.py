"""Durable tiered storage: crash recovery, restart, tiering policy and
GC-fed compaction (storage.durable; ISSUE 7 acceptance).

The reopen-after-kill family runs against BOTH append-only on-disk
stores — ``MemoryBackend(log_path=...)`` and ``SegmentBackend`` — since
they share the record framing and the torn-tail recovery contract:
anything acknowledged by ``flush()`` survives; a torn tail is truncated
so post-crash appends land at a parseable offset.
"""
import os

import numpy as np
import pytest

from repro.core import Cluster, ForkBase, FBlob, FMap
from repro.core.branch import BranchTable
from repro.core.chunk import cid_of, encode_chunk
from repro.storage import (MemoryBackend, SegmentBackend, TieredBackend,
                           WriteBuffer, open_durable)
from repro.storage.durable.segment import _LEN, _TOMBSTONE


def chunks(rng, n=8, size=300):
    return [encode_chunk(3, rng.bytes(size) + bytes([i])) for i in range(n)]


# ------------------------------------------------- reopen-after-kill family

@pytest.fixture(params=["log", "segment"])
def reopenable(request, tmp_path):
    """(make, datafile): a factory reopening the same on-disk store, and
    the file a crash would tear (the log / the active segment)."""
    if request.param == "log":
        path = str(tmp_path / "chunks.log")

        def make():
            return MemoryBackend(log_path=path)

        def datafile():
            return path
    else:
        root = str(tmp_path / "segs")

        def make():
            # one active segment, no auto compaction: the pure
            # record-scan recovery path
            return SegmentBackend(root, segment_bytes=1 << 30,
                                  auto_compact=False)

        def datafile():
            segs = sorted(f for f in os.listdir(root)
                          if f.startswith("seg-") and f.endswith(".seg"))
            return os.path.join(root, segs[-1])
    return make, datafile


def test_torn_tail_mid_record_recovers_prefix(reopenable, rng):
    make, datafile = reopenable
    be = make()
    raws = chunks(rng, n=5)
    cids = be.put_many(raws)
    be.flush()
    with open(datafile(), "ab") as f:       # crash mid-append: the cid
        f.write(bytes(32) + _LEN.pack(1000) + b"partial-payload")
    be2 = make()                            # and length landed, payload torn
    assert sorted(be2.iter_cids()) == sorted(cids)
    assert be2.get_many(cids) == raws
    # the tail was truncated ON DISK: post-crash appends stay parseable
    be2.delete_many(cids[:1])
    extra = be2.put(encode_chunk(3, rng.bytes(90)))
    be2.flush()
    be3 = make()
    assert not be3.has(cids[0])
    assert be3.has(extra)
    assert be3.get_many(cids[1:]) == raws[1:]


def test_torn_tail_mid_tombstone_recovers_prefix(reopenable, rng):
    make, datafile = reopenable
    be = make()
    raws = chunks(rng, n=4)
    cids = be.put_many(raws)
    be.flush()
    # crash mid-tombstone append: cid + 2 of the 4 length bytes
    with open(datafile(), "ab") as f:
        f.write(cids[1] + _LEN.pack(_TOMBSTONE)[:2])
    be2 = make()
    assert be2.has(cids[1])                 # torn tombstone NOT applied
    assert be2.get_many(cids) == raws
    be2.delete_many(cids[1:2])              # the delete redone post-crash
    be2.flush()
    be3 = make()
    assert not be3.has(cids[1])
    assert be3.get_many([cids[0]] + cids[2:]) == [raws[0]] + raws[2:]


def test_crash_between_sweep_and_compaction(reopenable, rng):
    """The GC sweep flushes its tombstones before compaction runs; a
    crash in that window must neither resurrect swept chunks nor lose
    survivors."""
    make, _ = reopenable
    be = make()
    raws = chunks(rng, n=8)
    cids = be.put_many(raws)
    be.delete_many(cids[:5])                # the sweep
    be.flush()                              # durable tombstones...
    be2 = make()                            # ...crash before compaction
    assert be2.has_many(cids) == [False] * 5 + [True] * 3
    assert be2.get_many(cids[5:]) == raws[5:]
    assert len(be2) == 3


def test_footerless_active_segment_scans_sealed_use_footers(tmp_path, rng,
                                                            monkeypatch):
    root = str(tmp_path / "segs")
    be = SegmentBackend(root, segment_bytes=4 << 10)
    raws = chunks(rng, n=30, size=400)
    cids = be.put_many(raws)
    assert be.segment_count() >= 3          # at least two sealed + active
    be.flush()
    be.close()
    # every sealed file carries the footer trailer magic
    segs = sorted(f for f in os.listdir(root) if f.endswith(".seg"))
    for name in segs[:-1]:
        with open(os.path.join(root, name), "rb") as f:
            f.seek(-8, 2)
            assert f.read() == b"SEGTRLR1"
    # reopen: only the footer-less ACTIVE segment takes the record scan
    scanned = []
    orig = SegmentBackend._scan

    def spy(self, path):
        scanned.append(os.path.basename(path))
        return orig(self, path)

    monkeypatch.setattr(SegmentBackend, "_scan", spy)
    be2 = SegmentBackend(root, segment_bytes=4 << 10)
    assert scanned == [segs[-1]]
    assert be2.get_many(cids) == raws
    be2.close()


def test_segment_replay_restores_stats(tmp_path, rng):
    root = str(tmp_path / "segs")
    be = SegmentBackend(root, segment_bytes=4 << 10, auto_compact=False)
    raws = chunks(rng, n=12, size=500)
    cids = be.put_many(raws)
    be.delete_many(cids[:4])
    be.flush()
    want = {f: getattr(be.stats, f)
            for f in ("puts", "logical_bytes", "physical_bytes",
                      "deletes", "reclaimed_bytes")}
    be.close()
    be2 = SegmentBackend(root, segment_bytes=4 << 10, auto_compact=False)
    got = {f: getattr(be2.stats, f) for f in want}
    assert got == want
    be2.close()


# -------------------------------------------------------- compaction

def test_compaction_reclaims_dead_bytes_per_segment(tmp_path, rng):
    """Acceptance: GC-fed compaction reclaims >= 80% of the dead bytes
    of an over-threshold sealed segment — and ONLY that segment is
    rewritten (no stop-the-world rewrite: untouched files keep their
    inodes)."""
    root = str(tmp_path / "segs")
    be = SegmentBackend(root, segment_bytes=4 << 10)
    raws = chunks(rng, n=40, size=400)
    be.put_many(raws)
    assert be.segment_count() >= 4
    gens = sorted(be._segments)
    victim = gens[0]
    doomed = list(be._segments[victim].live)
    others = {g: os.stat(be._segments[g].path).st_ino
              for g in gens[1:] if os.path.exists(be._segments[g].path)}
    be.delete_many(doomed)                  # the GC sweep's output
    dead = be._segments[victim].dead_bytes
    assert dead > 0
    disk0 = be.disk_bytes()
    be.flush()                              # sweep flush IS the feed
    assert be.stats.compactions >= 1
    reclaimed = disk0 - be.disk_bytes()
    assert reclaimed >= 0.8 * dead
    # other sealed segments were not rewritten
    for g, ino in others.items():
        seg = be._segments.get(g)
        if seg is not None and os.path.exists(seg.path):
            assert os.stat(seg.path).st_ino == ino
    # survivors intact, across a reopen too
    live = sorted(be.iter_cids())
    survivors = be.get_many(live)
    be.close()
    be2 = SegmentBackend(root, segment_bytes=4 << 10)
    assert be2.get_many(live) == survivors
    be2.close()


def test_tombstone_survives_compaction_against_earlier_segment(tmp_path,
                                                               rng):
    """Resurrection hazard: a tombstone living in a LATER segment than
    its dead record must survive that segment's rewrite while the dead
    record is still on disk — dropping it early would replay the dead
    chunk back to life."""
    root = str(tmp_path / "segs")
    be = SegmentBackend(root, segment_bytes=2 << 10, auto_compact=False)
    doomed = encode_chunk(3, rng.bytes(300))
    dcid = be.put(doomed)                   # record lands in segment 1
    filler1 = be.put_many(chunks(rng, n=10, size=300))
    assert be._index[dcid] == 1 and be._active.gen > 1
    be.delete(dcid)                         # tombstone in the active seg
    filler2 = be.put_many(chunks(rng, n=12, size=300))
    tomb_gen = next(g for g, s in be._segments.items() if dcid in s.tombs)
    assert tomb_gen > 1 and be._segments[tomb_gen].sealed
    # kill most of the tombstone's segment so it crosses the threshold,
    # then compact it — WITHOUT touching segment 1 (dead record stays)
    victims = list(be._segments[tomb_gen].live)
    be.delete_many(victims)
    be.compact(tomb_gen)
    assert dcid in be._segments[tomb_gen].tombs   # kept: seg 1 holds it
    be.flush()
    be.close()
    be2 = SegmentBackend(root, segment_bytes=2 << 10, auto_compact=False)
    assert not be2.has(dcid)                # not resurrected
    keep = [c for c in filler1 + filler2 if c not in set(victims)]
    assert all(be2.has_many(keep))
    be2.close()


def test_gc_report_carries_compacted_bytes(tmp_path, rng):
    db = ForkBase(SegmentBackend(str(tmp_path / "segs"),
                                 segment_bytes=4 << 10))
    keep = rng.bytes(50_000)
    db.put("k", FBlob(keep))
    db.fork("k", "master", "scratch")
    db.put("k", FBlob(rng.bytes(50_000)), "scratch")
    db.remove("k", "scratch")
    report = db.gc()
    assert report.swept_chunks > 0
    assert report.compacted_bytes > 0       # the sweep fed the compactor
    assert "compacted" in str(report)
    assert db.get("k").blob().read() == keep


# ------------------------------------------------------------- tiering

def test_tier_liveness_dirty_chunks_demote_before_eviction(tmp_path, rng):
    """A live chunk is never evicted from its last copy: hot-tier
    overflow writes dirty chunks back to the cold tier first."""
    t = TieredBackend(SegmentBackend(str(tmp_path / "cold")),
                      hot_bytes=2_000)
    raws = chunks(rng, n=30, size=300)      # ~9 KB >> hot capacity
    cids = t.put_many(raws)
    assert t.stats.tier_demotions > 0
    assert t.hot_count < 30
    assert t.get_many(cids) == raws         # every chunk still readable
    assert t.stats.tier_misses > 0 and t.stats.tier_promotions > 0
    t.get_many(cids[-3:])                   # LRU-hot now
    h0 = t.stats.tier_hits
    t.get_many(cids[-3:])
    assert t.stats.tier_hits >= h0 + 3
    assert 0.0 < t.stats.tier_hit_rate < 1.0


def test_tier_flush_makes_everything_durable(tmp_path, rng):
    root = str(tmp_path / "tier")
    t = open_durable(root, hot_bytes=1 << 20)
    raws = chunks(rng, n=10)
    cids = t.put_many(raws)
    assert t.dirty_count == 10              # hot-only so far
    t.flush()
    assert t.dirty_count == 0
    t.close()
    t2 = open_durable(root, hot_bytes=1 << 20)
    assert t2.get_many(cids) == raws
    assert len(t2) == 10
    t2.close()


def test_tier_demote_policy_hook(tmp_path, rng):
    t = TieredBackend(SegmentBackend(str(tmp_path / "cold")),
                      hot_bytes=1 << 20)
    cids = t.put_many(chunks(rng, n=12, size=200))
    shed = t.demote(0)                      # age out the whole hot tier
    assert shed == 12 and t.hot_count == 0 and t.dirty_count == 0
    assert t.get_many(cids)                 # served (and re-promoted) cold
    assert t.stats.tier_promotions >= 12


def test_tier_delete_of_dirty_chunk_never_hits_disk(tmp_path, rng):
    cold = SegmentBackend(str(tmp_path / "cold"))
    t = TieredBackend(cold, hot_bytes=1 << 20)
    cid = t.put(encode_chunk(3, rng.bytes(400)))
    assert t.delete(cid) == 1
    assert len(cold) == 0 and cold.stats.puts == 0
    t.flush()
    assert cold.disk_bytes() == 0           # nothing ever written


# ------------------------------------------------ engine/cluster restart

def test_forkbase_durable_restart_bit_identical_heads(tmp_path, rng):
    root = str(tmp_path / "eng")
    db = ForkBase(durable_root=root)
    m = FMap({b"k%02d" % i: rng.bytes(40) for i in range(30)})
    db.put(b"table", m)
    db.fork(b"table", "master", "dev")
    m2 = db.get(b"table", "dev").map()
    m2.set(b"extra", b"x")
    db.put(b"table", m2, "dev")
    db.sync()
    snap = db.branches.snapshot()
    heads = db.branches.all_heads()
    del db
    db2 = ForkBase(durable_root=root)
    assert db2.branches.snapshot() == snap  # bit-identical
    assert db2.branches.all_heads() == heads
    assert db2.get(b"table", "dev").map().get(b"extra") == b"x"
    # the restarted engine keeps working: put, gc, sync
    db2.put(b"table", FMap({b"a": b"1"}), "dev")
    assert db2.gc().missing_roots == 0
    db2.sync()


def test_cluster_durable_restart_bit_identical_heads(tmp_path, rng):
    """Acceptance: a cluster built over the tiered backend survives
    process restart with bit-identical branch heads."""
    root = str(tmp_path / "clu")
    c = Cluster(3, durable_root=root, segment_bytes=8 << 10)
    for i in range(12):
        c.put(b"key%02d" % i,
              FMap({b"f%02d" % j: rng.bytes(40) for j in range(8)}))
    c.fork(b"key03", "master", "side")
    c.put(b"key03", FMap({b"x": b"y"}), "side")
    c.sync()
    snaps = [n.servlet.branches.snapshot() for n in c.nodes]
    index_size = len(c.index)
    del c
    c2 = Cluster(3, durable_root=root, segment_bytes=8 << 10)
    assert [n.servlet.branches.snapshot() for n in c2.nodes] == snaps
    assert len(c2.index) == index_size      # master location map rebuilt
    assert c2.get(b"key03", "side").map().get(b"x") == b"y"
    for i in range(12):
        assert c2.get(b"key%02d" % i).map().get(b"f00") is not None
    # restarted cluster collects and keeps serving
    rep = c2.gc()
    assert rep.missing_roots == 0
    assert c2.get(b"key07").map().get(b"f05") is not None


def test_ckpt_durable_restart(tmp_path, rng):
    from repro.ckpt import CheckpointStore
    root = str(tmp_path / "ckpt")
    cs = CheckpointStore(durable_root=root)
    state = {"w": rng.standard_normal((16, 16)).astype(np.float32),
             "b": rng.standard_normal(16).astype(np.float32)}
    cs.save(state, "train", step=1)
    cs.sync()
    del cs
    cs2 = CheckpointStore(durable_root=root)
    got = cs2.restore({"w": np.zeros((16, 16), np.float32),
                       "b": np.zeros(16, np.float32)}, "train")
    np.testing.assert_array_equal(got["w"], state["w"])
    np.testing.assert_array_equal(got["b"], state["b"])
    assert cs2.history("train")[0][1]["step"] == 1


def test_branchtable_snapshot_restore_rebuilds_refcounts():
    bt = BranchTable()
    bt.set_head(b"k1", "master", b"\x01" * 32)
    bt.on_new_version(b"k1", b"\x01" * 32, ())
    bt.fork(b"k1", "dev", b"\x01" * 32)
    bt.on_new_version(b"k2", b"\x02" * 32, (), foc=True)
    blob = bt.snapshot()
    bt2 = BranchTable()
    bt2.restore(blob)
    assert bt2.snapshot() == blob
    assert bt2._head_rc == bt._head_rc      # incremental rc rebuilt
    assert bt2.all_heads() == bt.all_heads()
    # restored table keeps mutating correctly (refcounts consistent)
    bt2.remove(b"k1", "dev")
    assert b"\x01" * 32 in bt2.all_heads()  # master + UB still point at it


# ------------------------------------------------- streaming iter_cids

def test_write_buffer_iter_cids_is_lazy(rng):
    """Satellite regression: iter_cids materialized pending + the whole
    inner inventory as one list; it must stream instead."""
    inner = MemoryBackend()
    stored = inner.put_many(chunks(rng, n=6))
    consumed = []

    real = inner.iter_cids

    def spying():
        for c in real():
            consumed.append(c)
            yield c

    inner.iter_cids = spying
    buf = WriteBuffer(inner)
    pending = buf.put(encode_chunk(3, rng.bytes(64)))
    it = buf.iter_cids()
    assert iter(it) is it                   # an iterator, not a list
    assert next(it) == pending
    assert consumed == []                   # inner untouched so far
    rest = list(it)
    assert sorted(rest) == sorted(stored)


def test_segment_iter_cids_streams_per_segment(tmp_path, rng):
    be = SegmentBackend(str(tmp_path / "segs"), segment_bytes=2 << 10)
    cids = be.put_many(chunks(rng, n=30, size=300))
    it = be.iter_cids()
    assert iter(it) is it
    assert sorted(it) == sorted(cids)
    be.close()


# ----------------------------------------------------------- fuzzing

def _fuzz_episode(root, seed, *, segment_bytes, steps, kill):
    """Seeded put/delete/flush/reopen episode; with ``kill=True`` each
    reopen keeps only a random op-boundary prefix of the unsynced tail
    (simulated power cut: the file loses everything past the cut, plus
    garbage bytes land after it)."""
    rng = np.random.default_rng(seed)
    pool = [encode_chunk(3, rng.bytes(int(rng.integers(30, 280))))
            for _ in range(24)]
    be = SegmentBackend(root, segment_bytes=segment_bytes,
                        auto_compact=not kill)
    model = {cid: be.get(cid) for cid in be.iter_cids()}
    tail = []                               # (op, cid, raw, record bytes)
    base_size = os.path.getsize(be._active.path)

    def reopen(be, model, tail, base_size):
        if kill:
            be._wf.flush()                  # bytes reach the file...
            path = be._active.path
            k = int(rng.integers(0, len(tail) + 1))
            cut = base_size + sum(nb for *_, nb in tail[:k])
            # ...but the tail is lost: unwind it newest-first (the same
            # cid can be deleted then re-put inside one tail)
            for op, cid, raw, _ in reversed(tail[k:]):
                if op == "put":
                    model.pop(cid, None)
                else:
                    model[cid] = raw        # the delete never happened
            be.close()
            os.truncate(path, cut)
            if rng.random() < 0.5:          # garbage after the cut
                with open(path, "ab") as f:
                    f.write(rng.bytes(int(rng.integers(1, 35))))
        else:
            be.flush()
            be.close()
        be = SegmentBackend(root, segment_bytes=segment_bytes,
                            auto_compact=not kill)
        assert sorted(be.iter_cids()) == sorted(model)
        assert be.get_many(list(model)) == list(model.values())
        return be, [], os.path.getsize(be._active.path)

    for _ in range(steps):
        r = rng.random()
        raw = pool[int(rng.integers(len(pool)))]
        cid = cid_of(raw)
        if r < 0.55:
            be.put(raw)
            if cid not in model:
                model[cid] = raw
                tail.append(("put", cid, raw, 36 + len(raw)))
        elif r < 0.85:
            if cid in model:
                be.delete(cid)
                del model[cid]
                tail.append(("del", cid, raw, 36))
        else:
            be, tail, base_size = reopen(be, model, tail, base_size)
    be, _, _ = reopen(be, model, tail, base_size)
    be.close()


def test_segment_reopen_fuzz(tmp_path):
    """Seeded clean-reopen interleavings with SMALL segments: sealing,
    footers, tombstones and auto-compaction all churn under random ops
    and every reopen converges to the model."""
    for seed in range(4):
        _fuzz_episode(str(tmp_path / f"ep{seed}"), 100 + seed,
                      segment_bytes=2 << 10, steps=60, kill=False)


@pytest.mark.slow
def test_kill_and_replay_fuzz(tmp_path):
    """Scheduled durability fuzz (durability-fuzz CI job): seeded
    kill-and-replay interleavings — every crash keeps an arbitrary
    op-boundary prefix of the unsynced tail and the reopened store must
    equal the surviving-op model exactly.  Episode count scales with
    DURABILITY_FUZZ_EPISODES."""
    episodes = int(os.environ.get("DURABILITY_FUZZ_EPISODES", "12"))
    for seed in range(episodes):
        _fuzz_episode(str(tmp_path / f"kill{seed}"), 9000 + seed,
                      segment_bytes=1 << 30, steps=50, kill=True)
