"""GC subsystem invariants: root-set extraction, batched mark, sweep,
pins, checkpoint retention (prune), cluster-wide collection, and the
core safety property — GC never collects a chunk reachable from any
surviving head, under randomized put/fork/merge/remove/prune workloads."""

import numpy as np
import pytest

from repro.core import (BranchExists, Cluster, FBlob, ForkBase,
                        FString, NoSuchRef)
from repro.gc import PinSet, mark
from repro.storage import MemoryBackend


@pytest.fixture
def db():
    return ForkBase(MemoryBackend())


@pytest.fixture
def rng():
    return np.random.default_rng(7)


# ------------------------------------------------------------------ mark

def test_mark_walks_history_and_trees(db, rng):
    """Everything reachable from one head — bases chain + every POS-Tree
    level of every version — is live."""
    datas = [rng.bytes(50_000) for _ in range(3)]
    for d in datas:
        db.put("k", FBlob(d))
    live, rounds, _ = mark(db.store, db.branches.all_heads())
    assert live == set(db.store.iter_cids())     # nothing is garbage yet
    assert rounds >= 3                           # one get_many per level
    assert db.gc().swept_chunks == 0
    for i, d in enumerate(datas):                # history still readable
        uid = db.track("k", "master")[2 - i].uid
        assert db.get("k", uid=uid).blob().read() == d


def test_mark_batches_one_round_trip_per_level(db, rng):
    db.put("k", FBlob(rng.bytes(120_000)))
    g0 = db.store.stats.get_batches
    _, rounds, _ = mark(db.store, db.branches.all_heads())
    assert db.store.stats.get_batches - g0 == rounds
    assert rounds < len(db.store)                # frontier BFS, not per-chunk


def test_dangling_roots_reported_not_fatal(db, rng):
    """A stale pin (or tag) must not brick collection forever."""
    db.put("k", FBlob(rng.bytes(10_000)))
    db.pins.pin(b"\x01" * 32)                    # never existed
    report = db.gc()
    assert report.missing_roots == 1
    assert db.get("k") is not None
    db.pins.unpin(b"\x01" * 32)
    assert db.gc().missing_roots == 0


def test_fork_from_unknown_uid_raises(db):
    db.put("k", FString(b"x"))
    with pytest.raises(NoSuchRef):
        db.fork("k", b"\x02" * 32, "bad")        # dangling tag refused


def test_gc_after_remove_reclaims_only_unreachable(db, rng):
    shared = rng.bytes(40_000)
    db.put("k", FBlob(shared))
    db.fork("k", "master", "exp")
    db.put("k", FBlob(shared + rng.bytes(10_000)), "exp")  # shares chunks
    db.remove("k", "exp")
    db.gc()
    assert db.get("k").blob().read() == shared   # shared prefix survived


def test_fork_then_remove_is_a_noop_for_foc_heads(db):
    """Tagging an existing untagged head and removing the tag must
    restore the pre-fork state — the racing head stays a GC root."""
    base = db.put("k", FString(b"v1"))
    u = db.put("k", FString(b"racing"), base_uid=base)
    assert u in db.list_untagged_branches("k")
    db.fork("k", u, "tmp")
    db.remove("k", "tmp")
    assert u in db.list_untagged_branches("k")
    db.gc()
    assert db.get("k", uid=u).string().value == b"racing"


def test_remove_aliases_of_foc_head_any_order(rng):
    """Two tags aliasing the same racing head: removing both (either
    order) restores the untagged head — never destroys it."""
    for order in (("b", "c"), ("c", "b")):
        db = ForkBase(MemoryBackend())
        base = db.put("k", FString(b"v1"))
        u = db.put("k", FString(b"racing"), base_uid=base)
        db.fork("k", u, "b")
        db.fork("k", u, "c")
        for br in order:
            db.remove("k", br)
        db.gc()
        assert db.get("k", uid=u).string().value == b"racing"
        assert u in db.list_untagged_branches("k")


def test_merged_untagged_heads_survive_tag_churn(db):
    """An M7 merge of racing heads is itself a genuine untagged head."""
    base = db.put("k", FString(b"v"))
    u1 = db.put("k", FString(b"a"), base_uid=base)
    u2 = db.put("k", FString(b"b"), base_uid=base)
    from repro.core import choose_one
    merged = db.merge("k", u1, u2, resolver=choose_one(0))
    db.fork("k", merged, "tmp")
    db.remove("k", "tmp")
    db.gc()
    assert merged in db.list_untagged_branches("k")
    assert db.get("k", uid=merged) is not None


def test_prune_unknown_branch_raises(rng):
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    _run(cs, rng, "run", range(2))
    with pytest.raises(NoSuchRef):
        cs.prune("typo", keep_last=1)


def test_remove_order_does_not_leak(db, rng):
    """Removing origin-then-fork (either order) of a never-advanced fork
    leaves nothing pinned: reclaimability must not depend on removal
    order."""
    for order in (("master", "exp"), ("exp", "master")):
        db = ForkBase(MemoryBackend())
        db.put("k", FBlob(rng.bytes(15_000)))
        db.fork("k", "master", "exp")
        for b in order:
            db.remove("k", b)
        assert db.gc().swept_chunks > 0
        assert len(db.store) == 0


def test_remove_after_branch_advanced_is_collectable(db, rng):
    db.put("k", FString(b"v"))
    db.fork("k", "master", "b")
    db.put("k", FBlob(rng.bytes(20_000)), "b")   # branch advances
    uid = db.get("k", "b").uid
    db.remove("k", "b")
    assert db.gc().swept_chunks > 0
    with pytest.raises(KeyError):
        db.get("k", uid=uid)


def test_gc_respects_foc_untagged_heads(db, rng):
    """Fork-on-conflict heads live in the UB table — they are roots even
    though no tagged branch points at them."""
    base = db.put("k", FString(b"v1"))
    u1 = db.put("k", FString(b"a"), base_uid=base)
    u2 = db.put("k", FString(b"b"), base_uid=base)
    db.gc()
    assert db.get("k", uid=u1).string().value == b"a"
    assert db.get("k", uid=u2).string().value == b"b"


# ------------------------------------------------------------------ pins

def test_pins_shield_detached_versions(db, rng):
    data = rng.bytes(30_000)
    db.put("k", FBlob(data), "tmp")
    uid = db.get("k", "tmp").uid
    db.remove("k", "tmp")
    with db.pins.hold(uid):
        assert db.gc().swept_chunks == 0
        assert db.get("k", uid=uid).blob().read() == data
    report = db.gc()                             # hold released -> swept
    assert report.swept_chunks > 0
    with pytest.raises(KeyError):
        db.get("k", uid=uid)


def test_pinset_refcounts():
    p = PinSet()
    p.pin(b"u1")
    with p.hold(b"u1", b"u2"):
        assert b"u2" in p and len(p) == 2
    assert b"u1" in p and b"u2" not in p         # outer pin survived
    p.unpin(b"u1")
    assert len(p) == 0


# ------------------------------------------------------------- exceptions

def test_typed_branch_errors(db):
    db.put("k", FString(b"x"))
    db.fork("k", "master", "b")
    with pytest.raises(BranchExists):
        db.fork("k", "master", "b")
    with pytest.raises(BranchExists):
        db.rename("k", "master", "b")
    with pytest.raises(NoSuchRef):
        db.fork("k", "ghost", "c")
    with pytest.raises(NoSuchRef):
        db.rename("k", "ghost", "c")
    with pytest.raises(NoSuchRef):
        db.merge("k", "ghost", "master")
    with pytest.raises(NoSuchRef):
        db.merge("k", "master", "ghost")
    assert isinstance(NoSuchRef("x"), KeyError)
    assert isinstance(BranchExists("x"), ValueError)


# ---------------------------------------------------------------- ckpt

def _run(cs, rng, branch, steps, shape=(48, 48)):
    state = {"w": rng.normal(size=shape).astype("float32"),
             "m": rng.normal(size=shape).astype("float32")}
    for step in steps:
        state = {k: v + 0.01 * rng.normal(size=v.shape).astype(v.dtype)
                 for k, v in state.items()}
        cs.save(state, branch, step=step)
    return state


def test_ckpt_prune_keep_last_and_every(rng):
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    state = _run(cs, rng, "run", range(10))
    n0 = len(cs.db.store)
    phys0 = cs.db.store.stats.physical_bytes
    kept, report = cs.prune("run", keep_last=2, keep_every=4)
    assert report.swept_chunks > 0
    assert len(cs.db.store) < n0
    assert cs.db.store.stats.physical_bytes < phys0
    steps = [c["step"] for _, c in cs.history("run")]
    assert steps == [9, 8, 4, 0]                 # newest 2 + every 4th
    out = cs.restore(state, "run")               # latest: byte-identical
    for k in state:
        np.testing.assert_array_equal(np.asarray(out[k]), state[k])
    cs.restore(state, uid=kept[-1])              # oldest kept still loads


def test_ckpt_prune_spares_forked_experiment(rng):
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    _run(cs, rng, "run", range(5))
    fork_uid = cs.history("run")[2][0]           # step 2
    cs.fork(fork_uid, "exp")
    state = _run(cs, rng, "exp", range(3, 6))
    cs.prune("run", keep_last=1)
    # the fork's whole lineage (incl. pre-fork history) stays reachable
    out = cs.restore(state, "exp")
    for k in state:
        np.testing.assert_array_equal(np.asarray(out[k]), state[k])
    assert cs.restore(state, uid=fork_uid) is not None
    # shared history was anchored, not rewritten: the pruned run still
    # shares an ancestor with the fork (merge/lca keep working) and its
    # history walks through the untouched pre-fork versions
    from repro.core import lca
    run_head = cs.db.get(cs.key, "run").uid
    exp_head = cs.db.get(cs.key, "exp").uid
    assert lca(cs.db.store, run_head, exp_head) == fork_uid
    assert [c["step"] for _, c in cs.history("run")] == [4, 2, 1, 0]


def test_ckpt_prune_shared_head_is_noop(rng):
    """Pruning a branch whose head IS the fork point rewrites nothing."""
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    _run(cs, rng, "run", range(3))
    cs.fork("run", "twin")                       # same head, no advance
    n0 = len(cs.db.store)
    kept, report = cs.prune("twin", keep_last=1)
    assert kept == []
    assert len(cs.db.store) == n0
    assert [c["step"] for _, c in cs.history("twin")] == [2, 1, 0]


def test_ckpt_hold_blocks_prune_reclaim(rng):
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    state = _run(cs, rng, "run", range(4))
    old_uid = cs.history("run")[3][0]            # step 0 manifest
    with cs.hold(old_uid):
        cs.prune("run", keep_last=1)
        cs.restore(state, uid=old_uid)           # still materializes
    cs.db.gc()
    with pytest.raises(KeyError):
        cs.db.get(cs.key, uid=old_uid)


# -------------------------------------------------------------- cluster

def test_cluster_gc_global_roots(rng):
    cl = Cluster(4)
    keep = {}
    for i in range(6):                           # keys land on many servlets
        k = f"key{i}"
        keep[k] = rng.bytes(20_000)
        cl.put(k, FBlob(keep[k]))
        cl.fork(k, "master", "tmp")
        cl.put(k, FBlob(rng.bytes(20_000)), "tmp")
    n0 = len(cl.index)
    for k in keep:
        cl.remove(k, "tmp")
    report = cl.gc()
    assert report.swept_chunks > 0
    assert len(cl.index) < n0
    for k, d in keep.items():                    # every survivor intact
        assert cl.get(k).blob().read() == d
    assert cl.gc().swept_chunks == 0             # idempotent
    # stats stay coherent: node stores and placement counters shrink,
    # nothing is debited into the negative, and the per-servlet
    # routing-store write counters are untouched by the sweep
    for n in cl.nodes:
        assert n.store.stats.physical_bytes >= 0
        assert n.stats.chunk_bytes >= 0 and n.stats.chunks >= 0
        assert n.servlet.store.stats.physical_bytes >= 0
    assert sum(n.stats.chunks for n in cl.nodes) == len(cl.index)


def test_single_servlet_gc_is_cluster_safe(rng):
    """gc() on ONE servlet must union the global root set — other
    servlets' keys survive even though the shared inventory is swept."""
    cl = Cluster(4)
    keep = {}
    for i in range(6):
        keep[f"key{i}"] = rng.bytes(15_000)
        cl.put(f"key{i}", FBlob(keep[f"key{i}"]))
        cl.fork(f"key{i}", "master", "tmp")
        cl.put(f"key{i}", FBlob(rng.bytes(15_000)), "tmp")
        cl.remove(f"key{i}", "tmp")
    report = cl.nodes[0].servlet.gc()      # delegates to Cluster.gc
    assert report.swept_chunks > 0
    for k, d in keep.items():
        assert cl.get(k).blob().read() == d
    # the sweep never skews any servlet's write-side routing counters
    for n in cl.nodes:
        assert n.servlet.store.stats.physical_bytes >= 0


@pytest.mark.parametrize("incremental", [False, True])
def test_cluster_gc_rebases_build_pressure_on_live_bytes(rng, incremental):
    """ROADMAP "GC-aware rebalancing": after a collection — incremental
    or stop-the-world — construction-pressure counters must track the
    post-GC LIVE byte distribution, not gross bytes ever written; a node
    whose data was mostly collected stops repelling new work."""
    from repro.core import ChunkParams
    cl = Cluster(4, "2LP", ChunkParams(q=8))
    for i in range(24):                     # one hot key: skewed pressure
        cl.put("hotkey", FBlob(rng.bytes(30_000)), branch=f"b{i}")
    gross = sum(cl.build_distribution())
    for i in range(1, 24):
        cl.remove("hotkey", f"b{i}")        # most of it becomes garbage
    report = cl.gc(incremental=incremental, budget=32)
    assert report.swept_chunks > 0
    live = [max(0, n.stats.chunk_bytes) for n in cl.nodes]
    assert cl.build_distribution() == live  # rebased on live placement
    assert sum(cl.build_distribution()) < gross
    assert cl.get("hotkey", "b0") is not None


# ------------------------------------------------- property: GC is safe

def _surviving_versions(db, key):
    """Every version reachable from any surviving head (full DAG walk)."""
    out = set()
    frontier = set(db.branches.tagged(key).values())
    frontier |= set(db.branches.untagged(key))
    while frontier:
        uid = frontier.pop()
        if uid in out:
            continue
        out.add(uid)
        from repro.core import load_fobject
        frontier |= set(load_fobject(db.store, uid).bases)
    return out


def test_gc_safety_random_workload():
    """After random put/fork/merge/remove/gc workloads, every version
    reachable from a surviving head round-trips — GC never collects
    live data."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(
        st.one_of(
            st.tuples(st.just("put"), st.integers(0, 2), st.binary(
                min_size=1, max_size=4000)),
            st.tuples(st.just("fork"), st.integers(0, 2), st.integers(0, 3)),
            st.tuples(st.just("merge"), st.integers(0, 2),
                      st.integers(0, 3)),
            st.tuples(st.just("remove"), st.integers(0, 2),
                      st.integers(0, 3)),
            st.tuples(st.just("gc"), st.just(0), st.just(0)),
        ), min_size=1, max_size=30)

    @settings(max_examples=25, deadline=None)
    @given(ops)
    def run(seq):
        db = ForkBase(MemoryBackend())
        contents: dict[bytes, bytes] = {}        # uid -> expected payload
        for op, ki, arg in seq:
            key = f"k{ki}".encode()
            branches = sorted(db.branches.tagged(key)) or ["master"]
            if op == "put":
                uid = db.put(key, FBlob(arg),
                             branches[arg[0] % len(branches)]
                             if db.branches.tagged(key) else "master")
                contents[uid] = arg
            elif op == "fork" and db.branches.tagged(key):
                src = branches[arg % len(branches)]
                try:
                    db.fork(key, src, f"b{len(branches)}")
                except BranchExists:
                    pass
            elif op == "merge" and len(branches) >= 2:
                tgt, ref = branches[arg % len(branches)], branches[
                    (arg + 1) % len(branches)]
                if tgt != ref:
                    db.merge(key, tgt, ref,
                             resolver=lambda c: c.ours)
            elif op == "remove" and db.branches.tagged(key):
                db.remove(key, branches[arg % len(branches)])
            elif op == "gc":
                db.gc()
        db.gc()
        for key in db.list_keys():
            for uid in _surviving_versions(db, key):
                h = db.get(key, uid=uid)         # must not raise
                if uid in contents and h.type == FBlob.TYPE:
                    assert h.blob().read() == contents[uid]

    run()
