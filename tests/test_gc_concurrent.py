"""Incremental concurrent GC safety — a stateful interleaving harness.

The property under test is the whole point of the tri-color design:
interleave put/fork/merge/remove/truncate/pin with collection slices
(``IncrementalCollector.step``) at random budgets, and after EVERY rule
every chunk reachable from any branch head or pin is still readable and
hash-verifies.  Barrier holes — a dedup put adopting a condemned chunk
mid-sweep, a fork re-rooting a detached subgraph mid-mark — show up as
concrete traces.

One rule set (``GCWorkload``) drives two harnesses:

  * a Hypothesis ``RuleBasedStateMachine`` (when the dev extra is
    installed — CI's fuzz job runs it at >= 500 examples), which
    shrinks any failure to a minimal op sequence;
  * a seeded reference fuzzer over the same ops that needs nothing
    beyond numpy, so the tier-1 suite exercises the interleavings even
    without the dev extra.

Also here: the deterministic pause-bound property (``step(budget=k)``
touches at most k chunks per call, mark and sweep alike, measured by a
counting store wrapper) and directed regressions for the root-barrier
rescue paths.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import BranchExists, ChunkParams, FBlob, ForkBase
from repro.core.chunk import cid_of
from repro.core.merge import MergeConflict
from repro.gc import GCPhase, mark
from repro.storage import MemoryBackend

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                     rule, run_state_machine_as_test)
    HAVE_HYPOTHESIS = True
except ImportError:          # dev extra absent: reference fuzzer only
    HAVE_HYPOTHESIS = False

KEYS = [b"k0", b"k1", b"k2"]
PARAMS = ChunkParams(q=8)        # 256 B target chunks: real trees at test sizes


class GCWorkload:
    """The shared rule set: mutator traffic + collection slices over one
    engine, with the safety invariant both harnesses check after every
    op."""

    def __init__(self):
        self.db = ForkBase(MemoryBackend(), PARAMS)
        self.col = None
        self.contents: dict[bytes, bytes] = {}   # uid -> expected payload
        self.pinned: list[bytes] = []

    # ---------------------------------------------------------- helpers
    def _branches(self, key):
        return sorted(self.db.branches.tagged(key))

    def _versions(self, key, branch):
        return [o.uid for o in self.db.track(key, branch)]

    # ---------------------------------------------------------- mutators
    def put(self, ki: int, data: bytes, pick: int):
        key = KEYS[ki]
        bs = self._branches(key)
        uid = self.db.put(key, FBlob(data),
                          bs[pick % len(bs)] if bs else "master")
        self.contents[uid] = data

    def fork_branch(self, ki: int, pick: int):
        key = KEYS[ki]
        bs = self._branches(key)
        if not bs:
            return
        try:
            self.db.fork(key, bs[pick % len(bs)], f"b{len(bs)}")
        except BranchExists:
            pass

    def fork_from_version(self, ki: int, pick: int, depth: int):
        """Re-root a historical version by uid (root-barrier path)."""
        key = KEYS[ki]
        bs = self._branches(key)
        if not bs:
            return
        uids = self._versions(key, bs[pick % len(bs)])
        if not uids:
            return
        try:
            self.db.fork(key, uids[depth % len(uids)], f"v{len(bs)}")
        except BranchExists:
            pass

    def merge_branches(self, ki: int, pick: int):
        key = KEYS[ki]
        bs = self._branches(key)
        if len(bs) < 2:
            return
        tgt = bs[pick % len(bs)]
        ref = bs[(pick + 1) % len(bs)]
        if tgt != ref:
            try:
                self.db.merge(key, tgt, ref, resolver=lambda c: c.ours)
            except MergeConflict:
                pass     # truncate can orphan ancestry: merge refused

    def remove_branch(self, ki: int, pick: int):
        key = KEYS[ki]
        bs = self._branches(key)
        if bs:
            self.db.remove(key, bs[pick % len(bs)])

    def truncate(self, ki: int, pick: int, n: int):
        key = KEYS[ki]
        bs = self._branches(key)
        if not bs:
            return
        branch = bs[pick % len(bs)]
        chain = self._versions(key, branch)
        if len(chain) < 2:
            return
        mapping = self.db.truncate_history(key, branch, chain[:n])
        for old, new in mapping.items():
            if old in self.contents:       # rewritten meta, same payload
                self.contents[new] = self.contents[old]

    def pin_version(self, ki: int, pick: int, depth: int):
        """In-flight reader: pin a reachable version (root barrier)."""
        key = KEYS[ki]
        bs = self._branches(key)
        if not bs:
            return
        uids = self._versions(key, bs[pick % len(bs)])
        if uids:
            uid = uids[depth % len(uids)]
            self.db.pins.pin(uid)
            self.pinned.append(uid)

    def unpin(self):
        if self.pinned:
            self.db.pins.unpin(self.pinned.pop())

    # ---------------------------------------------------------- collector
    def gc_begin(self):
        if self.col is None or not self.col.active:
            self.col = self.db.incremental_gc()

    def gc_step(self, budget: int):
        if self.col is not None and self.col.active:
            self.col.step(budget)

    def gc_stop_the_world(self):
        # collections are serialized: STW only runs between epochs
        if self.col is None or not self.col.active:
            self.db.gc()

    # ---------------------------------------------------------- invariant
    def check_invariant(self):
        roots = self.db.branches.all_heads() | self.db.pins.uids()
        live, _, missing = mark(self.db.store, roots)
        assert missing == 0, "a head/pin root was swept"
        for cid in live:
            # repro: allow(PERF001): invariant checker reads one cid at
            # a time so the failing cid is named in the assert
            raw = self.db.store.get(cid)       # readable (not swept)
            assert cid_of(raw) == cid          # and hash-verifies
        for key in self.db.list_keys():
            heads = set(self.db.branches.tagged(key).values())
            heads |= set(self.db.branches.untagged(key))
            for uid in heads:
                if uid in self.contents:       # payload round-trips
                    h = self.db.get(key, uid=uid)
                    assert h.blob().read() == self.contents[uid]


# ------------------------------------------- seeded reference fuzzer

def _random_op(w: GCWorkload, rng) -> None:
    op = rng.integers(0, 100)
    ki = int(rng.integers(0, 3))
    pick = int(rng.integers(0, 8))
    if op < 30:
        w.put(ki, rng.bytes(int(rng.integers(1, 1500))), pick)
    elif op < 38:
        w.fork_branch(ki, pick)
    elif op < 46:
        w.fork_from_version(ki, pick, int(rng.integers(0, 5)))
    elif op < 54:
        w.merge_branches(ki, pick)
    elif op < 64:
        w.remove_branch(ki, pick)
    elif op < 70:
        w.truncate(ki, pick, int(rng.integers(1, 3)))
    elif op < 76:
        w.pin_version(ki, pick, int(rng.integers(0, 5)))
    elif op < 80:
        w.unpin()
    elif op < 86:
        w.gc_begin()
    elif op < 97:
        w.gc_step(int(rng.integers(1, 41)))
    else:
        w.gc_stop_the_world()


def _run_reference_fuzz(episodes: int, steps: int, seed: int) -> None:
    rng = np.random.default_rng(seed)
    for _ in range(episodes):
        w = GCWorkload()
        for _ in range(steps):
            _random_op(w, rng)
            w.check_invariant()


def test_gc_interleaving_reference_fuzz():
    _run_reference_fuzz(episodes=40, steps=30, seed=0)


@pytest.mark.slow
def test_gc_interleaving_reference_fuzz_deep():
    _run_reference_fuzz(
        episodes=int(os.environ.get("GC_FUZZ_EPISODES", "500")),
        steps=40, seed=1)


# ------------------------------------------- hypothesis state machine

if HAVE_HYPOTHESIS:
    class GCInterleaving(RuleBasedStateMachine):
        """The same rule set, driven (and shrunk) by Hypothesis."""

        def __init__(self):
            super().__init__()
            self.w = GCWorkload()

        @rule(ki=st.integers(0, 2),
              data=st.binary(min_size=1, max_size=1500),
              pick=st.integers(0, 7))
        def put(self, ki, data, pick):
            self.w.put(ki, data, pick)

        @rule(ki=st.integers(0, 2), pick=st.integers(0, 7))
        def fork_branch(self, ki, pick):
            self.w.fork_branch(ki, pick)

        @rule(ki=st.integers(0, 2), pick=st.integers(0, 7),
              depth=st.integers(0, 4))
        def fork_from_version(self, ki, pick, depth):
            self.w.fork_from_version(ki, pick, depth)

        @rule(ki=st.integers(0, 2), pick=st.integers(0, 7))
        def merge_branches(self, ki, pick):
            self.w.merge_branches(ki, pick)

        @rule(ki=st.integers(0, 2), pick=st.integers(0, 7))
        def remove_branch(self, ki, pick):
            self.w.remove_branch(ki, pick)

        @rule(ki=st.integers(0, 2), pick=st.integers(0, 7),
              n=st.integers(1, 2))
        def truncate(self, ki, pick, n):
            self.w.truncate(ki, pick, n)

        @rule(ki=st.integers(0, 2), pick=st.integers(0, 7),
              depth=st.integers(0, 4))
        def pin_version(self, ki, pick, depth):
            self.w.pin_version(ki, pick, depth)

        @rule()
        def unpin(self):
            self.w.unpin()

        @rule()
        def gc_begin(self):
            self.w.gc_begin()

        @rule(budget=st.integers(1, 40))
        def gc_step(self, budget):
            self.w.gc_step(budget)

        @rule()
        def gc_stop_the_world(self):
            self.w.gc_stop_the_world()

        @invariant()
        def every_reachable_chunk_readable_and_hash_verifies(self):
            self.w.check_invariant()

    GCInterleaving.TestCase.settings = settings(
        max_examples=50, stateful_step_count=30, deadline=None)
    TestGCInterleaving = GCInterleaving.TestCase

    @pytest.mark.slow
    def test_gc_interleaving_fuzz():
        """Scheduled CI fuzz: the same machine at >= 500 examples and
        longer op sequences (GC_FUZZ_EXAMPLES overrides)."""
        examples = int(os.environ.get("GC_FUZZ_EXAMPLES", "500"))
        run_state_machine_as_test(
            GCInterleaving,
            settings=settings(max_examples=examples,
                              stateful_step_count=40, deadline=None))


# ------------------------------------------------------- pause bound


class TouchCountingBackend(MemoryBackend):
    """Counts chunk *touches* — payload reads and deletions — so a test
    can bound the work one collection slice does.  (``has_many`` /
    ``iter_cids`` are presence probes, not chunk touches.)"""

    def __init__(self):
        super().__init__()
        self.touched = 0

    def get_many(self, cids):
        self.touched += len(cids)
        return super().get_many(cids)

    def delete_many(self, cids):
        self.touched += len(cids)
        return super().delete_many(cids)


@pytest.mark.parametrize("budget", [1, 7, 32])
def test_step_touches_at_most_budget_chunks(budget, rng):
    """Deterministic pause bound: across BOTH phases, one step(budget=k)
    never reads or deletes more than k chunks."""
    store = TouchCountingBackend()
    db = ForkBase(store, PARAMS)
    db.put("k", FBlob(rng.bytes(30_000)))
    db.fork("k", "master", "tmp")
    db.put("k", FBlob(rng.bytes(150_000)), "tmp")
    db.remove("k", "tmp")                       # garbage for the sweep
    col = db.incremental_gc()
    while col.phase is not GCPhase.DONE:
        store.touched = 0
        col.step(budget)
        assert store.touched <= budget
    assert col.report.mark_rounds > 1           # mark actually sliced
    assert col.report.swept_chunks > budget     # sweep actually sliced
    assert db.get("k") is not None


def test_step_rejects_nonpositive_budget(rng):
    db = ForkBase(MemoryBackend(), PARAMS)
    db.put("k", FBlob(rng.bytes(2_000)))
    col = db.incremental_gc()
    with pytest.raises(ValueError):
        col.step(0)
    col.collect()


# ------------------------------------------------- root-barrier rescues


def test_fork_from_detached_uid_mid_sweep_rescues_subgraph(rng):
    """Re-rooting a condemned subgraph mid-sweep must transitively
    rescue every chunk of it, not just the head meta chunk."""
    db = ForkBase(MemoryBackend(), PARAMS)
    data = rng.bytes(20_000)
    uid = db.put("k", FBlob(data), "tmp")
    db.remove("k", "tmp")                       # fully detached
    col = db.incremental_gc()
    while col.step(8) is GCPhase.MARK:
        pass
    assert col.phase is GCPhase.SWEEP           # condemned, nothing swept yet
    db.fork("k", uid, "back")                   # root barrier fires
    while col.step(8) is not GCPhase.DONE:
        pass
    assert col.report.barriered > 0
    assert db.get("k", "back").blob().read() == data


def test_pin_mid_sweep_rescues_subgraph(rng):
    db = ForkBase(MemoryBackend(), PARAMS)
    data = rng.bytes(20_000)
    uid = db.put("k", FBlob(data), "tmp")
    db.remove("k", "tmp")
    col = db.incremental_gc()
    while col.step(8) is GCPhase.MARK:
        pass
    db.pins.pin(uid)                            # in-flight reader arrives
    while col.step(8) is not GCPhase.DONE:
        pass
    assert db.get("k", uid=uid).blob().read() == data
    db.pins.unpin(uid)
    assert db.gc().swept_chunks > 0             # next epoch reclaims it


def test_collections_are_serialized(rng):
    db = ForkBase(MemoryBackend(), PARAMS)
    db.put("k", FBlob(rng.bytes(5_000)))
    col = db.incremental_gc()
    with pytest.raises(RuntimeError):
        col.begin()
    col.collect()
    assert col.begin() == 2                     # reusable across epochs
    col.collect()


def test_pin_mid_sweep_rescues_through_gc_hooks(rng):
    """The transitive mid-sweep rescue must follow application-level
    link extractors too: a checkpoint manifest's tensor-tree roots live
    only in its JSON values (``manifest_refs``), and pinning a condemned
    checkpoint must rescue the tensors, not just the manifest chain."""
    from repro.ckpt.store import CheckpointStore
    cs = CheckpointStore(ForkBase(MemoryBackend()))
    state = {"w": rng.normal(size=(48, 48)).astype("float32")}
    uid = cs.save(state, "run", step=0)
    cs.db.remove(cs.key, "run")                 # whole run condemned
    col = cs.db.incremental_gc()
    while col.step(8) is GCPhase.MARK:
        pass
    assert col.phase is GCPhase.SWEEP
    cs.db.pins.pin(uid)                         # late reader pins the ckpt
    while col.step(8) is not GCPhase.DONE:
        pass
    out = cs.restore(state, uid=uid)            # tensors fully readable
    np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])


def test_external_engine_root_barrier_reaches_cluster_collection(rng):
    """An external ForkBase sharing a servlet's routing store begins the
    collection; its own fork-from-uid mid-sweep must still rescue."""
    from repro.core import Cluster
    cl = Cluster(3)
    db = ForkBase(cl.nodes[0].servlet.store)    # external committer
    data = rng.bytes(20_000)
    uid = db.put("k", FBlob(data), "tmp")
    db.remove("k", "tmp")                       # detached
    col = db.incremental_gc()                   # delegates to the cluster
    while col.step(8) is GCPhase.MARK:
        pass
    assert col.phase is GCPhase.SWEEP
    db.fork("k", uid, "back")                   # external root barrier
    while col.step(8) is not GCPhase.DONE:
        pass
    assert db.get("k", "back").blob().read() == data


def test_root_barrier_counts_only_present_rescues(rng):
    """Regression: the transitive mid-sweep rescue used to count every
    frontier cid in report.barriered, including cids the store no
    longer holds — which were never going to be deleted and were never
    'rescued' from anything."""
    db = ForkBase(MemoryBackend(), PARAMS)
    uid = db.put("k", FBlob(rng.bytes(20_000)), "tmp")
    db.remove("k", "tmp")                       # fully detached
    col = db.incremental_gc()
    while col.step(8) is GCPhase.MARK:
        pass
    assert col.phase is GCPhase.SWEEP
    # one condemned chunk silently vanishes (lost replica / bit-rot
    # delete) while STAYING in the condemned set
    from repro.gc import chunk_refs
    victim = next(c for c in sorted(col._condemned_set)
                  if c != uid and not chunk_refs(db.store._data[c]))
    del db.store._data[victim]          # a leaf: the rest stays connected
    expected = sum(1 for c in col._condemned_set
                   if c in db.store._data)
    db.fork("k", uid, "back")                   # transitive rescue
    assert col.report.barriered == expected     # pre-fix: expected + 1
    while col.step(8) is not GCPhase.DONE:
        pass


def test_freeze_consumes_inventory_in_budget_slices(rng):
    """Sliced inventory freeze (ROADMAP): the MARK->SWEEP transition
    must consume at most ``budget`` inventory cids per step instead of
    filtering the whole store in one pause."""
    from repro.gc import IncrementalCollector
    store = MemoryBackend()
    db = ForkBase(store, PARAMS)
    db.put("k", FBlob(rng.bytes(40_000)))
    db.put("k", FBlob(rng.bytes(40_000)), "tmp")
    db.remove("k", "tmp")
    consumed = {"n": 0}

    def counting_inventory():
        def gen():
            for cid in store.iter_cids():
                consumed["n"] += 1
                yield cid
        return gen()

    col = IncrementalCollector(store, branches=db.branches,
                               inventory_fn=counting_inventory)
    col.begin()
    budget = 16
    freeze_slices = 0
    while col.phase is GCPhase.MARK:
        before = consumed["n"]
        col.step(budget)
        took = consumed["n"] - before
        assert took <= budget                   # bounded pause per slice
        if took:
            freeze_slices += 1
    n_inventory = len(store)
    assert consumed["n"] >= n_inventory         # whole inventory seen
    assert freeze_slices >= (n_inventory + budget - 1) // budget
    while col.step(budget) is not GCPhase.DONE:
        pass
    assert db.get("k").blob() is not None       # live value intact
    assert col.report.swept_chunks > 0          # garbage reclaimed


def test_put_during_freeze_is_not_condemned(rng):
    """A chunk put (or dedup-adopted) while the inventory freeze is in
    progress must never enter the condemned set — the barrier keeps
    MARK semantics until SWEEP actually begins."""
    db = ForkBase(MemoryBackend(), PARAMS)
    data = rng.bytes(30_000)
    db.put("k", FBlob(data), "tmp")
    db.remove("k", "tmp")                       # detached: all condemned
    db.put("other", FBlob(rng.bytes(30_000)))   # live ballast to mark
    col = db.incremental_gc()
    while col.phase is GCPhase.MARK and col._inv_iter is None:
        col.step(1)                             # reach the freeze window
    assert col.phase is GCPhase.MARK and col._inv_iter is not None
    uid = db.put("k", FBlob(data))              # dedups onto condemned
    while col.step(1) is not GCPhase.DONE:
        pass
    assert db.get("k", uid=uid).blob().read() == data


def test_finished_collectors_do_not_accumulate(rng):
    db = ForkBase(MemoryBackend())
    for _ in range(5):
        db.put("k", FBlob(rng.bytes(3_000)))
        db.gc(incremental=True, budget=16)
    assert len(db.gc_collectors) == 1           # finished epochs dropped
    assert db.gc_collectors[0].marked == frozenset()   # O(live) set freed


def test_mid_mark_remove_is_floating_garbage_not_unsafe(rng):
    """A branch removed after the snapshot stays live THIS epoch (its
    chunks were snapshot roots) and falls in the next — never a use-
    after-sweep, never a leak."""
    db = ForkBase(MemoryBackend(), PARAMS)
    keep = rng.bytes(15_000)
    db.put("k", FBlob(keep))
    db.fork("k", "master", "tmp")
    db.put("k", FBlob(rng.bytes(15_000)), "tmp")
    col = db.incremental_gc()
    col.step(4)
    db.remove("k", "tmp")                       # mid-mark removal
    while col.step(16) is not GCPhase.DONE:
        pass
    assert col.report.swept_chunks == 0         # floating this epoch
    assert db.gc().swept_chunks > 0             # reclaimed next epoch
    assert db.get("k").blob().read() == keep
