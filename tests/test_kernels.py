"""Pallas kernel oracle sweeps: shapes x dtypes x params vs ref.py."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.kernels.chunker import boundary_bitmap_pallas
from repro.kernels.fphash import fphash
from repro.kernels.ops import use_pallas_chunker
from repro.kernels.ref import boundary_bitmap_ref, fphash_ref


@pytest.mark.parametrize("n", [1, 47, 48, 255, 4991, 4992, 4993, 39936,
                               100_001])
@pytest.mark.parametrize("wq", [(48, 12), (16, 8), (128, 10), (4, 4)])
def test_chunker_matches_ref(n, wq, rng):
    w, q = wq
    data = rng.integers(0, 256, n, dtype=np.uint8)
    got = boundary_bitmap_pallas(data, w, q)
    want = boundary_bitmap_ref(data, w, q)
    np.testing.assert_array_equal(got, want)


@given(st.binary(min_size=0, max_size=3000), st.sampled_from([8, 16, 48]))
@settings(max_examples=20, deadline=None)
def test_chunker_property(data, w):
    arr = np.frombuffer(data, dtype=np.uint8)
    got = boundary_bitmap_pallas(arr, w, 6)
    want = boundary_bitmap_ref(arr, w, 6)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [0, 1, 31, 4095, 4096, 4097, 12288, 65536])
def test_fphash_matches_ref(n, rng):
    data = rng.bytes(n)
    assert fphash(data) == fphash_ref(data)


def test_fphash_avalanche(rng):
    d = bytearray(rng.bytes(5000))
    h0 = fphash(bytes(d))
    d[2500] ^= 1
    h1 = fphash(bytes(d))
    assert h0 != h1
    diff = bin(int.from_bytes(h0, "little")
               ^ int.from_bytes(h1, "little")).count("1")
    assert 64 < diff < 192       # ~half the 256 bits flip


def test_engine_identical_trees_with_pallas(rng):
    """Flipping the storage engine to the Pallas chunker must not change
    any root cid (same boundaries bit-for-bit)."""
    from repro.core import ChunkParams, ChunkStore, POSTree
    data = rng.integers(0, 256, 150_000, dtype=np.uint8)
    s = ChunkStore()
    t_np = POSTree.build_bytes(s, data, ChunkParams())
    use_pallas_chunker(True)
    try:
        t_pl = POSTree.build_bytes(s, data, ChunkParams())
    finally:
        use_pallas_chunker(False)
    assert t_np.root_cid == t_pl.root_cid
