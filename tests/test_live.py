"""Live flat-state fast path (repro.live) — LiveDB/ArchiveDB split.

Directed tests for LiveTable semantics (overlay/caches/staleness),
epoch folds (the batched Merkle commitment), engine plumbing
(fork-folds-first, commit_epoch, fence pinning), the attest pin delta
and EpochFence bloom spill, the floating-garbage bound, the live app
modes (ledger, wiki), and cluster routing — plus the equivalence fuzz:
random put/delete/fork/fold/gc interleavings where the folded POS-Tree
root must stay bit-identical to a tree built directly from the model
dict, live-served gets must match the model, and every proof verb must
verify against live-served values.

Like test_gc_concurrent.py, one rule set drives both a Hypothesis
state machine (dev extra) and a seeded numpy reference fuzzer (tier-1).
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import ChunkParams, FMap, ForkBase, NoSuchRef
from repro.gc import EpochFence, GCPhase
from repro.live import EpochPolicy
from repro.storage import MemoryBackend

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                     rule, run_state_machine_as_test)
    HAVE_HYPOTHESIS = True
except ImportError:          # dev extra absent: reference fuzzer only
    HAVE_HYPOTHESIS = False

PARAMS = ChunkParams(q=8)        # 256 B target chunks: real trees at test sizes
KEY = b"state"


def mkdb():
    return ForkBase(MemoryBackend(), PARAMS)


def kv(i: int) -> tuple[bytes, bytes]:
    return f"k{i:05d}".encode(), f"v{i:05d}".encode() * 3


def direct_root(model: dict[bytes, bytes]) -> bytes:
    """Root of a POS-Tree built directly from the model dict in a
    scratch store — the bit-identical reference for folded roots."""
    return FMap(dict(model), params=PARAMS).commit(MemoryBackend())


# --------------------------------------------------------- table basics
def test_live_put_get_delete_fold():
    db = mkdb()
    t = db.live(KEY)
    assert t.get(b"a") is None
    t.put(b"a", b"1")
    t.put(b"b", b"2")
    assert t.get(b"a") == b"1" and t.get(b"b") == b"2"
    assert db.get(KEY) is None                    # nothing folded yet
    rep = t.fold()
    assert rep.folded_keys == 2 and rep.uid is not None
    assert t.dirty_count == 0
    h = db.get(KEY)
    assert h.uid == rep.uid
    assert h.map().get(b"a") == b"1"
    t.delete(b"a")
    assert t.get(b"a") is None                    # overlay delete wins
    rep2 = t.fold()
    assert rep2.deleted_keys == 1
    assert db.get(KEY).map().get(b"a") is None
    assert t.get(b"a") is None                    # negative cache after fold
    # a second table handle is the same object
    assert db.live(KEY) is t
    # empty fold is a no-op
    assert t.fold().uid == rep2.uid and t.stats.folds == 2


def test_live_reads_through_archive():
    db = mkdb()
    t = db.live(KEY)
    for i in range(200):
        k, v = kv(i)
        t.put(k, v)
    t.fold()
    # cold-cache reads are served from the archive tree, then cached
    t._clean.clear()
    t._absent.clear()
    m0 = t.stats.misses
    k, v = kv(77)
    assert t.get(k) == v
    assert t.stats.misses == m0 + 1
    assert t.get(k) == v                          # now cached: a hit
    assert t.stats.misses == m0 + 1
    assert t.load_all() > 0
    assert t.get(kv(3)[0]) == kv(3)[1]


def test_folded_root_bit_identical_to_direct_tree():
    db = mkdb()
    t = db.live(KEY)
    rng = np.random.default_rng(7)
    model: dict[bytes, bytes] = {}
    for i in rng.permutation(300):
        k, v = kv(int(i))
        t.put(k, v)
        model[k] = v
    t.fold()
    for i in range(0, 300, 7):                    # second epoch: mixed delta
        k, _ = kv(i)
        t.delete(k)
        model.pop(k, None)
    for i in range(300, 340):
        k, v = kv(i)
        t.put(k, v)
        model[k] = v
    t.fold()
    assert db.get(KEY).obj.data == direct_root(model)
    assert dict(t.items()) == model


def test_archive_versions_and_history():
    db = mkdb()
    t = db.live(KEY)
    t.put(b"x", b"1")
    u1 = t.fold(context=b"e1").uid
    t.put(b"x", b"2")
    t.put(b"y", b"9")
    u2 = t.fold(context=b"e2").uid
    objs = db.track(KEY, "master")
    assert [o.uid for o in objs] == [u2, u1]
    assert db.get(KEY, uid=u1).map().get(b"x") == b"1"
    assert db.get(KEY, uid=u2).map().get(b"x") == b"2"
    assert db.verify_lineage(u2, u1)


def test_fork_and_merge_fold_first():
    db = mkdb()
    t = db.live(KEY)
    t.put(b"a", b"1")
    db.fork(KEY, "master", "dev")                 # dirty head folds first
    assert t.dirty_count == 0
    assert db.get(KEY, "dev").map().get(b"a") == b"1"
    td = db.live(KEY, "dev")
    td.put(b"b", b"2")
    t.put(b"c", b"3")
    db.merge(KEY, "master", "dev")                # both inputs fold first
    assert t.dirty_count == 0 and td.dirty_count == 0
    m = db.get(KEY).map()
    assert (m.get(b"a"), m.get(b"b"), m.get(b"c")) == (b"1", b"2", b"3")


def test_external_put_revalidates_keeping_overlay():
    db = mkdb()
    t = db.live(KEY)
    t.put(b"a", b"1")
    t.fold()
    t.put(b"b", b"overlay")                       # dirty across the move
    m = db.get(KEY).map()
    m.set(b"c", b"external")
    db.put(KEY, m)                                # head moves under the table
    assert t.get(b"c") == b"external"             # revalidated read-through
    assert t.get(b"b") == b"overlay"              # overlay survived
    assert t.stats.revalidations >= 1
    t.fold()
    final = db.get(KEY).map()
    assert (final.get(b"a"), final.get(b"b"), final.get(b"c")) == \
        (b"1", b"overlay", b"external")


def test_epoch_policy_auto_fold():
    db = mkdb()
    t = db.live(KEY, policy=EpochPolicy(max_dirty_keys=4,
                                        max_dirty_bytes=None))
    for i in range(4):
        t.put(*kv(i))
    assert t.stats.auto_folds == 1 and t.dirty_count == 0
    db2 = mkdb()
    t2 = db2.live(KEY, policy=EpochPolicy(max_dirty_keys=None,
                                          max_dirty_bytes=64))
    t2.put(b"big", b"x" * 100)
    assert t2.stats.auto_folds == 1 and t2.stats.dirty_bytes == 0


def test_rename_and_remove_live_registry():
    db = mkdb()
    t = db.live(KEY)
    t.put(b"a", b"1")
    t.fold()
    db.rename(KEY, "master", "main")
    assert db.live(KEY, "main") is t and t.branch == "main"
    t.put(b"b", b"2")
    db.remove(KEY, "main")                        # unfolded delta dies too
    t2 = db.live(KEY, "main")
    assert t2 is not t and t2.get(b"b") is None


def test_commit_epoch_folds_pins_and_attests():
    db = mkdb()
    ta = db.live(b"ka")
    tb = db.live(b"kb", "master")
    ta.put(b"x", b"1")
    tb.put(b"y", b"2")
    db.live(b"kc")                                # clean table: not folded
    p0 = db.gc_fence.pin_count()
    rep = db.commit_epoch(context=b"epoch", attest=True, secret=b"s")
    assert len(rep.folds) == 2 and rep.folded_keys == 2
    assert sorted(f.key for f in rep.folds) == [b"ka", b"kb"]
    # folded heads pinned under the fence handshake (attest pins more)
    assert db.gc_fence.pin_count() >= p0 + 2
    assert rep.attestation is not None
    from repro.proof.attest import verify_attestation
    verify_attestation(rep.attestation, secret=b"s")
    # the folds are durable heads
    assert db.get(b"ka").map().get(b"x") == b"1"
    assert db.get(b"kb").map().get(b"y") == b"2"


# ------------------------------------------------- attest pin delta path
def test_attest_pins_only_dirty_heads_after_baseline():
    db = mkdb()
    from repro.core import FBlob
    for i in range(12):
        db.put(f"key{i}".encode(), FBlob(b"v" * 40))
    db.attest()                                   # baseline: all heads
    base = db.gc_fence.pin_count()
    assert base >= 12
    db.put(b"key3", FBlob(b"w" * 40))             # one dirty key
    db.attest()
    delta = db.gc_fence.pin_count() - base
    # O(heads of the one dirty key), not O(all heads)
    assert 1 <= delta <= 2
    # a collection advances the fence epoch -> next attest re-baselines
    db.gc(incremental=True, budget=64)
    db.put(b"key5", FBlob(b"z" * 40))
    db.attest()
    assert db.gc_fence.pin_count() >= 12


def test_epoch_fence_bloom_spill_bounds_pin_memory():
    uids = [bytes([i]) * 32 for i in range(1, 9)]
    fence = EpochFence(max_pins=3)
    fence.heads_fn = lambda: uids                 # all still current heads
    fence.pin(uids)
    assert fence.pin_count() == 8                 # 3 exact + 5 spilled
    assert len(fence._pins[fence.epoch]) == 3     # memory bound holds
    roots = fence.grace_roots()
    assert set(uids) <= roots                     # spilled pins recovered
    # a spilled pin that is NO LONGER a head is not recovered (the
    # documented trade); an exact pin survives regardless
    fence.heads_fn = lambda: uids[:4]
    roots = fence.grace_roots()
    assert set(uids[:3]) <= roots and uids[3] in roots
    assert not (set(uids[5:]) & roots)
    # expiry drops bloom state with the epoch
    fence.begin_epoch()
    fence.begin_epoch()
    assert fence.pin_count(0) == 0 and not fence._blooms


# ------------------------------------------------ floating-garbage bound
def test_floating_garbage_counted_across_epochs():
    from repro.core import FBlob
    db = mkdb()
    db.put(b"keep", FBlob(b"K" * 600))
    db.put(b"doomed", FBlob(b"D" * 600))
    r1 = db.gc(incremental=True, budget=32)
    assert r1.floating_garbage == 0               # no previous epoch
    db.remove(b"doomed", "master")                # orphan a marked-live head
    r2 = db.gc(incremental=True, budget=32)
    assert r2.swept_chunks > 0
    # everything swept now was live last epoch: pure floating garbage
    assert r2.floating_garbage == r2.swept_chunks
    r3 = db.gc(incremental=True, budget=32)
    assert r3.floating_garbage == 0


# -------------------------------------------- proof verbs vs live values
def test_proof_verbs_verify_against_live_values():
    from repro.proof import verify_member
    db = mkdb()
    t = db.live(KEY)
    for i in range(120):
        t.put(*kv(i))
    t.delete(kv(60)[0])
    t.fold()
    root = db.get(KEY).obj.data
    for i in (0, 13, 59, 119):
        k, _ = kv(i)
        claim = verify_member(root, db.prove_member(KEY, item_key=k))
        assert claim.key == k and claim.value == t.get(k)
    gone = kv(60)[0]
    assert t.get(gone) is None
    claim = verify_member(root, db.prove_absence(KEY, item_key=gone))
    assert claim.key == gone


# ------------------------------------------------------------- app modes
def test_ledger_live_mode_matches_archival():
    from repro.apps import ForkBaseLedger
    from repro.apps.blockchain import LightClient
    arch = ForkBaseLedger(mkdb())
    live = ForkBaseLedger(mkdb(), live=True)
    for led in (arch, live):
        led.write("bank", "alice", b"100")
        led.write("bank", "bob", b"50")
        led.commit()
        led.write("bank", "alice", b"75")
        led.write("mkt", "gold", b"1900")
        led.commit()
    assert live.read("bank", "alice") == b"75"
    assert live.block_scan(0) == arch.block_scan(0)
    assert live.block_scan(1) == arch.block_scan(1)
    assert [v for _, v in live.state_scan("bank", "alice")] == \
        [v for _, v in arch.state_scan("bank", "alice")]
    assert live.verify_block(0)
    # flat state proof closes against a light client's trusted head
    proof = live.prove_state_flat("bank", "alice")
    client = LightClient(live.db.get("chain").uid)
    dist, val = client.verify_state_flat(proof, "bank", "alice")
    assert (dist, val) == (0, b"75")
    old = live.prove_state_flat("bank", "alice", height=0)
    assert client.verify_state_flat(old, "bank", "alice") == (1, b"100")
    from repro.proof import InvalidProof
    with pytest.raises(InvalidProof):
        client.verify_state_flat(proof, "bank", "bob")


def test_live_wiki_epoch_history():
    from repro.apps import LiveWiki
    w = LiveWiki(mkdb())
    w.create("Page", b"draft " * 60)
    assert w.load("Page") == b"draft " * 60
    w.fold()
    w.edit("Page", b"final " * 60)
    w.fold()
    assert w.read_version("Page", 0) == b"final " * 60
    assert w.read_version("Page", 1) == b"draft " * 60


def test_cluster_live_routing():
    from repro.core.cluster import Cluster
    cluster = Cluster(3, "2LP", PARAMS)
    keys = [f"ck{i}".encode() for i in range(6)]
    for i, k in enumerate(keys):
        cluster.live(k).put(b"n", str(i).encode())
    reps = cluster.commit_epoch(context=b"e0")
    assert sum(len(r.folds) for r in reps) == len(keys)
    for i, k in enumerate(keys):
        assert cluster.get(k).map().get(b"n") == str(i).encode()
        assert cluster.live(k).get(b"n") == str(i).encode()


# ------------------------------------------------------ equivalence fuzz
class LiveWorkload:
    """Shared rule set: live-table traffic + folds + forks + GC slices
    over one engine, with per-op model equivalence and per-fold root
    bit-identity checks."""

    def __init__(self):
        self.db = mkdb()
        self.models: dict[str, dict[bytes, bytes]] = {"master": {}}
        self.col = None
        self.nfork = 0

    def _branch(self, pick: int) -> str:
        bs = sorted(self.models)
        return bs[pick % len(bs)]

    # ---------------------------------------------------------- mutators
    def put(self, pick: int, ki: int, payload: bytes):
        b = self._branch(pick)
        k, _ = kv(ki)
        self.db.live(KEY, b).put(k, payload)
        self.models[b][k] = payload

    def delete(self, pick: int, ki: int):
        b = self._branch(pick)
        k, _ = kv(ki)
        self.db.live(KEY, b).delete(k)
        self.models[b].pop(k, None)

    def fold(self, pick: int):
        b = self._branch(pick)
        self.db.live(KEY, b).fold()

    def fork(self, pick: int):
        if len(self.models) >= 4:
            return
        src = self._branch(pick)
        t = self.db.live(KEY, src)
        if t.dirty_count == 0 and \
                self.db.branches.head(KEY, src) is None:
            return                                 # nothing to fork yet
        self.nfork += 1
        new = f"b{self.nfork}"
        try:
            self.db.fork(KEY, src, new)
        except NoSuchRef:
            return
        self.models[new] = dict(self.models[src])

    def gc_step(self, budget: int):
        if self.col is None or not self.col.active:
            self.col = self.db.incremental_gc()
        self.col.step(budget)

    def gc_full(self):
        # drain an in-flight collection instead of stacking a second
        # concurrent epoch on the same store
        if self.col is not None and self.col.active:
            while self.col.step(64) is not GCPhase.DONE:
                pass
            self.col = None
            return
        self.db.gc(incremental=True, budget=64)

    # ---------------------------------------------------------- checks
    def check_serving(self):
        """Live gets match the model on every branch, hit or miss."""
        for b, model in self.models.items():
            t = self.db.live(KEY, b)
            for k in list(model)[:6]:
                assert t.get(k) == model[k], (b, k)
            assert t.get(b"\xffmissing") is None

    def check_roots(self):
        """Fold every branch: each folded root must be bit-identical to
        a tree built directly from the model dict, and proofs against it
        must verify live-served values."""
        from repro.proof import verify_member
        for b, model in sorted(self.models.items()):
            t = self.db.live(KEY, b)
            t.fold()
            h = self.db.get(KEY, b)
            if h is None:
                assert not model, b
                continue
            assert h.obj.data == direct_root(model), b
            for k in list(model)[:3]:
                claim = verify_member(
                    h.obj.data, self.db.prove_member(KEY, b, item_key=k))
                assert claim.value == t.get(k), (b, k)

    def finish(self):
        while self.col is not None and self.col.active:
            self.col.step(64)
        self.check_roots()
        self.check_serving()


def _payloads(rng):
    n = int(rng.integers(1, 60))
    return bytes(rng.integers(97, 123, size=n, dtype=np.uint8))


def test_live_equivalence_reference_fuzz():
    """Seeded fuzz over the shared rule set — tier-1's hypothesis-free
    twin of the state machine below."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        w = LiveWorkload()
        for _ in range(120):
            op = int(rng.integers(0, 100))
            pick = int(rng.integers(0, 4))
            if op < 45:
                w.put(pick, int(rng.integers(0, 80)), _payloads(rng))
            elif op < 60:
                w.delete(pick, int(rng.integers(0, 80)))
            elif op < 72:
                w.fold(pick)
            elif op < 80:
                w.fork(pick)
            elif op < 92:
                w.gc_step(int(rng.integers(1, 48)))
            else:
                w.gc_full()
            w.check_serving()
        w.finish()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_live_equivalence_state_machine():
    n = int(os.environ.get("LIVE_FUZZ_EXAMPLES", "25"))

    class LiveMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.w = LiveWorkload()

        @rule(pick=st.integers(0, 3), ki=st.integers(0, 80),
              payload=st.binary(min_size=1, max_size=60))
        def put(self, pick, ki, payload):
            self.w.put(pick, ki, payload)

        @rule(pick=st.integers(0, 3), ki=st.integers(0, 80))
        def delete(self, pick, ki):
            self.w.delete(pick, ki)

        @rule(pick=st.integers(0, 3))
        def fold(self, pick):
            self.w.fold(pick)

        @rule(pick=st.integers(0, 3))
        def fork(self, pick):
            self.w.fork(pick)

        @rule(budget=st.integers(1, 48))
        def gc_step(self, budget):
            self.w.gc_step(budget)

        @rule()
        def gc_full(self):
            self.w.gc_full()

        @invariant()
        def serving_matches_model(self):
            self.w.check_serving()

        def teardown(self):
            self.w.finish()

    run_state_machine_as_test(
        LiveMachine,
        settings=settings(max_examples=n, stateful_step_count=40,
                          deadline=None))


def test_gc_phase_exported_for_interleaving():
    # the fuzz drives collections through the public phase enum
    assert GCPhase.MARK is not GCPhase.SWEEP
