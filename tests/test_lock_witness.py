"""Runtime lock witness (repro.core.locking): rank inversions, cycles,
hold accounting, and end-to-end wiring through the threaded cluster."""
import threading

import pytest

from repro.core import locking
from repro.core.locking import (LOCK_ATTRS, LOCK_ORDER, LockWitness,
                                WitnessLock, make_lock)
from repro.errors import ConfigError, InvariantViolation


# ------------------------------------------------------------- the tables

def test_lock_order_table_is_consistent():
    # every attribute resolves to a declared rank; servlet is outermost
    for attr, rank_name in LOCK_ATTRS.items():
        assert rank_name in LOCK_ORDER, attr
    assert LOCK_ORDER["servlet"] < LOCK_ORDER["collector"]
    assert LOCK_ORDER["collector"] < LOCK_ORDER["index"]
    assert LOCK_ORDER["index"] == LOCK_ORDER["store"]   # incomparable pair
    assert LOCK_ORDER["fence"] > LOCK_ORDER["store"]


def test_make_lock_plain_when_witness_off():
    if locking.witness_enabled():
        pytest.skip("suite runs under REPRO_LOCK_WITNESS=1")
    lk = make_lock("servlet")
    assert not isinstance(lk, WitnessLock)
    with lk:            # still a working RLock
        with lk:
            pass


def test_make_lock_witnessed_when_enabled():
    locking.enable_witness()
    try:
        lk = make_lock("store", label="n0")
        assert isinstance(lk, WitnessLock)
        assert lk.display == "store[n0]"
    finally:
        locking.disable_witness()


def test_unranked_name_rejected():
    with pytest.raises(ConfigError):
        WitnessLock("bogus")
    with pytest.raises(ConfigError):
        make_lock("bogus")


# ------------------------------------------------------------- detection

def test_single_thread_rank_inversion_detected():
    w = LockWitness()
    servlet = WitnessLock("servlet", label="n0", witness=w)
    coll = WitnessLock("collector", label="gc", witness=w)
    with coll:
        with servlet:            # servlet(10) under collector(20): inverted
            pass
    assert len(w.violations) == 1
    v = w.violations[0]
    assert v.kind == "rank-inversion"
    assert v.acquiring == "servlet[n0]"
    assert "collector[gc]" in v.held
    with pytest.raises(InvariantViolation):
        w.assert_clean()


def test_ascending_nesting_is_clean():
    w = LockWitness()
    servlet = WitnessLock("servlet", witness=w)
    coll = WitnessLock("collector", witness=w)
    store = WitnessLock("store", witness=w)
    with servlet:
        with coll:
            with store:
                pass
    w.assert_clean()
    assert w.violations == []


def test_two_thread_inversion_detected():
    # t1 takes a then b; t2 takes b then a.  Threads run SEQUENTIALLY —
    # the witness flags the *order* (a latent deadlock) without needing
    # the unlucky interleaving that would actually wedge.
    w = LockWitness()
    a = WitnessLock("index", label="a", witness=w)
    b = WitnessLock("store", label="b", witness=w)

    def t1():
        with a:
            with b:
                pass

    def t2():
        with b:
            with a:
                pass

    th = threading.Thread(target=t1, name="t1")
    th.start(); th.join()
    assert w.violations == []        # first order just seeds the graph
    th = threading.Thread(target=t2, name="t2")
    th.start(); th.join()
    kinds = [v.kind for v in w.violations]
    assert "cycle" in kinds
    v = next(v for v in w.violations if v.kind == "cycle")
    assert v.thread == "t2"
    assert v.acquiring == "index[a]"
    with pytest.raises(InvariantViolation) as ei:
        w.assert_clean()
    assert "cycle" in str(ei.value)


def test_gc_acquisition_pattern_is_clean():
    # mimic incremental_gc: all servlet locks ascending, collector inside;
    # then a mutator thread takes one servlet lock, then the collector.
    w = LockWitness()
    servlets = [WitnessLock("servlet", label=f"n{i}", witness=w)
                for i in range(3)]
    coll = WitnessLock("collector", label="gc", witness=w)

    def begin():
        from contextlib import ExitStack
        with ExitStack() as stack:
            for lk in servlets:
                stack.enter_context(lk)
            with coll:
                pass

    def mutate():
        with servlets[1]:
            with coll:
                pass

    for fn in (begin, mutate):
        th = threading.Thread(target=fn)
        th.start(); th.join()
    w.assert_clean()


def test_gc_pattern_reverted_order_is_flagged():
    # the pre-fix shape — collector (begin()) before the servlet locks —
    # is exactly a rank inversion the witness refuses
    w = LockWitness()
    servlet = WitnessLock("servlet", label="n0", witness=w)
    coll = WitnessLock("collector", label="gc", witness=w)
    with coll:
        with servlet:
            pass
    assert any(v.kind == "rank-inversion" for v in w.violations)


def test_descending_servlet_nesting_is_flagged():
    # same-rank locks escape the rank check; the cycle detector catches
    # the AB/BA pair across two threads
    w = LockWitness()
    n0 = WitnessLock("servlet", label="n0", witness=w)
    n1 = WitnessLock("servlet", label="n1", witness=w)

    def ascending():
        with n0:
            with n1:
                pass

    def descending():
        with n1:
            with n0:
                pass

    for fn in (ascending, descending):
        th = threading.Thread(target=fn)
        th.start(); th.join()
    assert any(v.kind == "cycle" for v in w.violations)


# ------------------------------------------------------------ accounting

def test_reentrant_acquire_reports_once():
    w = LockWitness()
    lk = WitnessLock("servlet", label="n0", witness=w)
    with lk:
        with lk:                 # re-entry: depth-counted, not re-reported
            pass
    st = w.holds["servlet[n0]"]
    assert st.acquisitions == 1
    assert st.held_total_s >= 0.0
    assert st.held_max_s <= st.held_total_s + 1e-9


def test_report_shape():
    w = LockWitness()
    lk = WitnessLock("fence", label="f", witness=w)
    with lk:
        pass
    rep = w.report()
    assert rep["violations"] == []
    assert rep["locks"]["fence[f]"]["acquisitions"] == 1
    assert rep["locks"]["fence[f]"]["held_max_s"] >= 0.0


def test_reset_clears_graph_and_stats():
    w = LockWitness()
    a = WitnessLock("index", witness=w)
    b = WitnessLock("store", witness=w)
    with a:
        with b:
            pass
    w.reset()
    assert w.holds == {} and w.violations == []
    # opposite order after reset: no stale edge -> no cycle
    with b:
        with a:
            pass
    assert w.violations == []


# ----------------------------------------------------- end-to-end wiring

def test_witnessed_cluster_round_trip(rng):
    """Real cluster under the witness: puts, reads, and a full
    incremental GC epoch acquire ranked locks only in documented
    order."""
    locking.enable_witness()
    locking.WITNESS.reset()
    try:
        from repro.core.cluster import Cluster
        cl = Cluster(n_nodes=3)
        for i in range(4):
            cl.put(f"k{i}".encode(), rng.integers(0, 256, 4096,
                                                  dtype="u1").tobytes())
        from repro.gc.incremental import GCPhase
        col = cl.incremental_gc()
        while col.step(budget=64) is not GCPhase.DONE:
            pass
        for i in range(4):
            assert cl.get(f"k{i}".encode()) is not None
        locking.WITNESS.assert_clean()
        rep = locking.WITNESS.report()
        ranks = {name.split("[")[0].split("#")[0]
                 for name in rep["locks"]}
        assert "servlet" in ranks            # the wiring is actually live
    finally:
        locking.disable_witness()
        locking.WITNESS.reset()
