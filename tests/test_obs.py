"""Observability layer: metrics, spans, events, exporters, integration.

Every test resets the process-wide registry/journal FIRST and builds
its stores AFTER the reset: ``REGISTRY.reset()`` drops the instrument
table, so per-instance histogram caches inside stores created before
the reset would record into orphaned instruments.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.core import Cluster, FBlob, ForkBase
from repro.storage import MemoryBackend
from repro.storage.backend import StoreStats, TamperedChunk
from repro.storage.durable import SegmentBackend, open_durable


@pytest.fixture(autouse=True)
def fresh_obs():
    obs.reset()
    obs.enable()
    yield
    obs.reset()
    obs.enable()


# ---------------------------------------------------------------- metrics

def test_histogram_buckets_and_percentiles():
    h = obs.histogram("t_us")
    for _ in range(99):
        h.observe(3e-6)            # 3 µs -> bucket [2, 4) µs
    h.observe(1000e-6)             # one 1 ms outlier
    assert h.count == 100
    assert h.p50 == 4.0            # power-of-two upper bound
    assert h.p99 == 4.0
    assert h.percentile(1.0) == 1024.0
    assert h.max_us == pytest.approx(1000.0)
    assert h.mean_us == pytest.approx((99 * 3 + 1000) / 100)
    v = h.as_value()
    assert {"count", "sum_us", "mean_us", "p50_us", "p99_us",
            "max_us"} <= set(v)


def test_histogram_saturates_last_bucket():
    h = obs.histogram("huge_us")
    h.observe(1e6)                 # 10^12 µs: beyond the bucket range
    assert h.count == 1
    assert h.percentile(1.0) == float(1 << 39)


def test_instruments_are_shared_and_type_checked():
    assert obs.counter("c", {"a": 1}) is obs.counter("c", {"a": 1})
    obs.inc("c", 2, {"a": 1})
    obs.inc("c", 3, {"a": 1})
    assert obs.counter("c", {"a": 1}).value == 5
    with pytest.raises(TypeError):
        obs.gauge("c", {"a": 1})   # name already bound to a Counter


def test_disabled_mode_is_a_noop():
    obs.disable()
    try:
        obs.inc("dead")
        obs.set_gauge("dead_g", 7)
        obs.observe("dead_us", 1e-3)
        obs.emit("dead.event", x=1)
        obs.record_gc_pause("mark", 1e-3)
        with obs.trace("dead.span") as sp:
            assert sp is None
    finally:
        obs.enable()
    snap = obs.snapshot()
    assert snap["metrics"] == {"counters": {}, "gauges": {},
                               "histograms": {}}
    assert snap["events"] == []
    assert snap["spans"] == []
    assert snap["gc"]["slice_pauses"] == []


def test_monotonic_never_goes_backwards():
    t0 = obs.monotonic()
    t1 = obs.monotonic()
    assert t1 >= t0


# ----------------------------------------------------------------- spans

def test_trace_nesting_and_exception_closes_span():
    with obs.trace("outer", op="demo") as root:
        with obs.trace("inner") as ch:
            assert obs.current_span() is ch
        with pytest.raises(RuntimeError):
            with obs.trace("boom"):
                raise RuntimeError("bang")
        # contextvar restored even though "boom" raised
        assert obs.current_span() is root
    assert obs.current_span() is None
    roots = obs.recent_spans()
    assert roots[-1] is root
    assert [c.name for c in root.children] == ["inner", "boom"]
    boom = root.children[1]
    assert boom.error == "RuntimeError"
    assert boom.parent_id == root.span_id
    assert root.child_seconds() <= root.duration_s


def test_store_span_closed_on_backend_exception():
    store = MemoryBackend(verify=True)
    with pytest.raises(TamperedChunk):
        store.put(b"payload", b"\x00" * 32)   # wrong caller-supplied cid
    assert obs.current_span() is None
    sp = obs.recent_spans()[-1]
    assert sp.name == "store.put"
    assert sp.error == "TamperedChunk"


def test_read_timing_is_sampled_one_in_eight():
    store = MemoryBackend()
    cids = store.put_many([b"a" * 100, b"b" * 100])
    h = obs.histogram("store_get_us", {"backend": "memory"})
    store.get_many(cids)           # first multi-cid batch is sampled
    assert h.count == 1
    for _ in range(7):
        store.get_many(cids)       # next 7 skip the timer
    assert h.count == 1
    store.get_many(cids)           # 8th lands again
    assert h.count == 2
    store.get(cids[0])             # single-cid reads are never timed
    assert h.count == 2
    assert store.stats.gets == 9 * 2 + 1   # StoreStats still counts all


# --------------------------------------------------- cluster span fan-out

def test_cluster_fanout_parent_child_ids_across_servlets():
    cl = Cluster(n_nodes=4, mode="2LP")
    rng = np.random.default_rng(0)
    for i in range(8):
        cl.put(f"key-{i}", FBlob(rng.bytes(2048)))
    roots = [sp for sp in obs.recent_spans() if sp.name == "cluster.put"]
    assert len(roots) == 8
    all_ids = []
    for root in roots:
        engine = [c for c in root.children if c.name == "engine.put"]
        assert len(engine) == 1
        assert engine[0].parent_id == root.span_id
        assert root.child_seconds() <= root.duration_s
        all_ids.extend(sp.span_id for sp in root.walk())
    assert len(all_ids) == len(set(all_ids))   # ids unique across fan-out


def test_durable_cluster_put_trace_has_four_layers(tmp_path):
    # tiny hot tier: the put demotes to the segment store INSIDE the
    # tiered put, so one client put yields the full layer stack
    cl = Cluster(n_nodes=2, durable_root=str(tmp_path),
                 hot_bytes=1 << 10, segment_bytes=256 << 10)
    rng = np.random.default_rng(1)
    cl.put("doc", FBlob(rng.bytes(64 << 10)))
    root = next(sp for sp in reversed(obs.recent_spans())
                if sp.name == "cluster.put")

    # per-layer spans under one root, with per-layer backend labels
    names = [sp.name for sp in root.walk()]
    assert "engine.put" in names
    backends = {sp.attrs.get("backend") for sp in root.walk()
                if sp.name == "store.put"}
    assert {"routing", "tiered", "segment"} <= backends

    def depth(sp):
        return 1 + max((depth(c) for c in sp.children), default=0)

    assert depth(root) >= 4        # cluster -> engine -> routing -> tiered+

    # timing discipline: at every node, summed child time <= own time
    for sp in root.walk():
        assert sp.child_seconds() <= sp.duration_s * (1 + 1e-9)
        for c in sp.children:
            assert c.parent_id == sp.span_id
    put_spans = [sp for sp in root.walk() if sp.name == "store.put"]
    assert all(sp.attrs.get("chunks", 0) >= 1 for sp in put_spans)
    assert any(sp.attrs.get("bytes", 0) > 0 for sp in put_spans)
    cl.sync()


# --------------------------------------------------------------- events

def test_eventlog_ring_bound_and_jsonl_sink(tmp_path):
    path = tmp_path / "events.jsonl"
    log = obs.EventLog(capacity=4, sink_path=str(path))
    try:
        for i in range(10):
            log.emit("demo.tick", i=i, blob=b"\xff")
        assert len(log) == 4                       # ring kept bounded
        assert [e["i"] for e in log.events("demo.tick")] == [6, 7, 8, 9]
        assert log.counts()["demo.tick"] == 10     # rate survives the wrap
        lines = [json.loads(ln) for ln in path.read_text().splitlines()]
        assert [e["i"] for e in lines] == list(range(10))
        assert all(e["kind"] == "demo.tick" and e["blob"] == "ff"
                   for e in lines)
        assert obs.counter("events_total", {"kind": "demo.tick"}).value == 10
    finally:
        log.close_sink()


def test_tier_events_demote_promote_and_torn_tail(tmp_path):
    store = open_durable(str(tmp_path / "t"), hot_bytes=1 << 10,
                         segment_bytes=64 << 10)
    raws = [bytes([i]) * 600 for i in range(8)]
    cids = store.put_many(raws)                   # overflows the hot tier
    demotes = obs.EVENTS.events("tier.demote")
    assert demotes and demotes[0]["cause"] == "overflow"
    store.flush()
    causes = {e["cause"] for e in obs.EVENTS.events("tier.demote")}
    assert "flush" in causes
    store.demote(0)                               # everything cold now
    assert store.get(cids[0]) == raws[0]
    assert obs.EVENTS.events("tier.promote")
    store.close()

    # garbage appended to the active segment is truncated on reopen and
    # journaled as a torn-tail event
    seg_dir = tmp_path / "t" / "segments"
    seg = sorted(seg_dir.glob("seg-*.seg"))[-1]
    with open(seg, "ab") as f:
        f.write(b"\x07garbage-tail")
    reopened = SegmentBackend(str(seg_dir))
    torn = obs.EVENTS.events("storage.torn_tail")
    assert torn and torn[-1]["backend"] == "segment"
    assert torn[-1]["dropped_bytes"] > 0
    assert sorted(reopened.iter_cids()) == sorted(cids)
    reopened.close()


# ----------------------------------------------- segment reopen stats

def test_segment_reopen_adopts_stats_without_double_count(tmp_path):
    root = str(tmp_path / "segs")
    store = SegmentBackend(root, segment_bytes=1 << 20)
    raws = [bytes([i]) * 100 for i in range(10)]
    cids = store.put_many(raws)
    assert store.stats.puts == 10
    phys = store.stats.physical_bytes
    store.close()

    h = obs.histogram("store_put_us", {"backend": "segment"})
    count_before = h.count
    assert count_before >= 1                      # the one live batch

    reopened = SegmentBackend(root)
    # replay re-derives the stats (replay == re-execution): the counts
    # match the original store exactly — adopted once, not added twice
    assert reopened.stats.puts == 10
    assert reopened.stats.physical_bytes == phys
    assert sorted(reopened.iter_cids()) == sorted(cids)
    # and replay never routes through the instrumented put path, so the
    # latency histogram is untouched (snapshot pulls stats, never pushes)
    assert h.count == count_before
    snap = obs.snapshot(stores={"segment": reopened.stats})
    assert snap["stores"]["segment"]["puts"] == 10
    reopened.close()


# ------------------------------------------------------------ GC events

def test_gc_events_and_slice_pause_history():
    db = ForkBase()
    rng = np.random.default_rng(2)
    for i in range(4):
        db.put(f"k{i}", FBlob(rng.bytes(4096)))
        db.put(f"k{i}", FBlob(rng.bytes(4096)))   # garbage: old versions
    col = db.incremental_gc()
    while col.active:
        col.step(64)
    kinds = obs.EVENTS.counts()
    assert kinds.get("gc.begin", 0) >= 1
    assert kinds.get("gc.phase", 0) >= 1
    assert kinds.get("gc.done", 0) >= 1
    snap = db.observe()
    assert snap["gc"]["reports"], "GCReport history should be recorded"
    pauses = snap["gc"]["slice_pauses"]
    assert pauses and all({"phase", "epoch", "us"} <= set(p)
                          for p in pauses)
    assert "gc_slice_us" in snap["metrics"]["histograms"]


# ------------------------------------------------------- audit journal

def test_audit_quarantine_and_release_events(monkeypatch):
    from repro.proof.audit import AuditDaemon, AuditFinding, AuditReport

    cl = Cluster(n_nodes=2)
    cl.put("x", FBlob(b"payload" * 64))
    daemon = AuditDaemon(cl, sample=4)
    monkeypatch.setattr(
        daemon, "_audit_target",
        lambda target: AuditReport(findings=[
            AuditFinding("node0", "corrupt", "injected corruption")]))
    rep = daemon.tick()
    assert not rep.ok and "node0" in daemon.quarantined
    quarantines = obs.EVENTS.events("audit.quarantine")
    assert quarantines and quarantines[-1]["node"] == "node0"
    assert quarantines[-1]["reason"] == "corrupt"
    assert obs.counter("audit_quarantines_total").value == 1
    assert obs.gauge("audit_quarantined_nodes").value == 1
    assert obs.EVENTS.counts().get("audit.finding", 0) >= 1

    daemon.release("node0")
    releases = obs.EVENTS.events("audit.release")
    assert releases and releases[-1]["node"] == "node0"
    assert releases[-1]["reason"] == "operator-release"
    assert obs.counter("audit_releases_total").value == 1
    assert obs.gauge("audit_quarantined_nodes").value == 0


# ------------------------------------------------------------ exporters

def test_snapshot_json_roundtrip_with_tier_and_gc(tmp_path):
    cl = Cluster(n_nodes=2, durable_root=str(tmp_path),
                 hot_bytes=4 << 10, segment_bytes=64 << 10)
    rng = np.random.default_rng(3)
    for i in range(4):
        cl.put(f"k{i}", FBlob(rng.bytes(8 << 10)))
    for i in range(4):
        assert cl.get(f"k{i}").blob().read()
    obs.record_gc_pause("mark", 123e-6, epoch=5)

    snap = cl.observe()
    blob = json.dumps(snap)                      # JSON-safe end to end
    assert json.loads(blob) == snap
    assert snap["enabled"] is True
    hists = snap["metrics"]["histograms"]
    put_keys = [k for k in hists if k.startswith("store_put_us")]
    assert put_keys
    assert all({"p50_us", "p99_us", "max_us", "count"} <= set(hists[k])
               for k in put_keys)
    assert snap["gc"]["slice_pauses"][-1] == {"phase": "mark", "epoch": 5,
                                              "us": 123.0}
    roll = snap["stores"]["cluster"]
    assert 0.0 <= roll["tier_hit_rate"] <= 1.0
    assert roll["puts"] == sum(snap["stores"][f"node{i}"]["puts"]
                               for i in range(2))
    assert snap["cluster"]["mode"] == "2LP"
    assert [sp for sp in snap["spans"] if sp["name"] == "cluster.put"]


def test_prometheus_text_renders_all_instrument_kinds():
    obs.inc("reqs_total", 3, {"verb": "put"})
    obs.set_gauge("depth", 7)
    obs.observe("lat_us", 5e-6)
    st = StoreStats(puts=2, logical_bytes=10, physical_bytes=5)
    text = obs.prometheus_text(stores={"main": st})
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{verb="put"} 3' in text
    assert "# TYPE depth gauge" in text
    assert "depth 7" in text
    assert "# TYPE lat_us summary" in text
    assert 'lat_us{quantile="0.5"}' in text
    assert "lat_us_count 1" in text
    assert 'store_puts{store="main"} 2' in text


def test_store_stats_as_dict_and_merge():
    a = StoreStats(puts=2, gets=4, logical_bytes=100, physical_bytes=50,
                   tier_hits=3, tier_misses=1)
    b = StoreStats(puts=1, gets=1, logical_bytes=20, physical_bytes=20,
                   tier_hits=1, tier_misses=3)
    out = a.merge(b)
    assert out is a
    d = a.as_dict()
    assert d["puts"] == 3 and d["gets"] == 5
    assert d["logical_bytes"] == 120 and d["physical_bytes"] == 70
    assert d["dedup_ratio"] == pytest.approx(120 / 70)
    assert d["tier_hit_rate"] == pytest.approx(4 / 8)
    # exhaustive export: every dataclass field appears in the dict
    from dataclasses import fields
    assert {f.name for f in fields(StoreStats)} <= set(d)
