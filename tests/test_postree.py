"""POS-Tree property tests: the load-bearing invariant is
equal content <=> identical root cid, independent of edit history."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import chunk as ck
from repro.core.chunker import ChunkParams
from repro.core.chunkstore import ChunkStore
from repro.core.postree import POSTree

P8 = ChunkParams(q=8)


def build_map(store, items, params=P8):
    items = sorted(items.items())
    els = [ck.pack_kv(k, v) for k, v in items]
    return POSTree.build_elements(store, ck.MAP, els,
                                  [k for k, _ in items], params)


# ------------------------------------------------------------ determinism

@given(st.binary(min_size=0, max_size=20_000))
@settings(max_examples=20, deadline=None)
def test_blob_content_determinism(data):
    s = ChunkStore()
    t1 = POSTree.build_bytes(s, data, P8)
    t2 = POSTree.build_bytes(s, bytes(data), P8)
    assert t1.root_cid == t2.root_cid
    assert t1.read_bytes(0, len(data)) == data


@given(st.dictionaries(st.binary(min_size=1, max_size=12),
                       st.binary(max_size=40), max_size=200))
@settings(max_examples=20, deadline=None)
def test_map_content_determinism(items):
    s = ChunkStore()
    t1 = build_map(s, items)
    t2 = build_map(s, dict(reversed(list(items.items()))))
    assert t1.root_cid == t2.root_cid


# --------------------------------------- incremental commit == full rebuild

@given(st.binary(min_size=1, max_size=8000),
       st.lists(st.tuples(st.integers(0, 7999), st.integers(0, 200),
                          st.binary(max_size=100)), min_size=1, max_size=4))
@settings(max_examples=25, deadline=None)
def test_blob_splice_equals_rebuild(data, edits):
    s = ChunkStore()
    tree = POSTree.build_bytes(s, data, P8)
    cur = data
    for start, dlen, rep in edits:
        start = min(start, len(cur))
        end = min(start + dlen, len(cur))
        tree.splice_bytes([(start, end, rep)])
        cur = cur[:start] + rep + cur[end:]
        ref = POSTree.build_bytes(s, cur, P8)
        assert tree.root_cid == ref.root_cid
        assert tree.read_bytes(0, tree.total_count) == cur


@given(st.dictionaries(st.binary(min_size=1, max_size=10),
                       st.binary(max_size=30), min_size=1, max_size=150),
       st.lists(st.tuples(st.binary(min_size=1, max_size=10),
                          st.one_of(st.none(), st.binary(max_size=30))),
                min_size=1, max_size=6))
@settings(max_examples=25, deadline=None)
def test_map_edits_equal_rebuild(items, ops):
    """Random set/delete sequences: incremental tree == fresh build,
    regardless of operation order (order-independence of the final state).
    Exercises FMap overlay batching + splice_elements."""
    from repro.core.types import FMap
    s = ChunkStore()
    m = FMap(items, params=P8)
    m.commit(s)
    state = dict(items)
    for k, v in ops:
        if v is None:
            m.delete(k)
            state.pop(k, None)
        else:
            m.set(k, v)
            state[k] = v
    m.commit(s)
    ref = build_map(s, state)
    assert m.tree.root_cid == ref.root_cid


# ----------------------------------------------------------------- dedup

def test_dedup_across_versions(rng):
    s = ChunkStore()
    data = rng.integers(0, 256, 200_000, dtype=np.uint8)
    t1 = POSTree.build_bytes(s, data, P8)
    phys0 = s.stats.physical_bytes
    d2 = data.copy()
    d2[1000:1010] = 0
    t2 = POSTree.build_bytes(s, d2, P8)
    added = s.stats.physical_bytes - phys0
    assert added < 0.05 * phys0, f"dedup failed: {added}/{phys0}"
    shared = t1.node_cids() & t2.node_cids()
    assert len(shared) > 0.8 * len(t1.node_cids())


def test_cross_object_dedup(rng):
    """The paper's point vs Decibel: dedup works ACROSS objects."""
    s = ChunkStore()
    base = rng.integers(0, 256, 100_000, dtype=np.uint8)
    POSTree.build_bytes(s, base, P8)
    phys0 = s.stats.physical_bytes
    other = np.concatenate([rng.integers(0, 256, 512, dtype=np.uint8), base])
    POSTree.build_bytes(s, other, P8)   # a *different* object, shared tail
    added = s.stats.physical_bytes - phys0
    assert added < 0.1 * phys0


# ------------------------------------------------------------------ diff

def test_diff_keys_precision(rng):
    s = ChunkStore()
    items = {f"k{i:05d}".encode(): rng.bytes(20) for i in range(3000)}
    t1 = build_map(s, items)
    items2 = dict(items)
    items2[b"k00777"] = b"CHANGED"
    items2[b"knew"] = b"ADDED"
    del items2[b"k01234"]
    t2 = build_map(s, items2)
    a, r, c = t2.diff_keys(t1)
    assert a == [b"knew"] and r == [b"k01234"] and c == [b"k00777"]


def test_lookup_paths(rng):
    s = ChunkStore()
    items = {f"k{i:05d}".encode(): rng.bytes(16) for i in range(2000)}
    t = build_map(s, items)
    assert t.descend_key(b"k00500") == items[b"k00500"]
    found, j, li, gi = t.find_key(b"k01999")
    assert found and t.get_item(gi) == (b"k01999", items[b"k01999"])
    t2 = POSTree.from_root(s, ck.MAP, t.root_cid, P8)
    assert t2.root_cid == t.root_cid
    assert t2.descend_key(b"k00001") == items[b"k00001"]


def test_tamper_evidence(rng):
    s = ChunkStore(verify=True)
    data = rng.integers(0, 256, 50_000, dtype=np.uint8)
    t = POSTree.build_bytes(s, data, P8)
    cid = t.levels[0][3].cid
    s._data[cid] = b"\x03tampered!"          # corrupt a stored chunk
    with pytest.raises(AssertionError):
        s.get(cid)
